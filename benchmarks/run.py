"""Benchmark harness — one function per paper table/figure, plus the
roofline aggregation over the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig1,...]

Prints ``name,us_per_call,derived`` CSV (one line per benchmark) and writes
detailed JSON to experiments/bench/.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"


def _emit(name: str, us: float, derived: str, detail: dict) -> None:
    print(f"{name},{us:.0f},{derived}", flush=True)
    OUT.mkdir(parents=True, exist_ok=True)
    detail = dict(detail, name=name, us_per_call=us, derived=derived)
    (OUT / f"{name}.json").write_text(json.dumps(detail, indent=2, default=str))


# ------------------------------------------------------------------ #
# Table 1 — memory efficiency on 500-token generation
# ------------------------------------------------------------------ #
def table1_memory() -> None:
    from benchmarks.common import bench_config, random_params
    from repro.serving.engine import Engine
    from repro.serving.sampling import SamplingParams

    cfg = bench_config()
    params = random_params(cfg)
    n_tok = 500
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 14), 0,
                                cfg.vocab_size)
    rows = {}
    for label, freeze in (("baseline", False), ("asr_kf_egr", True)):
        eng = Engine(cfg, params, max_seq=560, enable_freeze=freeze)
        t0 = time.time()
        res = eng.generate({"tokens": jnp.asarray(prompt)}, n_tok,
                           SamplingParams(temperature=0.7))
        dt = time.time() - t0
        rows[label] = {
            "total_tokens": res.total_kv[-1],
            "active_kv": int(res.active_kv[-1]),
            "compression_pct": round(100 * res.compression, 2),
            "time_s": round(dt, 2),
        }
    d = rows["asr_kf_egr"]
    _emit("table1_memory", 1e6 * d["time_s"] / n_tok,
          f"compression={d['compression_pct']}%_active={d['active_kv']}"
          f"/{d['total_tokens']}",
          {"rows": rows, "paper": {"compression_pct": 66.93,
                                   "active_kv": 170, "total": 514}})


# ------------------------------------------------------------------ #
# Table 2 — passkey retrieval (needle-in-haystack)
# ------------------------------------------------------------------ #
def table2_passkey() -> None:
    from benchmarks.common import (bench_config, copy_accuracy,
                                   induction_trained_params)
    from repro.serving.engine import Engine
    from repro.serving.sampling import SamplingParams
    from repro.training import data as DATA

    cfg = bench_config(trained_vocab=True)
    t0 = time.time()
    params = induction_trained_params(cfg)
    acc = copy_accuracy(params, cfg)
    passkey = 44181
    ctx = 384
    prompt, _ = DATA.passkey_prompt(cfg.vocab_size, ctx, passkey, seed=7)
    batch = {"tokens": jnp.asarray(prompt[None])}
    outs = {}
    for label, freeze in (("baseline", False), ("asr_kf_egr", True)):
        eng = Engine(cfg, params, max_seq=ctx + 16, enable_freeze=freeze)
        res = eng.generate(batch, DATA.N_DIGITS, SamplingParams.greedy())
        outs[label] = res
    needle = DATA.encode_passkey(passkey)
    got_f = outs["asr_kf_egr"].tokens[0]
    got_b = outs["baseline"].tokens[0]
    digits_ok = bool((got_f == needle).all())
    parity = bool((got_f == got_b).all())
    dt = time.time() - t0
    _emit("table2_passkey", 1e6 * dt,
          f"digits={'PASS' if digits_ok else 'FAIL'}"
          f"_parity={'PASS' if parity else 'FAIL'}"
          f"_copyacc={acc:.2f}",
          {"needle": needle.tolist(), "frozen_out": got_f.tolist(),
           "baseline_out": got_b.tolist(), "copy_accuracy": acc,
           "compression_pct": round(100 * outs["asr_kf_egr"].compression, 2),
           "paper": {"target": 44181, "retrieved": 44181, "result": "PASS"}})


# ------------------------------------------------------------------ #
# Table 3 — generation quality proxy under identical sampling
# ------------------------------------------------------------------ #
def table3_quality() -> None:
    """Paper compares qualitative explanations.  Deterministic proxy:
    greedy continuation overlap between frozen and full-KV runs of the SAME
    trained model on the SAME prompt."""
    from benchmarks.common import bench_config, induction_trained_params
    from repro.serving.engine import Engine
    from repro.serving.sampling import SamplingParams

    cfg = bench_config(trained_vocab=True)
    t0 = time.time()
    params = induction_trained_params(cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 48), 0,
                                cfg.vocab_size)
    n_tok = 200
    outs = {}
    for label, freeze in (("baseline", False), ("asr_kf_egr", True)):
        eng = Engine(cfg, params, max_seq=300, enable_freeze=freeze)
        outs[label] = eng.generate({"tokens": jnp.asarray(prompt)}, n_tok,
                                   SamplingParams.greedy())
    agree = float(np.mean(outs["baseline"].tokens == outs["asr_kf_egr"].tokens))
    comp = outs["asr_kf_egr"].compression
    dt = time.time() - t0
    _emit("table3_quality", 1e6 * dt / n_tok,
          f"greedy_agreement={agree:.2f}_compression={100*comp:.1f}%",
          {"greedy_agreement": agree,
           "active_kv": outs["asr_kf_egr"].active_kv[-1],
           "baseline_active": outs["baseline"].active_kv[-1],
           "compression_pct": round(100 * comp, 2),
           "paper": {"baseline_active": 269, "frozen_active": 119,
                     "compression_pct": 55.76}})


# ------------------------------------------------------------------ #
# Figure 1 — active-KV trajectory
# ------------------------------------------------------------------ #
def fig1_trajectory() -> None:
    from benchmarks.common import bench_config, random_params
    from repro.serving.engine import Engine
    from repro.serving.sampling import SamplingParams

    cfg = bench_config()
    params = random_params(cfg)
    eng = Engine(cfg, params, max_seq=560)
    t0 = time.time()
    res = eng.generate(
        {"tokens": jax.random.randint(jax.random.PRNGKey(2), (1, 14), 0,
                                      cfg.vocab_size)},
        500, SamplingParams(temperature=0.7))
    dt = time.time() - t0
    a = np.asarray(res.active_kv)
    t = np.asarray(res.total_kv, dtype=np.float64)
    # paper Fig. 1 signatures: sublinear growth + oscillation + plateau
    tail_slope = np.polyfit(np.arange(len(a) - len(a) // 2),
                            a[len(a) // 2:], 1)[0]
    osc = int(np.sum(np.diff(np.sign(np.diff(a))) != 0))
    _emit("fig1_trajectory", 1e6 * dt / 500,
          f"tail_slope={tail_slope:.3f}_oscillations={osc}"
          f"_final_ratio={a[-1]/t[-1]:.2f}",
          {"active": a.tolist(), "total": res.total_kv,
           "tail_slope_tokens_per_step": tail_slope,
           "sign_changes": osc,
           "paper": "active stabilizes ~100-170 while total grows linearly"})


# ------------------------------------------------------------------ #
# Roofline aggregation (reads experiments/dryrun/*.json)
# ------------------------------------------------------------------ #
def roofline() -> None:
    from benchmarks.roofline import aggregate
    t0 = time.time()
    table = aggregate()
    n = len(table)
    dom = {}
    for r in table:
        dom[r["bottleneck"]] = dom.get(r["bottleneck"], 0) + 1
    _emit("roofline", 1e6 * (time.time() - t0),
          f"combos={n}_bottlenecks={dom}", {"rows": table})


def ablations() -> None:
    from benchmarks import ablations as AB
    t0 = time.time()
    AB.length_scaling()
    AB.tau_sensitivity()
    _emit("ablations", 1e6 * (time.time() - t0),
          "length_scaling+tau_sensitivity(json_in_experiments/bench)", {})


BENCHES = {
    "table1": table1_memory,
    "table2": table2_passkey,
    "table3": table3_quality,
    "fig1": fig1_trajectory,
    "roofline": roofline,
    "ablations": ablations,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main()

"""Roofline aggregation: reads experiments/dryrun/*.json (produced by
repro.launch.dryrun) and emits the §Roofline table of EXPERIMENTS.md —
compute / memory / collective seconds per (arch x shape x mesh), dominant
bottleneck, MODEL_FLOPS ratio, and a one-line improvement note per row.

    PYTHONPATH=src python -m benchmarks.roofline [--markdown]
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import List

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _note(r: dict) -> str:
    """One sentence on what would move the dominant term down."""
    b = r.get("bottleneck")
    kind = r.get("kind", "")
    if b == "memory":
        if kind.startswith("decode"):
            return ("decode reads params+KV once per token: raise batch or "
                    "quantize KV (freeze already caps resident KV)")
        return "reduce remat recompute / keep activations bf16"
    if b == "collective":
        if kind == "train":
            return "overlap FSDP all-gathers with compute; reduce-scatter grads"
        return "keep weights resident (tensor-only sharding) to kill per-step all-gather"
    if b == "compute":
        if kind in ("train", "prefill"):
            return ("causal-masked full S^2 attention in the pure-JAX path "
                    "counts 2x logical FLOPs; TPU Pallas kernel halves it")
        return "MXU-align block shapes; skip frozen KV blocks in the kernel"
    return ""


def load() -> List[dict]:
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        rows.append(r)
    return rows


def aggregate(optimized: bool = False) -> List[dict]:
    out = []
    for r in load():
        if bool(r.get("optimized")) != optimized:
            continue
        if not r.get("ok") or "skipped" in r:
            if "skipped" in r:
                out.append({"arch": r["arch"], "shape": r["shape"],
                            "mesh": r["mesh"], "bottleneck": "skipped",
                            "note": r["skipped"]})
            continue
        rf = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "kind": r.get("kind"),
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "bottleneck": r["bottleneck"],
            "hlo_flops": r["hlo_flops"], "hlo_bytes": r["hlo_bytes"],
            "collective_bytes": r["collectives"]["total"],
            "model_flops_total": r["model_flops_total"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "bytes_per_device": r.get("argument_size_in_bytes", 0),
            "temp_bytes": r.get("temp_size_in_bytes", 0),
            "optimized": bool(r.get("optimized")),
            "note": _note(r),
        })
    return out


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    for unit, f in (("s", 1.0), ("ms", 1e3), ("us", 1e6)):
        if x * f >= 1:
            return f"{x*f:.2f}{unit}"
    return f"{x*1e9:.1f}ns"


def markdown(rows: List[dict], mesh_filter: str = "data=16xmodel=16") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "MODEL/HLO flops | args/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh_filter and r.get("bottleneck") != "skipped":
            continue
        if r["bottleneck"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']} | "
            f"{r['bytes_per_device']/2**30:.2f}GB |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default="data=16xmodel=16")
    args = ap.parse_args()
    rows = aggregate()
    if args.markdown:
        print(markdown(rows, args.mesh))
    else:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()

"""Serving benchmarks over mixed request traces.

Three comparisons, all reported per run:

1. **static vs continuous** (PR 1): the static FIFO batcher runs every batch
   for max(n_tokens) steps (head-of-line blocking); the continuous engine
   retires a lane and admits the next request mid-stream.  Deterministic
   mixed trace, throughput + latency + jitted-step counts.

2. **contiguous vs paged continuous** (PR 2): a long-prompt mixed trace
   with Poisson arrivals served by both continuous engines.  The contiguous
   engine carries a dense (n_lanes, max_seq) cache and prefills each prompt
   in one blocking call; the paged engine decodes over a bounded
   O(P * page) active pool per lane with chunked prefill interleaved into
   resident decode steps.  Reported: throughput, arrival-to-completion
   latency p50/p99, peak live device KV bytes (incl. prefill scratch), and
   page swap counts — the acceptance check is paged winning p99 at strictly
   lower peak KV.

3. **needle-in-haystack retrieval** (PR 3): the paper's defining claim is
   that freezing is *reversible* — entropy spikes recover frozen KV, which
   is what separates ASR-KF-EGR from eviction schemes that permanently
   lose early context.  Each request plants a "needle" in its first prompt
   page, freeze pressure pushes that page out (frozen / host-stashed), and
   sustained entropy spikes drive the recovery ladder.  Retrieval accuracy
   = the fraction of the needle's KV that is *attendable* (un-frozen, and
   device-resident on the paged path) during the query window — the last
   stretch of each request's decode.  Acceptance: the paged engine with
   recovery enabled matches the contiguous engine's accuracy at strictly
   lower peak device KV bytes; paged *without* recovery is reported as the
   eviction-scheme contrast.

    PYTHONPATH=src python -m benchmarks.continuous_batching           # full
    PYTHONPATH=src python -m benchmarks.continuous_batching --smoke   # CI
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import numpy as np

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"

# mixed-length trace from the PR-1 acceptance criteria: 8 requests,
# n_tokens spanning 8..64, served on 4 lanes
TRACE = [64, 8, 8, 8, 32, 16, 8, 8]
N_LANES = 4
MAX_SEQ = 160


def make_requests(cfg, seed=0):
    from repro.serving.sampling import SamplingParams
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, size=16), n,
             SamplingParams(temperature=0.7)) for n in TRACE]


def run_static(cfg, params):
    from repro.serving.engine import Engine
    from repro.serving.scheduler import StaticScheduler

    eng = Engine(cfg, params, max_seq=MAX_SEQ)
    sched = StaticScheduler(eng, batch_size=N_LANES)
    for prompt, n, sp in make_requests(cfg):
        sched.submit(prompt, n, sp)
    t0 = time.time()
    latencies = []
    while sched.queue:
        uids = sched.run_once()
        now = time.time() - t0
        latencies += [now] * len(uids)
    # every batch runs max(n_tokens) - 1 decode steps after its prefill
    steps = sum(max(TRACE[i:i + N_LANES]) - 1
                for i in range(0, len(TRACE), N_LANES))
    return _stats(time.time() - t0, latencies, steps)


def run_continuous(cfg, params):
    from repro.serving.engine import ContinuousEngine
    from repro.serving.scheduler import Scheduler

    eng = ContinuousEngine(cfg, params, max_seq=MAX_SEQ, n_lanes=N_LANES)
    sched = Scheduler(eng)
    for prompt, n, sp in make_requests(cfg):
        sched.submit(prompt, n, sp)
    t0 = time.time()
    latencies = []
    while sched.queue or sched.engine.n_active_lanes:
        uids = sched.run_once()
        if not uids:
            break
        now = time.time() - t0
        latencies += [now] * len(uids)
    return _stats(time.time() - t0, latencies, eng.wall_step)


def _stats(wall_s, latencies, steps):
    total_tokens = sum(TRACE)
    # each request's first token comes from its prefill, so only
    # n_tokens - 1 of its tokens occupy decode lane-steps
    decode_tokens = total_tokens - len(TRACE)
    return {
        "wall_s": round(wall_s, 2),
        "tokens_per_s": round(total_tokens / max(wall_s, 1e-9), 1),
        "latency_p50_s": round(float(np.percentile(latencies, 50)), 2),
        "latency_p99_s": round(float(np.percentile(latencies, 99)), 2),
        "jitted_steps": steps,
        "lane_steps": steps * N_LANES,
        "useful_tokens": total_tokens,
        "utilization_pct": round(100 * decode_tokens / (steps * N_LANES), 1),
    }


# ===================================================================== #
# Long-prompt mixed trace, Poisson arrivals: contiguous vs paged engine
# ===================================================================== #
def long_trace(cfg, smoke: bool, seed=0):
    """(prompt_len, n_tokens) mix dominated by a few very long prompts —
    the head-of-line-blocking case chunked prefill is built for."""
    if smoke:
        lens = [(192, 12), (24, 12), (16, 12), (192, 12), (24, 12), (16, 12)]
        mean_gap = 0.05
    else:
        lens = [(768, 24), (48, 24), (32, 24), (640, 24), (48, 16),
                (768, 24), (32, 16), (48, 24), (640, 16), (32, 24),
                (48, 16), (768, 24), (32, 16), (48, 24)]
        mean_gap = 0.08
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(mean_gap, size=len(lens)))
    from repro.serving.sampling import SamplingParams
    reqs = [(rng.randint(0, cfg.vocab_size, size=pl), n,
             SamplingParams(temperature=0.7)) for pl, n in lens]
    return reqs, arrivals


def serve_poisson(engine, reqs, arrivals):
    """Drive a continuous engine (contiguous or paged — same lane API)
    against timed arrivals; latency is arrival -> completion.  Step and
    swap counts are deltas, so the same engine can serve a warmup pass
    first — jit caches live on the engine's wrappers, so warming a
    throwaway engine would warm nothing."""
    from repro.serving.engine import Request

    step0 = engine.wall_step
    pending = list(zip(range(1, len(reqs) + 1), reqs, arrivals))
    arr_of = {i + 1: a for i, a in enumerate(arrivals)}
    queue, lat, step_lat, done = [], [], [], 0
    t0 = time.time()
    while done < len(reqs):
        now = time.time() - t0
        if not queue and engine.n_active_lanes == 0 and pending \
                and pending[0][2] > now:
            t0 -= pending[0][2] - now     # fast-forward idle gaps
            now = pending[0][2]
        while pending and pending[0][2] <= now:
            uid, (prompt, n, sp), _ = pending.pop(0)
            queue.append(Request(uid, np.asarray(prompt, np.int32), n, sp))
        while queue and engine.has_free_lane:
            engine.admit(queue.pop(0))
        if engine.n_active_lanes == 0:
            continue
        ts = time.perf_counter()
        retired = engine.step_once()
        step_lat.append(time.perf_counter() - ts)
        for req in retired:
            lat.append((time.time() - t0) - arr_of[req.uid])
            done += 1
    wall = time.time() - t0
    total_tokens = sum(n for _, n, _ in reqs)
    return {
        "wall_s": round(wall, 2),
        "tokens_per_s": round(total_tokens / max(wall, 1e-9), 1),
        "latency_p50_s": round(float(np.percentile(lat, 50)), 3),
        "latency_p99_s": round(float(np.percentile(lat, 99)), 3),
        "step_ms_mean": round(1e3 * float(np.mean(step_lat)), 3),
        "step_ms_p50": round(1e3 * float(np.percentile(step_lat, 50)), 3),
        "step_ms_p99": round(1e3 * float(np.percentile(step_lat, 99)), 3),
        "jitted_steps": engine.wall_step - step0,
        "peak_kv_bytes": int(engine.peak_kv_bytes),
    }


def paged_config(cfg):
    """Freeze settings shared by both arms of the paged comparison:
    page-granular quantile freeze, recovery off (the paged path restores
    via timer expiry only — keep the arms symmetric)."""
    fc = dataclasses.replace(cfg.freeze, page_size=32, window=32,
                             tau_mode="quantile", quantile=0.5, k_soft=1.0,
                             recovery_enabled=False)
    return dataclasses.replace(cfg, freeze=fc)


def run_paged_comparison(cfg, params, smoke: bool, warmup: bool = True):
    from repro.serving.engine import ContinuousEngine, PagedContinuousEngine

    cfg = paged_config(cfg)
    max_seq = 256 if smoke else 1024
    n_lanes = 2 if smoke else 4
    pool_pages = 4 if smoke else 6          # 128 / 192 active slots
    chunk = 64 if smoke else 128
    reqs, arrivals = long_trace(cfg, smoke)

    contig = ContinuousEngine(cfg, params, max_seq=max_seq, n_lanes=n_lanes)
    if warmup:                  # same engine: jit caches are per-wrapper
        serve_poisson(contig, reqs, arrivals)
    swaps0 = contig.offloader.n_offloads if contig.offloader else 0
    c_stats = serve_poisson(contig, reqs, arrivals)
    c_stats["swaps"] = (contig.offloader.n_offloads - swaps0
                        if contig.offloader else 0)

    paged = PagedContinuousEngine(cfg, params, max_seq=max_seq,
                                  n_lanes=n_lanes,
                                  max_active_pages=pool_pages,
                                  prefill_chunk=chunk)
    if warmup:
        # the burst-chunk schedule is load-dependent, so compile the closed
        # shape set up front instead of relying on one observed trace
        for plen, n in sorted({(len(p), n) for p, n, _ in reqs}):
            paged.warm_prefill(plen, n)
        serve_poisson(paged, reqs, arrivals)
    swaps0 = paged.ctl.n_swap_out + paged.ctl.n_swap_in
    p_stats = serve_poisson(paged, reqs, arrivals)
    p_stats["swaps"] = paged.ctl.n_swap_out + paged.ctl.n_swap_in - swaps0
    return c_stats, p_stats


# ===================================================================== #
# Async DMA pipeline: sync vs async paged engine, token-parity asserted
# ===================================================================== #
def async_trace_config(cfg):
    """Freeze + recovery settings for the async-pipeline comparison:
    aggressive page-granular freeze pressure (pages stash steadily) plus a
    low absolute entropy threshold so the recovery ladder escalates to FR
    and raises host thaws throughout the decode — the workload the
    speculative-thaw staging is built for.

    f32 + greedy decoding (the repo's parity methodology, see
    tests/test_paged_continuous.py::TestParity): the two arms interleave
    admissions and decode differently, so the load-adaptive prefill-chunk
    schedule produces numerically different (bit-wise) logit roundings —
    greedy argmax over f32 is stable across them, sampled bf16 is not."""
    fc = dataclasses.replace(cfg.freeze, page_size=16, window=16,
                             tau_mode="quantile", quantile=0.55, k_soft=0.7,
                             recovery_enabled=True,
                             entropy_abs_threshold=0.5, rewalk_tokens=8)
    return dataclasses.replace(cfg, freeze=fc, dtype="float32")


def _run_async_arm(cfg, params, smoke: bool, async_pipeline: bool):
    """Serve a deterministic mixed trace (all requests queued up front —
    admissions depend only on lane availability, never on wall clock, so
    both arms make bit-identical decisions) through one paged engine arm;
    returns (per-uid token streams, stats dict)."""
    from repro.analysis import trace_guard
    from repro.serving.engine import PagedContinuousEngine
    from repro.serving.scheduler import Scheduler
    from repro.serving.sampling import SamplingParams

    lens = [(96, 32), (24, 24), (64, 32), (16, 24)] if smoke else \
        [(192, 48), (48, 32), (128, 48), (32, 32), (192, 48), (48, 32)]
    max_seq = 256 if smoke else 512
    eng = PagedContinuousEngine(
        cfg, params, max_seq=max_seq, n_lanes=2,
        max_active_pages=5 if smoke else 6, prefill_chunk=16,
        rewind_cooldown=12, async_pipeline=async_pipeline,
        # fixed chunk split: the arms interleave admissions differently,
        # and burst chunks would change flash-attention summation order
        burst_prefill=False)
    sched = Scheduler(eng)
    rng = np.random.RandomState(3)
    for pl, n in lens:
        sched.submit(rng.randint(0, cfg.vocab_size, size=pl), n,
                     SamplingParams.greedy())

    def run_trace():
        lat = []
        while sched.queue or eng.n_active_lanes:
            sched._admit_free()
            if not eng.n_active_lanes:
                break
            t0 = time.perf_counter()
            for req in eng.step_once():
                sched.done[req.uid] = req
            lat.append(time.perf_counter() - t0)
        return lat

    run_trace()                             # warmup pass (jit compiles)
    snap0 = eng.stats.snapshot()
    thaw0 = (eng.ctl.n_thaw, eng.ctl.n_thaw_remap, eng.ctl.n_thaw_upload)
    # two timed repeats, best-of by mean: wall-clock on shared CI boxes is
    # scheduler/GC-noise dominated, and min-of-N is the standard latency
    # methodology; the structural metrics (parity, blocked fraction, thaw
    # counters) accumulate over both repeats
    lat_reps = []
    # the warmup pass covered every (bucketed) shape this trace hits, so
    # the timed repeats must not grow any jit compile cache — trace_guard
    # reports the actual growth and the CI bench check asserts it is 0
    with trace_guard(eng, label=f"async_arm(async={async_pipeline})") as tg:
        for _ in range(2):
            for pl, n in lens:              # same trace shape each repeat
                sched.submit(rng.randint(0, cfg.vocab_size, size=pl), n,
                             SamplingParams.greedy())
            lat_reps.append(run_trace())
    lat = min(lat_reps, key=lambda ls: float(np.mean(ls)))
    snap1 = eng.stats.snapshot()
    d = lambda k: snap1[k] - snap0[k]
    steps = max(d("steps"), 1)
    tokens = {u - len(lens): np.asarray(sched.done[u].result)
              for u in sorted(sched.done) if u > len(lens)}
    return tokens, {
        "step_ms_mean": round(1e3 * float(np.mean(lat)), 3),
        "step_ms_p50": round(1e3 * float(np.percentile(lat, 50)), 3),
        "step_ms_p99": round(1e3 * float(np.percentile(lat, 99)), 3),
        "host_blocked_fraction": round(d("blocked_steps") / steps, 4),
        "blocking_d2h": d("blocking_d2h"),
        "blocking_h2d": d("blocking_h2d"),
        "async_d2h": d("async_d2h"),
        "async_h2d": d("async_h2d"),
        "thaws": eng.ctl.n_thaw - thaw0[0],
        "thaw_remap": eng.ctl.n_thaw_remap - thaw0[1],
        "thaw_upload": eng.ctl.n_thaw_upload - thaw0[2],
        "peak_kv_bytes": int(eng.peak_kv_bytes),
        "n_retraces": tg.n_retraces,
        "retrace_growth": tg.growth,
    }


def run_async_comparison(cfg, params, smoke: bool):
    """Sync vs async paged engine on the same deterministic thaw-heavy
    trace.  The pipeline must be a pure overlap optimization: token
    streams are asserted identical, the async arm's host-blocked fraction
    must be strictly lower (it blocks only at boundary ticks), and
    speculative staging should turn most thaws into remap-only installs."""
    import jax
    from repro.models import model as MD
    cfg = async_trace_config(cfg)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)   # f32 weights
    sync_toks, sync_stats = _run_async_arm(cfg, params, smoke, False)
    async_toks, async_stats = _run_async_arm(cfg, params, smoke, True)
    parity = set(sync_toks) == set(async_toks) and all(
        np.array_equal(sync_toks[u], async_toks[u]) for u in sync_toks)
    thaws = async_stats["thaws"]
    remap_frac = async_stats["thaw_remap"] / thaws if thaws else 0.0
    return {
        "sync": sync_stats,
        "async": async_stats,
        "token_parity": bool(parity),
        "latency_win": bool(async_stats["step_ms_mean"]
                            < sync_stats["step_ms_mean"]),
        "blocked_win": bool(async_stats["host_blocked_fraction"]
                            < sync_stats["host_blocked_fraction"]),
        "thaw_remap_fraction": round(remap_frac, 3),
    }


# ===================================================================== #
# Needle-in-haystack retrieval: is frozen/stashed context recoverable?
# ===================================================================== #
def needle_config(cfg, page: int, recovery: bool):
    """Aggressive freeze pressure (quantile tau flags half the eligible
    pages every step, k_soft < 1 lengthens timers) plus a low absolute
    entropy threshold so spikes — and with them the recovery ladder — fire
    throughout the decode."""
    fc = dataclasses.replace(cfg.freeze, page_size=page, window=page,
                             tau_mode="quantile", quantile=0.5, k_soft=0.7,
                             recovery_enabled=recovery,
                             entropy_abs_threshold=0.5)
    return dataclasses.replace(cfg, freeze=fc)


def _needle_visibility(eng, lane: int, needle) -> float:
    """Fraction of the needle's KV currently attendable in `lane`.

    Paged engine (`needle` = global page id): mean over layers of "the
    needle page is device-resident AND un-frozen".  Contiguous engine
    (`needle` = cache-slot indices): mean over layers/slots of ~frozen.
    """
    from repro.serving.engine import PagedContinuousEngine
    if isinstance(eng, PagedContinuousEngine):
        pt = np.asarray(eng.state.page_table[:, lane])       # (L, P)
        fro = np.asarray(eng.state.freeze.frozen[:, lane])   # (L, P)
        return float(np.mean([
            bool(((pt[l] == needle) & ~fro[l]).any())
            for l in range(pt.shape[0])]))
    fro = np.asarray(eng.state.freeze.frozen[:, lane, :])    # (L, S)
    return float(np.mean(~fro[:, needle]))


def run_needle(cfg, params, smoke: bool, paged: bool, recovery: bool,
               kv_quant: str = "none"):
    """Serve the needle trace through one engine arm; retrieval accuracy is
    the max needle visibility observed inside each request's query window
    (its last 2 pages of decode steps), averaged over requests — i.e. "can
    attention still reach the needle when the query arrives?".  Accuracy
    is state-based, not timing-based, so no warmup pass is needed.

    ``kv_quant`` turns on per-page quantization of frozen/stashed pages
    (docs/quantization.md).  Beyond accuracy, each arm reports
    ``kv_device_bytes_query_floor`` — the LOWEST device-KV gauge sampled
    on steps where some live lane is inside its query window.  Any
    max-style aggregate is provably blind to the cut: admission starts
    all-hot, so both arms read the identical full pool at the window's
    first steps and a peak ties forever.  Under ``kv_quant="none"`` the
    gauge is constant (the pool is fixed and savings are zero), so the
    floor IS the unquantized footprint, while the quant arm's floor
    captures the packed steady state once stashed pages have swapped
    back in quantized — with ``max_rewinds=0`` and visibility-only
    recovery they never dequantize, so the floor is a residency measure,
    not a transient.  ``dma_bytes`` totals blocking + async transfers
    both ways (quantized pages cross packed, so the quant arm's total
    must drop)."""
    from repro.serving.engine import (ContinuousEngine,
                                      PagedContinuousEngine, Request)
    from repro.serving.sampling import SamplingParams

    page = 16
    cfg = needle_config(cfg, page, recovery)
    n_req = 2 if smoke else 4
    prompt_len = 4 * page if smoke else 8 * page     # needle = prompt page 0
    n_gen = 3 * page if smoke else 4 * page
    pool_pages = 4 if smoke else 6
    max_seq = prompt_len + n_gen + page
    query_window = 2 * page

    # sync pipeline for the needle arms: the probe reads per-lane host
    # bookkeeping (generated counts) between steps, which the async ring
    # defers — retrieval accuracy is a state property, not a timing one
    if paged:
        eng = PagedContinuousEngine(cfg, params, max_seq=max_seq,
                                    n_lanes=n_req,
                                    max_active_pages=pool_pages,
                                    prefill_chunk=page, max_rewinds=0,
                                    async_pipeline=False, kv_quant=kv_quant)
    else:
        eng = ContinuousEngine(cfg, params, max_seq=max_seq, n_lanes=n_req,
                               max_rewinds=0, async_pipeline=False,
                               kv_quant=kv_quant)
    rng = np.random.RandomState(7)
    reqs = [Request(i + 1,
                    rng.randint(0, cfg.vocab_size, size=prompt_len).astype(
                        np.int32),
                    n_gen, SamplingParams(temperature=0.7))
            for i in range(n_req)]
    lane_of = {eng.admit(r): r for r in reqs}
    best = {r.uid: 0.0 for r in reqs}
    steps = 0
    q_floor = None

    def _in_window(lane, r):
        l = eng.lanes[lane]
        return (l.request is r and lane not in getattr(eng, "prefills", {})
                and r.n_tokens - len(l.generated) <= query_window)

    while any(l.request is not None for l in eng.lanes):
        # pre-step sample: the retire step clears the savings ledger with
        # the lane, so sampling before it keeps the gauge a residency
        # measure, not a teardown artifact
        if any(_in_window(lane, r) for lane, r in lane_of.items()):
            g = eng.kv_device_bytes
            q_floor = g if q_floor is None else min(q_floor, g)
        eng.step_once()
        steps += 1
        assert steps < 200 * n_gen, "needle benchmark stalled"
        for lane, r in lane_of.items():
            if not _in_window(lane, r):
                continue
            if paged:
                needle = 0                                  # global page id
            else:
                sp = eng._bucket(prompt_len, n_gen)         # left-pad offset
                needle = np.arange(page) + (sp - prompt_len)
            best[r.uid] = max(best[r.uid],
                              _needle_visibility(eng, lane, needle))
    snap = eng.stats.snapshot()
    stats = {"retrieval_acc": round(float(np.mean(list(best.values()))), 3),
             "peak_kv_bytes": int(eng.peak_kv_bytes),
             "kv_device_bytes_query_floor": int(q_floor or 0),
             "dma_bytes": int(snap["d2h_bytes"] + snap["h2d_bytes"]),
             "kv_quant": kv_quant}
    if paged:
        stats["thaws"] = eng.ctl.n_thaw
        stats["swaps"] = eng.ctl.n_swap_out + eng.ctl.n_swap_in
        stats["quantized_pages"] = eng.ctl.n_quantized_pages
    return stats


def run_needle_comparison(cfg, params, smoke: bool):
    """Four arms: contiguous + recovery (the reference), paged + recovery
    (must match it at lower peak KV), paged without recovery (the
    eviction-scheme contrast ROADMAP warns about), and paged + recovery
    with int8 page quantization (must hold the same retrieval accuracy at
    lower query-window device KV and lower DMA bytes — the guardrail
    ``tools/check_bench.py --quant`` enforces)."""
    out = {}
    for name, paged, recovery, kv_quant in (
            ("contiguous_recovery", False, True, "none"),
            ("paged_recovery", True, True, "none"),
            ("paged_no_recovery", True, False, "none"),
            ("paged_recovery_quant", True, True, "int8")):
        out[name] = run_needle(cfg, params, smoke, paged, recovery,
                               kv_quant=kv_quant)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the untimed compile pass (reports cold times)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced traces for the CI tier-2 smoke job")
    ap.add_argument("--skip-static", action="store_true",
                    help="only run the paged vs contiguous comparison")
    args = ap.parse_args()

    import jax
    from benchmarks.common import bench_config
    from repro.models import model as MD

    cfg = bench_config()
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    report = {}

    if not args.skip_static:
        if not args.no_warmup:   # compile both paths outside the timed runs
            run_static(cfg, params)
            run_continuous(cfg, params)
        static = run_static(cfg, params)
        cont = run_continuous(cfg, params)
        ratio = cont["tokens_per_s"] / max(static["tokens_per_s"], 1e-9)
        print(f"{'':>22s}  {'static':>10s}  {'continuous':>10s}")
        for k in ("wall_s", "tokens_per_s", "latency_p50_s", "latency_p99_s",
                  "jitted_steps", "utilization_pct"):
            print(f"{k:>22s}  {static[k]:>10}  {cont[k]:>10}")
        print(f"\nthroughput ratio (continuous / static): {ratio:.2f}x\n")
        report.update(trace=TRACE, n_lanes=N_LANES, static=static,
                      continuous=cont, throughput_ratio=round(ratio, 3))

    # ---- paged vs contiguous on the long-prompt Poisson trace ---- #
    c_stats, p_stats = run_paged_comparison(cfg, params, smoke=args.smoke,
                                            warmup=not args.no_warmup)
    print(f"{'long-prompt Poisson':>22s}  {'contiguous':>12s}  {'paged':>12s}")
    for k in ("wall_s", "tokens_per_s", "latency_p50_s", "latency_p99_s",
              "jitted_steps", "peak_kv_bytes", "swaps"):
        print(f"{k:>22s}  {c_stats[k]:>12}  {p_stats[k]:>12}")
    p99_win = p_stats["latency_p99_s"] < c_stats["latency_p99_s"]
    mem_win = p_stats["peak_kv_bytes"] < c_stats["peak_kv_bytes"]
    print(f"\npaged p99 win: {p99_win}   "
          f"paged peak-KV win: {mem_win} "
          f"({p_stats['peak_kv_bytes']} < {c_stats['peak_kv_bytes']} bytes)")
    report.update(long_trace_contiguous=c_stats, long_trace_paged=p_stats,
                  paged_p99_win=bool(p99_win), paged_mem_win=bool(mem_win))

    # ---- async DMA pipeline: sync vs async paged engine ---- #
    ab = run_async_comparison(cfg, params, smoke=args.smoke)
    print(f"\n{'async pipeline':>22s}  {'sync':>12s}  {'async':>12s}")
    for k in ("step_ms_mean", "step_ms_p50", "step_ms_p99",
              "host_blocked_fraction", "blocking_d2h", "blocking_h2d",
              "thaws", "thaw_remap", "thaw_upload", "n_retraces"):
        print(f"{k:>22s}  {ab['sync'][k]:>12}  {ab['async'][k]:>12}")
    print(f"\nasync token parity: {ab['token_parity']}   "
          f"host-blocked win: {ab['blocked_win']}   "
          f"mean-step win: {ab['latency_win']}   "
          f"thaw remap fraction: {ab['thaw_remap_fraction']}")
    report.update(async_vs_sync=ab)

    # ---- needle-in-haystack: recovery keeps frozen context retrievable ---- #
    needle = run_needle_comparison(cfg, params, smoke=args.smoke)
    print(f"\n{'needle retrieval':>22s}  "
          + "  ".join(f"{k:>22s}" for k in needle))
    for field in ("retrieval_acc", "peak_kv_bytes",
                  "kv_device_bytes_query_floor", "dma_bytes"):
        print(f"{field:>26s}  "
              + "  ".join(f"{needle[k][field]:>22}" for k in needle))
    acc_match = (needle["paged_recovery"]["retrieval_acc"]
                 >= needle["contiguous_recovery"]["retrieval_acc"])
    needle_mem_win = (needle["paged_recovery"]["peak_kv_bytes"]
                      < needle["contiguous_recovery"]["peak_kv_bytes"])
    print(f"\npaged+recovery matches contiguous retrieval: {acc_match}   "
          f"at lower peak KV: {needle_mem_win}   "
          f"(no-recovery contrast: "
          f"{needle['paged_no_recovery']['retrieval_acc']})")
    quant, base = needle["paged_recovery_quant"], needle["paged_recovery"]
    quant_kv_win = (quant["kv_device_bytes_query_floor"]
                    < base["kv_device_bytes_query_floor"])
    quant_dma_win = quant["dma_bytes"] < base["dma_bytes"]
    print(f"int8 arm: retrieval {quant['retrieval_acc']}   "
          f"query-window KV win: {quant_kv_win} "
          f"({quant['kv_device_bytes_query_floor']} < "
          f"{base['kv_device_bytes_query_floor']})   "
          f"DMA win: {quant_dma_win} "
          f"({quant['dma_bytes']} < {base['dma_bytes']})")
    report.update(needle=needle, needle_acc_match=bool(acc_match),
                  needle_mem_win=bool(needle_mem_win),
                  quant_kv_win=bool(quant_kv_win),
                  quant_dma_win=bool(quant_dma_win))

    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "continuous_batching.json").write_text(
        json.dumps(report, indent=2))
    # machine-readable summary at the repo root (CI tier-2 asserts on it)
    bench = {
        "step_latency_ms": {
            arm: {k: ab[arm][f"step_ms_{k}"] for k in ("mean", "p50", "p99")}
            for arm in ("sync", "async")},
        "host_blocked_fraction": {
            arm: ab[arm]["host_blocked_fraction"]
            for arm in ("sync", "async")},
        "peak_device_kv_bytes": {
            "contiguous": c_stats["peak_kv_bytes"],
            "paged": p_stats["peak_kv_bytes"],
            "paged_async_arm": ab["async"]["peak_kv_bytes"]},
        "token_parity": ab["token_parity"],
        "blocked_win": ab["blocked_win"],
        "latency_win": ab["latency_win"],
        "thaws": ab["async"]["thaws"],
        "thaw_remap_fraction": ab["thaw_remap_fraction"],
        # steady-state jit compile-cache growth over the timed repeats
        # (repro.analysis.trace_guard; CI asserts --max-retraces 0)
        "n_retraces": {arm: ab[arm]["n_retraces"]
                       for arm in ("sync", "async")},
        # total blocking host<->device transfers per arm: the async
        # pipeline must not regress toward per-step blocking pulls
        "blocking_transfers": {
            arm: ab[arm]["blocking_d2h"] + ab[arm]["blocking_h2d"]
            for arm in ("sync", "async")},
        # quantized-KV guardrail (tools/check_bench.py --quant): the int8
        # needle arm must hold full retrieval while cutting BOTH the
        # query-window device-KV gauge and total DMA bytes vs the
        # unquantized paged+recovery arm
        "quant": {
            "retrieval_acc": needle["paged_recovery_quant"]["retrieval_acc"],
            "baseline_retrieval_acc": needle["paged_recovery"][
                "retrieval_acc"],
            "kv_device_bytes_query_floor": {
                arm: needle[arm]["kv_device_bytes_query_floor"]
                for arm in ("paged_recovery", "paged_recovery_quant")},
            "dma_bytes": {
                arm: needle[arm]["dma_bytes"]
                for arm in ("paged_recovery", "paged_recovery_quant")},
            "quantized_pages": needle["paged_recovery_quant"][
                "quantized_pages"],
        },
    }
    (pathlib.Path(__file__).resolve().parents[1]
     / "BENCH_continuous_batching.json").write_text(
        json.dumps(bench, indent=2))


if __name__ == "__main__":
    main()

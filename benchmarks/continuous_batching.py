"""Continuous vs static batching on a mixed-length request trace.

The static FIFO batcher runs every batch for max(n_tokens) steps, so short
requests pay for the longest co-batched one (head-of-line blocking); the
continuous engine retires a lane and admits the next request mid-stream.
This benchmark serves the same trace through both paths and reports
throughput (generated tokens / s), per-request latency (p50 / p99 from
trace start to completion) and jitted-step counts — the deterministic
utilization measure that doesn't depend on host speed.

    PYTHONPATH=src python -m benchmarks.continuous_batching
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"

# mixed-length trace from the acceptance criteria: 8 requests, n_tokens
# spanning 8..64, served on 4 lanes
TRACE = [64, 8, 8, 8, 32, 16, 8, 8]
N_LANES = 4
MAX_SEQ = 160


def make_requests(cfg, seed=0):
    from repro.serving.sampling import SamplingParams
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, size=16), n,
             SamplingParams(temperature=0.7)) for n in TRACE]


def run_static(cfg, params):
    from repro.serving.engine import Engine
    from repro.serving.scheduler import StaticScheduler

    eng = Engine(cfg, params, max_seq=MAX_SEQ)
    sched = StaticScheduler(eng, batch_size=N_LANES)
    for prompt, n, sp in make_requests(cfg):
        sched.submit(prompt, n, sp)
    t0 = time.time()
    latencies = []
    while sched.queue:
        uids = sched.run_once()
        now = time.time() - t0
        latencies += [now] * len(uids)
    # every batch runs max(n_tokens) - 1 decode steps after its prefill
    steps = sum(max(TRACE[i:i + N_LANES]) - 1
                for i in range(0, len(TRACE), N_LANES))
    return _stats(time.time() - t0, latencies, steps)


def run_continuous(cfg, params):
    from repro.serving.engine import ContinuousEngine
    from repro.serving.scheduler import Scheduler

    eng = ContinuousEngine(cfg, params, max_seq=MAX_SEQ, n_lanes=N_LANES)
    sched = Scheduler(eng)
    for prompt, n, sp in make_requests(cfg):
        sched.submit(prompt, n, sp)
    t0 = time.time()
    latencies = []
    while sched.queue or sched.engine.n_active_lanes:
        uids = sched.run_once()
        if not uids:
            break
        now = time.time() - t0
        latencies += [now] * len(uids)
    return _stats(time.time() - t0, latencies, eng.wall_step)


def _stats(wall_s, latencies, steps):
    total_tokens = sum(TRACE)
    # each request's first token comes from its prefill, so only
    # n_tokens - 1 of its tokens occupy decode lane-steps
    decode_tokens = total_tokens - len(TRACE)
    return {
        "wall_s": round(wall_s, 2),
        "tokens_per_s": round(total_tokens / max(wall_s, 1e-9), 1),
        "latency_p50_s": round(float(np.percentile(latencies, 50)), 2),
        "latency_p99_s": round(float(np.percentile(latencies, 99)), 2),
        "jitted_steps": steps,
        "lane_steps": steps * N_LANES,
        "useful_tokens": total_tokens,
        "utilization_pct": round(100 * decode_tokens / (steps * N_LANES), 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the untimed compile pass (reports cold times)")
    args = ap.parse_args()

    import jax
    from benchmarks.common import bench_config
    from repro.models import model as MD

    cfg = bench_config()
    params = MD.init_params(jax.random.PRNGKey(0), cfg)

    if not args.no_warmup:   # compile both paths outside the timed runs
        run_static(cfg, params)
        run_continuous(cfg, params)

    static = run_static(cfg, params)
    cont = run_continuous(cfg, params)
    ratio = cont["tokens_per_s"] / max(static["tokens_per_s"], 1e-9)

    print(f"{'':>22s}  {'static':>10s}  {'continuous':>10s}")
    for k in ("wall_s", "tokens_per_s", "latency_p50_s", "latency_p99_s",
              "jitted_steps", "utilization_pct"):
        print(f"{k:>22s}  {static[k]:>10}  {cont[k]:>10}")
    print(f"\nthroughput ratio (continuous / static): {ratio:.2f}x")

    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "continuous_batching.json").write_text(json.dumps(
        {"trace": TRACE, "n_lanes": N_LANES, "static": static,
         "continuous": cont, "throughput_ratio": round(ratio, 3)}, indent=2))


if __name__ == "__main__":
    main()

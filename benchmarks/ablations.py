"""Ablations beyond the paper's tables.

1. `length_scaling`  — paper §5.2 hypothesis: "compression improves with
   context length ... for truly long contexts ASR-KF-EGR could achieve 80%+".
   We measure steady-state compression at 125 / 250 / 500 / 1000 generated
   tokens under identical settings.
2. `tau_sensitivity` — paper §6 limitation: threshold sensitivity.  Sweeps
   the adaptive-quantile target (beyond-paper mode) and the fixed-tau mode,
   reporting compression + greedy-parity against the full-KV baseline.

    PYTHONPATH=src:. python -m benchmarks.ablations
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"


def length_scaling():
    from benchmarks.common import bench_config, random_params
    from repro.serving.engine import Engine
    from repro.serving.sampling import SamplingParams

    cfg = bench_config()
    params = random_params(cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 14), 0,
                                cfg.vocab_size)
    rows = []
    eng = Engine(cfg, params, max_seq=1100)
    for n in (125, 250, 500, 1000):
        res = eng.generate({"tokens": jnp.asarray(prompt)}, n,
                           SamplingParams(temperature=0.7), seed=n)
        rows.append({"tokens": n,
                     "compression_pct": round(100 * res.compression, 2),
                     "final_active": res.active_kv[-1]})
        print(f"  len={n:5d}  compression={rows[-1]['compression_pct']:6.2f}%"
              f"  active={rows[-1]['final_active']:.0f}", flush=True)
    mono = all(rows[i]["compression_pct"] <= rows[i + 1]["compression_pct"] + 3
               for i in range(len(rows) - 1))
    print(f"  §5.2 'compression grows with length': "
          f"{'SUPPORTED' if mono else 'NOT SUPPORTED'} "
          f"({rows[0]['compression_pct']}% -> {rows[-1]['compression_pct']}%)")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "ablation_length_scaling.json").write_text(json.dumps(
        {"rows": rows, "monotone": mono,
         "paper": "67% @500; hypothesizes 80%+ for 8k+"}, indent=2))


def tau_sensitivity():
    from benchmarks.common import bench_config, induction_trained_params
    from repro.serving.engine import Engine
    from repro.serving.sampling import SamplingParams

    cfg0 = bench_config(trained_vocab=True)
    params = induction_trained_params(cfg0)
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 48), 0,
                                cfg0.vocab_size)
    base_eng = Engine(cfg0, params, max_seq=300, enable_freeze=False)
    base = base_eng.generate({"tokens": jnp.asarray(prompt)}, 150,
                             SamplingParams.greedy())
    rows = []
    for mode, val in [("quantile", 0.25), ("quantile", 0.45),
                      ("quantile", 0.65), ("fixed", 0.5), ("fixed", 2.0)]:
        fc = dataclasses.replace(cfg0.freeze, tau_mode=mode,
                                 quantile=val if mode == "quantile" else 0.35,
                                 tau=val if mode == "fixed" else 0.5)
        cfg = dataclasses.replace(cfg0, freeze=fc)
        eng = Engine(cfg, params, max_seq=300)
        res = eng.generate({"tokens": jnp.asarray(prompt)}, 150,
                           SamplingParams.greedy())
        agree = float(np.mean(res.tokens == base.tokens))
        rows.append({"mode": mode, "value": val,
                     "compression_pct": round(100 * res.compression, 2),
                     "greedy_agreement": round(agree, 3)})
        print(f"  {mode}={val:<5}: compression="
              f"{rows[-1]['compression_pct']:6.2f}%  parity={agree:.3f}",
              flush=True)
    (OUT / "ablation_tau_sensitivity.json").write_text(
        json.dumps({"rows": rows}, indent=2))


def main():
    print("ablation: length_scaling (paper §5.2)")
    length_scaling()
    print("ablation: tau_sensitivity (paper §6)")
    tau_sensitivity()


if __name__ == "__main__":
    main()

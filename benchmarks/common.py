"""Shared benchmark utilities: the evaluation model + cached training.

The paper evaluates on a trained LLaMA-3 8B; this container has no weights
and no GPU, so every benchmark runs BOTH arms (full-KV baseline vs
ASR-KF-EGR) on the same reduced llama3-family model under identical
sampling, reporting the paper's metrics (compression, retrieval, parity).
For Table 2 the model is first trained on induction-structured data until it
can do copy-retrieval (cached across runs in experiments/).
"""
from __future__ import annotations

import dataclasses
import pathlib

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.training import checkpoint as CKPT
from repro.training import data as DATA
from repro.training import train_step as TS

CACHE = pathlib.Path(__file__).resolve().parents[1] / "experiments"


def bench_config(trained_vocab: bool = False) -> ModelConfig:
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(
        cfg.freeze, window=16, tau_mode="quantile", quantile=0.45,
        k_soft=1.0, page_size=16, recovery_enabled=True,
        entropy_abs_threshold=1e9)
    cfg = dataclasses.replace(cfg, freeze=fc)
    if trained_vocab:
        # small vocab so induction training converges quickly on CPU
        cfg = dataclasses.replace(cfg, vocab_size=128, dtype="float32",
                                  num_layers=2, d_model=256, num_heads=4,
                                  num_kv_heads=2, head_dim=64, d_ff=512)
    return cfg


def random_params(cfg: ModelConfig, seed: int = 0):
    from repro.models import model as MD
    return MD.init_params(jax.random.PRNGKey(seed), cfg)


def induction_trained_params(cfg: ModelConfig, steps: int = 300,
                             seed: int = 0):
    """Train (or load cached) a small induction-capable model."""
    path = CACHE / f"bench_model_v{cfg.vocab_size}_{steps}.msgpack"
    state = TS.init_train_state(jax.random.PRNGKey(seed), cfg)
    if path.exists():
        try:
            return CKPT.restore(str(path), state.params)
        except Exception:
            pass
    it = DATA.synthetic_lm(DATA.DataConfig(cfg.vocab_size, 256, 8, seed=1,
                                           induction_prob=1.0))
    step_fn = jax.jit(lambda s, b, lr: TS.train_step(s, b, cfg, lr=lr))
    for i in range(steps):
        lr = 3e-3 * min(1.0, (i + 1) / 100) * (0.5 ** (i // 400))
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step_fn(state, batch, jnp.float32(lr))
    CKPT.save(str(path), state.params)
    return state.params


def copy_accuracy(params, cfg, n=4, seq=256) -> float:
    """How well the model predicts the second occurrence of planted spans —
    a direct measure of retrieval capability."""
    from repro.models import model as MD
    it = DATA.synthetic_lm(DATA.DataConfig(cfg.vocab_size, seq, n, seed=9,
                                           induction_prob=1.0))
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    logits, _ = MD.train_logits(params, cfg, batch, remat=False)
    pred = jnp.argmax(logits[:, :-1], -1)
    tgt = batch["tokens"][:, 1:]
    # score only the copied second half
    half = seq // 2
    return float(jnp.mean((pred[:, half:] == tgt[:, half:])))

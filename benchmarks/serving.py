"""Multi-tenant streaming-server benchmark: weighted fair sharing, hog
containment, mid-stream disconnects, streaming parity.

One seeded workload drives the ``AsyncServingEngine`` facade (in-process
— the HTTP layer is byte-plumbing tested in tests/test_server.py; the
scheduling behaviour under test lives below it):

* **hog** (weight 1) — a burst of long generations submitted all at
  once at t=0: the open-loop flood that would monopolize every lane
  under plain FIFO admission.
* **gold** (weight 3) / **silver** (weight 1) — closed-loop interactive
  tenants, each keeping a couple of requests in flight; gold traffic
  carries mixed SLO deadlines (EDF within the shared priority class),
  and every third gold request *disconnects mid-stream* after a few
  tokens — the client-goes-away path (freeze-native suspend + drop).

All three tenants stay backlogged until a global committed-token target
is reached, then outstanding work is cancelled — so the measured window
is fully saturated and each tenant's goodput share is WFQ's to
determine.  **Fairness acceptance** (gated by ``check_bench
--serving``): every tenant's goodput share stays within
[0.5x, 1.5x] of its weight share — the hog's 1/5 entitlement contains
it, and gold's 3/5 holds despite the flood.  Also gated: zero unhandled
server exceptions, disconnects actually happened and freed their lanes
(no KV leak — ``audit_controller`` runs clean after the drain), and the
**streaming parity** invariant: the designated probe request's streamed
token sequence is identical to the same request served through the
batch ``Scheduler`` path (``launch/serve.py``'s) on the same engine —
greedy + f32 + ``burst_prefill=False``, the repo's parity methodology.

    PYTHONPATH=src python -m benchmarks.serving           # full
    PYTHONPATH=src python -m benchmarks.serving --smoke   # CI tier-2
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import pathlib
import time
from typing import Dict, List

import numpy as np

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"

WEIGHTS = {"gold": 3.0, "silver": 1.0, "hog": 1.0}
FAIRNESS_LO, FAIRNESS_HI = 0.5, 1.5
PROMPT_LEN = 12
N_LANES = 3


def serving_config(cfg):
    """f32 + steady freeze pressure, recovery off: fairness and parity
    must come from scheduling, not entropy spikes (same rationale as
    benchmarks/scheduling.py)."""
    fc = dataclasses.replace(cfg.freeze, page_size=16, window=16,
                             tau_mode="quantile", quantile=0.5, k_soft=1.0,
                             recovery_enabled=False)
    return dataclasses.replace(cfg, freeze=fc, dtype="float32")


async def _gold_worker(ae, wid, rng, cfg, stop, tally, probe_ref):
    """Interactive tenant with a pipeline of 2 requests in flight — gold
    is entitled to the majority weight share, so it must stay backlogged
    deep enough to actually consume it (a WFQ server is work-conserving:
    an under-backlogged tenant's slack flows to the others, which would
    read as unfairness when it is really idleness).  Mixed deadlines;
    every third request *disconnects* after 3 streamed tokens.  Worker
    0's first request is the streaming-parity probe (never
    disconnected)."""
    from repro.serving.sampling import SamplingParams
    i = 0

    async def _submit():
        nonlocal i
        probe = wid == 0 and i == 0
        prompt = probe_ref["prompt"] if probe else \
            rng.randint(0, cfg.vocab_size, size=PROMPT_LEN)
        n_tok = probe_ref["n_tokens"] if probe else int(rng.choice([16, 24]))
        deadline = None if probe or i % 2 else float(rng.choice([400, 800]))
        stream = await ae.submit(prompt, n_tok, SamplingParams.greedy(),
                                 deadline_ms=deadline, tenant="gold")
        disconnect = not probe and i % 3 == 2
        i += 1
        return stream, probe, disconnect

    async def _consume(stream, probe, disconnect):
        if disconnect:
            got = 0
            async for ev in stream:
                if ev["event"] == "token":
                    got += 1
                    if got == 3:
                        await ae.cancel(stream.uid)
                elif ev["event"] == "done":
                    tally["disconnected"] += ev["status"] == "cancelled"
                    break
        else:
            ev = await stream.collect()
            tally["stream_parity_ok"] &= ev["streamed"] == ev["tokens"]
            if probe:
                probe_ref["streamed"] = ev["streamed"]

    inflight = [await _submit(), await _submit()]
    while not stop.is_set():
        await _consume(*inflight.pop(0))
        inflight.append(await _submit())
    for entry in inflight:
        await ae.cancel(entry[0].uid)
        await _consume(*entry)


async def _silver_worker(ae, rng, cfg, stop, tally):
    from repro.serving.sampling import SamplingParams
    while not stop.is_set():
        prompt = rng.randint(0, cfg.vocab_size, size=PROMPT_LEN)
        stream = await ae.submit(prompt, int(rng.choice([16, 20])),
                                 SamplingParams.greedy(), tenant="silver")
        ev = await stream.collect()
        tally["stream_parity_ok"] &= ev["streamed"] == ev["tokens"]


async def _hog_burst(ae, rng, cfg, stop, tally, n_requests, n_tok):
    """The flood: everything submitted up front, consumed concurrently;
    whatever is still live when the target is reached gets cancelled
    (the bench is over — drain would measure an unsaturated tail)."""
    from repro.serving.sampling import SamplingParams
    streams = []
    for _ in range(n_requests):
        prompt = rng.randint(0, cfg.vocab_size, size=PROMPT_LEN)
        streams.append(await ae.submit(prompt, n_tok,
                                       SamplingParams.greedy(),
                                       tenant="hog"))

    async def consume(stream):
        ev = await stream.collect()
        if ev["status"] == "completed":
            tally["stream_parity_ok"] &= ev["streamed"] == ev["tokens"]
    tasks = [asyncio.ensure_future(consume(s)) for s in streams]
    await stop.wait()
    for s in streams:
        await ae.cancel(s.uid)
    await asyncio.gather(*tasks)


async def _controller(ae, stop, target_tokens, window):
    """Set ``stop`` once total committed tokens reach the target, and
    capture the tenancy stats AT that instant — the fairness shares are
    measured over the fully-saturated window only, not the drain tail
    (where tenants stop being backlogged and WFQ owes them nothing)."""
    while not stop.is_set():
        st = await ae.stats()
        total = sum(t["goodput_tokens"]
                    for t in st.get("tenants", {}).values())
        if total >= target_tokens:
            window["stats"] = st
            stop.set()
            return
        await asyncio.sleep(0.05)


async def run_serving(eng, target_tokens, hog_requests, hog_tok, cfg,
                      probe_ref) -> Dict:
    from repro.serving.scheduler import Scheduler
    from repro.serving.server import AsyncServingEngine
    from repro.serving.tenancy import TenancyController, TenantConfig
    tenancy = TenancyController(
        [TenantConfig(n, weight=w) for n, w in WEIGHTS.items()])
    sched = Scheduler(eng, tenancy=tenancy)
    ae = AsyncServingEngine(sched, stream_capacity=16)
    await ae.start()
    stop = asyncio.Event()
    tally = {"disconnected": 0, "stream_parity_ok": True}
    window: Dict = {}
    rngs = {k: np.random.RandomState(i)
            for i, k in enumerate(["g0", "g1", "s0", "s1", "hog"])}
    t0 = time.monotonic()
    await asyncio.gather(
        _controller(ae, stop, target_tokens, window),
        _gold_worker(ae, 0, rngs["g0"], cfg, stop, tally, probe_ref),
        _gold_worker(ae, 1, rngs["g1"], cfg, stop, tally, probe_ref),
        _silver_worker(ae, rngs["s0"], cfg, stop, tally),
        _silver_worker(ae, rngs["s1"], cfg, stop, tally),
        _hog_burst(ae, rngs["hog"], cfg, stop, tally, hog_requests,
                   hog_tok),
    )
    wall = time.monotonic() - t0
    stats = await ae.stats()
    stats["tenants_at_stop"] = window["stats"]["tenants"]
    await ae.close()
    # post-drain invariants: no lane still owned, no stranded scheduler
    # entry (every submitted uid reached `done`), stash store consistent
    lanes_leaked = sum(l.request is not None for l in eng.lanes)
    stranded = len(sched.metrics) - len(sched.done)
    hits = [m["deadline_hit"] for m in sched.metrics.values()
            if m["deadline_hit"] is not None]
    from repro.analysis.invariants import audit_controller
    audit_ok = True
    try:
        audit_controller(eng.ctl)
    except AssertionError:
        audit_ok = False
    return {
        "wall_s": round(wall, 2),
        "stats": stats,
        "tally": tally,
        "lanes_leaked": lanes_leaked,
        "stranded_entries": stranded,
        "audit_clean": audit_ok,
        "deadline_hit_rate": round(sum(hits) / len(hits), 3)
        if hits else None,
        "n_deadlined": len(hits),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workload for the CI tier-2 smoke job")
    args = ap.parse_args()

    import jax
    from benchmarks.common import bench_config
    from repro.models import model as MD
    from repro.serving.config import ServingConfig
    from repro.serving.engine import PagedContinuousEngine
    from repro.serving.sampling import SamplingParams
    from repro.serving.scheduler import Scheduler

    cfg = serving_config(bench_config())
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    sv = ServingConfig(max_seq=256, n_lanes=N_LANES, max_active_pages=4,
                       prefill_chunk=16,
                       # deterministic chunk split: the parity probe's
                       # reference interleaves admissions differently
                       burst_prefill=False)
    eng = PagedContinuousEngine(cfg, params, serving=sv)

    target, hog_requests, hog_tok = (240, 24, 24) if args.smoke \
        else (700, 48, 32)

    # ---- parity probe reference: the SAME request through the batch
    # Scheduler path (what launch/serve.py drives), on the SAME engine
    # (fresh lanes after run(); greedy trajectories are per-lane pure,
    # and reusing the engine reuses its jit caches as warmup) ---- #
    rng = np.random.RandomState(1234)
    probe_ref = {"prompt": rng.randint(0, cfg.vocab_size, size=PROMPT_LEN),
                 "n_tokens": 20, "streamed": None}
    s0 = Scheduler(eng)
    uid = s0.submit(probe_ref["prompt"], probe_ref["n_tokens"],
                    SamplingParams.greedy())
    s0.run()
    probe_ref["batch_tokens"] = [int(t) for t in s0.done[uid].result]

    report = asyncio.run(run_serving(eng, target, hog_requests, hog_tok,
                                     cfg, probe_ref))

    parity_ok = probe_ref["streamed"] == probe_ref["batch_tokens"]
    # fairness over the saturated window (tenancy stats captured the
    # instant the token target was reached); final post-drain stats are
    # still reported for the counters
    tenants = report["stats"]["tenants_at_stop"]
    total_goodput = sum(t["goodput_tokens"] for t in tenants.values())
    wsum = sum(WEIGHTS.values())
    fairness = {}
    fairness_ok = True
    for name, w in WEIGHTS.items():
        share = tenants[name]["goodput_tokens"] / max(total_goodput, 1)
        ratio = share / (w / wsum)
        ok = FAIRNESS_LO <= ratio <= FAIRNESS_HI
        fairness_ok &= ok
        fairness[name] = {"weight": w, "goodput_tokens":
                          tenants[name]["goodput_tokens"],
                          "share": round(share, 3),
                          "weight_share": round(w / wsum, 3),
                          "ratio": round(ratio, 3), "ok": ok}

    print(f"\n{'tenant':>8s} {'weight':>7s} {'goodput':>8s} {'share':>7s}"
          f" {'ratio':>6s}")
    for name, f in fairness.items():
        print(f"{name:>8s} {f['weight']:>7.1f} {f['goodput_tokens']:>8d}"
              f" {f['share']:>7.3f} {f['ratio']:>6.3f}")
    print(f"\nfairness ok (each ratio in [{FAIRNESS_LO}, {FAIRNESS_HI}]): "
          f"{fairness_ok}")
    print(f"disconnects: {report['tally']['disconnected']}  "
          f"cancelled total: {report['stats']['n_cancelled']}  "
          f"paused/resumed: {report['stats']['n_paused']}/"
          f"{report['stats']['n_resumed']}")
    print(f"streaming parity vs batch path: {parity_ok}  "
          f"per-stream replay parity: {report['tally']['stream_parity_ok']}")
    print(f"lanes leaked: {report['lanes_leaked']}  stranded entries: "
          f"{report['stranded_entries']}  audit clean: "
          f"{report['audit_clean']}  unhandled exceptions: "
          f"{report['stats']['unhandled_exceptions']}")
    if report["deadline_hit_rate"] is not None:
        print(f"deadline hit rate: {report['deadline_hit_rate']:.0%} "
              f"({report['n_deadlined']} deadlined requests)")

    full = {
        "target_tokens": target,
        "n_lanes": N_LANES,
        "weights": WEIGHTS,
        "fairness_bounds": [FAIRNESS_LO, FAIRNESS_HI],
        "fairness": fairness,
        "fairness_ok": bool(fairness_ok),
        "streaming_parity_ok": bool(parity_ok),
        "stream_replay_parity_ok": bool(report["tally"]
                                        ["stream_parity_ok"]),
        "disconnected_mid_stream": int(report["tally"]["disconnected"]),
        "deadline_hit_rate": report["deadline_hit_rate"],
        "n_deadlined": report["n_deadlined"],
        "wall_s": report["wall_s"],
        "lanes_leaked": report["lanes_leaked"],
        "stranded_entries": report["stranded_entries"],
        "audit_clean": report["audit_clean"],
        "server": {k: report["stats"][k] for k in
                   ("n_preemptions", "n_preempt_skipped_cost",
                    "n_cancelled", "n_paused", "n_resumed",
                    "unhandled_exceptions", "preempt_cost_s")},
        "tenants": tenants,
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "serving.json").write_text(json.dumps(full, indent=2))
    bench = {k: full[k] for k in
             ("fairness_ok", "fairness", "streaming_parity_ok",
              "stream_replay_parity_ok", "disconnected_mid_stream",
              "deadline_hit_rate", "lanes_leaked", "stranded_entries",
              "audit_clean")}
    bench["unhandled_exceptions"] = \
        report["stats"]["unhandled_exceptions"]
    bench["n_cancelled"] = report["stats"]["n_cancelled"]
    bench["goodput_per_tenant"] = {
        n: tenants[n]["goodput_tokens"] for n in WEIGHTS}
    (pathlib.Path(__file__).resolve().parents[1]
     / "BENCH_serving.json").write_text(json.dumps(bench, indent=2))


if __name__ == "__main__":
    main()

"""Mixed-SLO scheduling benchmark: preemptive SLO scheduler vs FIFO.

One trace, two scheduling arms over the SAME paged engine:

* **Workload** — a batch of *background* requests (priority 5, long
  generations, no deadline) saturates every lane from t=0, then
  *interactive foreground* requests (priority 0, short generations, tight
  per-request deadline) arrive while the lanes are busy.  This is the
  starvation case the ISSUE names: under FIFO a burst of low-value long
  generations head-of-line-blocks latency-critical requests even though
  the freeze/stash machinery makes suspending a lane nearly free.

* **Arms** — ``policy="fifo"`` (pure submission order, no preemption: the
  pre-PR-5 scheduler) vs ``policy="slo"`` (strict priority classes, EDF
  within a class, freeze-native lane preemption: a background victim's
  device residency force-stashes to the host store and later resumes via
  the thaw/remap path, token-identically).

* **Metrics** — foreground arrival→completion latency p50/p99 and
  deadline-hit-rate, total token throughput, preemption count, and a
  token-parity audit: every preempted request's final tokens are compared
  against an uninterrupted run of the same request on an idle engine
  (greedy + f32 + ``burst_prefill=False`` — the repo's parity
  methodology; a lane's trajectory on the paged engine is a pure function
  of its own request, so the reference is exact, not statistical).

Foreground deadlines are calibrated from the measured per-step wall time
(``DEADLINE_STEPS`` engine steps' worth), so the pass/fail structure is
machine-speed independent: FIFO misses because waiting for a background
lane costs ~`bg n_tokens` steps, not because the host is slow.

Acceptance (asserted by ``tools/check_bench.py`` in CI tier-2): the SLO
arm strictly beats FIFO on foreground deadline-hit-rate and foreground
p99, at equal-or-better total throughput, with every preempt-resumed
request token-identical to its uninterrupted run.  The throughput check
is **steady-state tokens per jitted step** (packing efficiency while
queued work remains to backfill freed lanes — what preemption could
actually degrade, by leaving lane-slots unpaired; the post-last-
admission drain tail is excluded, see ``drive``) plus a bound on the
blocking-transfer time preemption adds; raw wall-clock tokens/s is
reported but not asserted, because shared CI boxes swing it +-20% with
noisy neighbors — far beyond the few-ms effect under test.

    PYTHONPATH=src python -m benchmarks.scheduling           # full
    PYTHONPATH=src python -m benchmarks.scheduling --smoke   # CI tier-2
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time
from typing import Dict

import numpy as np

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"

# foreground deadline, in calibrated engine-steps: comfortably above the
# foreground's own service time (~prefill chunks + n_tokens steps), far
# below a background generation's remaining length
DEADLINE_STEPS = 26
# throughput tolerance for the "preemption costs ~nothing" check.  The
# check runs on *tokens per jitted step* (packing efficiency — exactly
# what preemption could degrade by leaving lane-slots unpaired), plus a
# bound on the blocking-transfer time preemption adds, because raw
# wall-clock tokens/s on shared CI boxes swings +-20% with neighbors —
# far beyond any real effect being measured.  Wall tokens/s is still
# reported for humans.
TPUT_TOLERANCE = 0.95
# preemption's blocking transfers (suspend pull + resume push, ~ms each)
# may add at most this fraction of the arm's wall time
BLOCKED_OVERHEAD_FRAC = 0.05


def sched_config(cfg):
    """Freeze pressure on (pages stash steadily, so preemption victims
    carry a real host-store population) with recovery off — the arms'
    timing differences must come from scheduling, not entropy spikes."""
    fc = dataclasses.replace(cfg.freeze, page_size=16, window=16,
                             tau_mode="quantile", quantile=0.5, k_soft=1.0,
                             recovery_enabled=False)
    return dataclasses.replace(cfg, freeze=fc, dtype="float32")


def make_trace(cfg, smoke: bool, step_s: float):
    """(arrival_s, submit-kwargs, role) tuples.  Background floods at t=0;
    foregrounds arrive spread over the first ~60% of the run, while every
    lane is still busy.

    The background batch is many *moderate, mixed-length* generations
    rather than a few huge uniform ones, for two reasons.  (1) With a
    shared queue and job quantum well below a lane's total work, the
    lanes rebalance after every preemption — a lane that lent time to a
    foreground simply takes fewer queued jobs — so the preemptive arm's
    makespan matches FIFO's instead of paying a phase-shift tail.
    (2) Uniform lengths make the FIFO baseline unrealistically perfect:
    lanes admitted together retire together forever, so every prefill
    lands in a decode-free call and no lane-slot is ever unpaired — a
    phase-lock no production trace exhibits and any reordering breaks.
    Mixed lengths de-phase both arms equally, leaving preemption's real
    cost (two pool-slice transfers per preemption) as the only
    difference."""
    from repro.serving.sampling import SamplingParams
    rng = np.random.RandomState(11)
    # the smoke trace still needs enough background volume that one
    # preemption's fixed cost (two pool-slice transfers) is amortized —
    # a sub-second trace reads a single suspend as a throughput cliff
    # enough moderate jobs that the shared queue can always rebalance a
    # preemption's phase shift (fewer jobs -> the tail realigns on a
    # half-job quantum and the packing ratio jitters)
    n_bg, bg_lo, bg_hi = (12, 12, 26) if smoke else (12, 16, 33)
    hog_tok = 48 if smoke else 64
    n_fg, fg_tok = (3, 6) if smoke else (6, 8)
    greedy = SamplingParams.greedy()
    trace = []
    # two "hog" generations submitted first: they take both lanes at t=0
    # and are still far from done when the first foreground arrives, so
    # the first preemption is a structural property of the trace, not a
    # coin-flip of the miss predictor against job phases (CI asserts
    # preemptions > 0 — and the warmup pass, which runs this same smoke
    # trace, compiles the suspend/resume path before anything is timed)
    for _ in range(2):
        trace.append((0.0, dict(
            prompt=rng.randint(0, cfg.vocab_size, size=24),
            n_tokens=hog_tok, sampling=greedy, priority=5), "bg"))
    bg_total = 2 * hog_tok
    for _ in range(n_bg):
        n = int(rng.randint(bg_lo, bg_hi))
        bg_total += n
        trace.append((0.0, dict(
            prompt=rng.randint(0, cfg.vocab_size, size=24),
            n_tokens=n, sampling=greedy, priority=5), "bg"))
    # spread the foregrounds across the background-dominated span (2
    # lanes); the first lands early, while both hogs are mid-generation
    gap = 0.6 * (bg_total / 2) * step_s / max(n_fg, 1)
    for i in range(n_fg):
        trace.append(((i + 0.35) * gap, dict(
            prompt=rng.randint(0, cfg.vocab_size, size=12),
            n_tokens=fg_tok, sampling=greedy, priority=0,
            deadline_ms=1e3 * DEADLINE_STEPS * step_s), "fg"))
    return trace


def drive(sched, trace):
    """Run timed arrivals through a scheduler; returns per-role uid lists,
    the wall time (idle gaps before the first pending arrival are
    fast-forwarded, as in benchmarks/continuous_batching.serve_poisson),
    per-call latencies, and the steady-state marker: (engine wall_step,
    tokens committed) at the moment the last pending request has been
    submitted and the queue is empty — i.e. where the *drain tail*
    begins.  Packing is asserted over the steady window only: once no
    queued work remains to backfill a freed lane, the final imbalance is
    bounded by one indivisible job for ANY non-clairvoyant scheduler, and
    which scheduler eats it is arrival-phase luck, not policy quality."""
    pending = sorted(trace, key=lambda t: t[0])
    roles = {"bg": [], "fg": []}
    t0 = time.monotonic()
    step_lat = []
    steady = None
    while pending or sched.queue or sched.busy:
        now = time.monotonic() - t0
        if not sched.queue and not sched.busy \
                and pending and pending[0][0] > now:
            t0 -= pending[0][0] - now
            now = pending[0][0]
        while pending and pending[0][0] <= now:
            _, kw, role = pending.pop(0)
            roles[role].append(sched.submit(**kw))
        if steady is None and not pending and not sched.queue:
            done_toks = sum(len(r.result) for r in sched.done.values()) \
                + sum(len(l.generated) for l in sched.engine.lanes
                      if l.request is not None)
            steady = (sched.engine.wall_step, done_toks)
        ts = time.perf_counter()
        sched.step()
        step_lat.append(time.perf_counter() - ts)
    return roles, time.monotonic() - t0, step_lat, steady


def arm_stats(sched, roles, wall, trace, steps, blocked_s, steady):
    m = sched.metrics
    fg_lat = [m[u]["finish_t"] - m[u]["arrival_t"] for u in roles["fg"]]
    hits = [m[u]["deadline_hit"] for u in roles["fg"]]
    total_tokens = sum(kw["n_tokens"] for _, kw, _ in trace)
    ss_steps, ss_tokens = steady
    return {
        "wall_s": round(wall, 2),
        "tokens_per_s": round(total_tokens / max(wall, 1e-9), 1),
        "jitted_steps": steps,
        "tokens_per_step": round(total_tokens / max(steps, 1), 3),
        "steady_tokens_per_step": round(ss_tokens / max(ss_steps, 1), 3),
        "blocked_s": round(blocked_s, 4),
        "fg_latency_p50_s": round(float(np.percentile(fg_lat, 50)), 3),
        "fg_latency_p99_s": round(float(np.percentile(fg_lat, 99)), 3),
        "fg_deadline_hit_rate": round(sum(hits) / len(hits), 3),
        "preemptions": sched.n_preemptions,
    }


def run_arm(eng, policy, trace):
    from repro.serving.scheduler import Scheduler
    sched = Scheduler(eng, policy=policy)
    w0, b0 = eng.wall_step, eng.stats.blocked_s
    roles, wall, step_lat, steady = drive(sched, trace)
    steps = eng.wall_step - w0
    blocked = eng.stats.blocked_s - b0
    ss = (steady[0] - w0, steady[1]) if steady else (steps, 0)
    preempted = [u for u, mm in sched.metrics.items() if mm["preempted"]]
    results = {u: np.asarray(sched.done[u].result) for u in preempted}
    return (arm_stats(sched, roles, wall, trace, steps, blocked, ss),
            results, step_lat)


def parity_audit(eng, trace, preempted_results):
    """Uninterrupted reference for EVERY preempted request: same engine
    (lane trajectories are per-lane pure, and reusing it reuses the jit
    caches), served alone.  No sampling/cap — the CI assertion claims
    every preempt-resumed request is token-identical, so every one is
    re-run (the preempted set is a handful of requests per trace)."""
    from repro.serving.scheduler import Scheduler
    by_uid = {}
    # drive() submits strictly in arrival order, so uid i+1 is trace[i]
    # of the time-sorted trace
    ordered = sorted(trace, key=lambda t: t[0])
    checked, ok = 0, True
    for uid, tokens in sorted(preempted_results.items()):
        _, kw, _ = ordered[uid - 1]
        s = Scheduler(eng, policy="fifo")
        ref = s.submit(**{k: v for k, v in kw.items()
                          if k in ("prompt", "n_tokens", "sampling")})
        s.run()
        same = np.array_equal(np.asarray(s.done[ref].result), tokens)
        by_uid[uid] = bool(same)
        ok &= same
        checked += 1
    return ok and checked > 0, checked, by_uid


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced trace for the CI tier-2 smoke job")
    args = ap.parse_args()

    import jax
    from benchmarks.common import bench_config
    from repro.models import model as MD
    from repro.serving.engine import PagedContinuousEngine

    cfg = sched_config(bench_config())
    params = MD.init_params(jax.random.PRNGKey(0), cfg)   # f32 weights
    n_lanes = 2
    eng = PagedContinuousEngine(
        cfg, params, max_seq=256 if args.smoke else 512, n_lanes=n_lanes,
        max_active_pages=4 if args.smoke else 5, prefill_chunk=16,
        # deterministic chunk split: the parity reference interleaves
        # differently, and burst chunks would change flash-attention
        # summation order
        burst_prefill=False)

    # ---- warmup + step-time calibration (compiles every shape both
    # timed arms hit, including the suspend/resume transfers) ---- #
    warm_trace = make_trace(cfg, smoke=True, step_s=5e-3)
    _, _, step_lat = run_arm(eng, "slo", warm_trace)
    step_s = float(np.median(step_lat))
    trace = make_trace(cfg, args.smoke, step_s)
    print(f"calibrated step time: {1e3 * step_s:.1f} ms -> "
          f"foreground deadline {1e3 * DEADLINE_STEPS * step_s:.0f} ms")

    # interleaved repeats, best-of by throughput per arm: wall clock on
    # shared CI boxes is scheduler/GC-noise dominated and min-of-N is the
    # standard latency methodology (cf. run_async_comparison); the
    # structural metrics (preemption count, parity) are trace properties
    # and reproduce in every repeat — parity is audited over all of them
    reps: Dict[str, list] = {"fifo": [], "slo": []}
    preempted: Dict[int, np.ndarray] = {}
    # the calibration arm above compiled every shape the timed arms hit
    # (incl. suspend/resume transfers), so the timed repeats must keep
    # every jit compile cache flat; trace_guard reports the growth and
    # the CI bench check asserts it is 0 on the smoke trace
    from repro.analysis import trace_guard
    with trace_guard(eng, label="scheduling timed repeats") as tg:
        for _ in range(2):
            for policy in ("fifo", "slo"):
                stats, pre, _ = run_arm(eng, policy, trace)
                reps[policy].append(stats)
                preempted.update(pre)
    fifo = max(reps["fifo"], key=lambda s: s["steady_tokens_per_step"])
    slo = max(reps["slo"], key=lambda s: s["steady_tokens_per_step"])
    parity, n_checked, parity_by_uid = parity_audit(eng, trace, preempted)

    print(f"\n{'mixed-SLO trace':>24s}  {'fifo':>10s}  {'slo':>10s}")
    for k in ("wall_s", "tokens_per_s", "jitted_steps", "tokens_per_step",
              "steady_tokens_per_step", "blocked_s", "fg_latency_p50_s",
              "fg_latency_p99_s", "fg_deadline_hit_rate", "preemptions"):
        print(f"{k:>24s}  {fifo[k]:>10}  {slo[k]:>10}")

    hit_win = slo["fg_deadline_hit_rate"] > fifo["fg_deadline_hit_rate"]
    p99_win = slo["fg_latency_p99_s"] < fifo["fg_latency_p99_s"]
    # throughput: steady-state packing efficiency must hold up AND
    # preemption's extra blocking-transfer time must stay a rounding
    # error of the run (see drive() on why the drain tail is excluded)
    tput_ok = (slo["steady_tokens_per_step"]
               >= TPUT_TOLERANCE * fifo["steady_tokens_per_step"]) \
        and (slo["blocked_s"] - fifo["blocked_s"]
             <= BLOCKED_OVERHEAD_FRAC * slo["wall_s"])
    print(f"\nhit-rate win: {hit_win}   fg p99 win: {p99_win}   "
          f"throughput ok (>= {TPUT_TOLERANCE}x tokens/step, blocked "
          f"overhead <= {BLOCKED_OVERHEAD_FRAC:.0%} wall): {tput_ok}   "
          f"preempt-resume parity: {parity} ({n_checked} audited)")

    report = {
        "n_lanes": n_lanes,
        "deadline_steps": DEADLINE_STEPS,
        "calibrated_step_ms": round(1e3 * step_s, 3),
        "throughput_tolerance": TPUT_TOLERANCE,
        "blocked_overhead_frac": BLOCKED_OVERHEAD_FRAC,
        "fifo": fifo, "slo": slo,
        "hit_rate_win": bool(hit_win),
        "fg_p99_win": bool(p99_win),
        "throughput_ok": bool(tput_ok),
        "preemptions": slo["preemptions"],
        "preempt_resume_token_parity": bool(parity),
        "parity_audited": n_checked,
        "parity_by_uid": parity_by_uid,
        "n_retraces": tg.n_retraces,
        "retrace_growth": tg.growth,
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "scheduling.json").write_text(json.dumps(report, indent=2))
    # machine-readable summary at the repo root (CI tier-2 asserts on it)
    bench = {k: report[k] for k in
             ("hit_rate_win", "fg_p99_win", "throughput_ok", "preemptions",
              "preempt_resume_token_parity", "parity_audited",
              "n_retraces")}
    bench["fg_deadline_hit_rate"] = {
        "fifo": fifo["fg_deadline_hit_rate"],
        "slo": slo["fg_deadline_hit_rate"]}
    bench["fg_latency_p99_s"] = {
        "fifo": fifo["fg_latency_p99_s"], "slo": slo["fg_latency_p99_s"]}
    bench["tokens_per_s"] = {
        "fifo": fifo["tokens_per_s"], "slo": slo["tokens_per_s"]}
    bench["tokens_per_step"] = {
        "fifo": fifo["tokens_per_step"], "slo": slo["tokens_per_step"]}
    bench["steady_tokens_per_step"] = {
        "fifo": fifo["steady_tokens_per_step"],
        "slo": slo["steady_tokens_per_step"]}
    (pathlib.Path(__file__).resolve().parents[1]
     / "BENCH_scheduling.json").write_text(json.dumps(bench, indent=2))


if __name__ == "__main__":
    main()

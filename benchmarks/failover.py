"""Replica-failover benchmark: mid-trace replica kill under a mixed-SLO
trace, served by ``ReplicaRouter`` over N in-process engine replicas.

* **Workload** — background requests (priority 5, moderate mixed-length
  generations, no deadline) flood every lane of every replica at t=0;
  interactive foreground requests (priority 0, short generations, tight
  calibrated deadlines) arrive spread across the run so several are
  in flight when the kill lands.

* **Kill** — one replica is crashed at an explicit mid-trace router tick
  (``kill_at``, the same deterministic ``replica_crash`` fault site
  ``--kill-replica-at`` drives), after at least two checkpoint cadences
  (``checkpoint_every`` ticks apart) so most of its in-flight lanes have
  a router-side checkpoint to resume from on the survivors.

* **Headline** (asserted by ``tools/check_bench.py --failover`` in CI
  tier-2): **zero lost requests** across the kill; every
  checkpoint-recovered request **token-identical** to an uninterrupted
  solo run of the same request (greedy + f32 + ``burst_prefill=False``
  — the repo's parity methodology: a lane's token stream is a pure
  function of its own request, so the reference is exact, and it holds
  across a *replica boundary* because a ``LaneSnapshot``'s payload is
  host-side numpy valid on any same-config engine); and **bounded
  deadline-hit degradation** — foreground requests whose lifetime
  overlaps the failover window still hit >= 80% of their deadlines.

* **Consistency** — the router journals every lane's committed tokens
  each tick; this bench runs recovery OFF (no entropy rewinds), so the
  journal is append-only and each recovered request's final tokens must
  extend its journal-at-failure prefix exactly.  The surviving
  replicas' controllers must also pass the exact stash/exported-bytes
  accounting audit (``repro.analysis.invariants.audit_controller``).

Foreground deadlines are calibrated from the measured per-tick wall time
(``DEADLINE_STEPS`` router ticks' worth, measured while every replica is
busy), so pass/fail is machine-speed independent.  The warmup phase runs
the same trace shape through a throwaway router over the *same engines*
and drains two replicas mid-run, compiling every shape the timed run
hits — prefill/decode, the checkpoint pull, and the cross-replica
resume push — before anything is timed.

    PYTHONPATH=src python -m benchmarks.failover           # full
    PYTHONPATH=src python -m benchmarks.failover --smoke   # CI tier-2
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time
from typing import Dict, List

import numpy as np

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"

N_REPLICAS = 3
N_LANES = 2                  # per replica
CHECKPOINT_EVERY = 4         # router ticks between checkpoint cadences
# foreground deadline in calibrated router ticks: comfortably above the
# foreground's own service time, below a background generation's
# remaining length (same construction as benchmarks/scheduling.py)
DEADLINE_STEPS = 26
# a foreground request is "in the failover window" when its lifetime
# overlaps [kill_tick, kill_tick + FG_WINDOW_TICKS] — wide enough to
# cover the re-place + resume of every recovered lane
FG_WINDOW_TICKS = 24
FG_HIT_FLOOR = 0.8


def failover_config(cfg):
    """Freeze pressure on (every lane carries real frozen/stashed pages
    across the replica boundary) with recovery OFF: no entropy rewinds,
    so the committed-token journal is append-only and the
    journal-consistency check below is exact."""
    fc = dataclasses.replace(cfg.freeze, page_size=16, window=16,
                             tau_mode="quantile", quantile=0.5, k_soft=1.0,
                             recovery_enabled=False)
    return dataclasses.replace(cfg, freeze=fc, dtype="float32")


def mk_engine(cfg, params, smoke: bool):
    from repro.serving.engine import PagedContinuousEngine
    return PagedContinuousEngine(
        cfg, params, max_seq=256 if smoke else 512, n_lanes=N_LANES,
        max_active_pages=4 if smoke else 5, prefill_chunk=16,
        # deterministic chunk split: the solo parity reference interleaves
        # differently, and burst chunks would change flash-attention
        # summation order
        burst_prefill=False)


def make_trace(cfg, smoke: bool, tick_s: float):
    """(arrival_s, submit-kwargs, role) tuples.  Background floods all
    N_REPLICAS * N_LANES lanes at t=0 with enough queued backlog that
    every replica is still busy at the kill tick; foregrounds arrive
    spread over the background-dominated span so several straddle the
    failover window."""
    from repro.serving.sampling import SamplingParams
    rng = np.random.RandomState(23)
    lanes = N_REPLICAS * N_LANES
    n_bg, bg_lo, bg_hi = (10, 20, 33) if smoke else (14, 24, 44)
    n_fg, fg_tok = (4, 6) if smoke else (8, 8)
    greedy = SamplingParams.greedy()
    trace = []
    bg_total = 0
    for _ in range(n_bg):
        n = int(rng.randint(bg_lo, bg_hi))
        bg_total += n
        trace.append((0.0, dict(
            prompt=rng.randint(0, cfg.vocab_size, size=24),
            n_tokens=n, sampling=greedy, priority=5), "bg"))
    # spread foregrounds across the background span (lanes lanes); the
    # deadline is DEADLINE_STEPS calibrated ticks from arrival
    gap = 0.6 * (bg_total / lanes) * tick_s / max(n_fg, 1)
    for i in range(n_fg):
        trace.append(((i + 0.35) * gap, dict(
            prompt=rng.randint(0, cfg.vocab_size, size=12),
            n_tokens=fg_tok, sampling=greedy, priority=0,
            deadline_ms=1e3 * DEADLINE_STEPS * tick_s), "fg"))
    return trace


def drive(router, trace):
    """Run timed arrivals through the router (idle gaps before the first
    pending arrival fast-forward, as in benchmarks/scheduling.drive).
    Returns per-role uid lists, per-uid submit/finish router ticks, and
    per-tick wall latencies tagged with how many replicas were busy."""
    pending = sorted(trace, key=lambda t: t[0])
    roles: Dict[str, List[int]] = {"bg": [], "fg": []}
    submit_tick: Dict[int, int] = {}
    finish_tick: Dict[int, int] = {}
    seen_done: set = set()
    tick_lat: List[tuple] = []
    t0 = time.monotonic()
    while pending or router.busy:
        now = time.monotonic() - t0
        if not router.busy and pending and pending[0][0] > now:
            t0 -= pending[0][0] - now
            now = pending[0][0]
        while pending and pending[0][0] <= now:
            _, kw, role = pending.pop(0)
            uid = router.submit(**kw)
            roles[role].append(uid)
            submit_tick[uid] = router.tick
        n_busy = sum(1 for r in router.replicas if r.alive and r.busy)
        ts = time.perf_counter()
        router.step()
        tick_lat.append((n_busy, time.perf_counter() - ts))
        # failover harvests retirements straight into router.done without
        # routing them through step()'s return — diff the done set
        for uid in router.done.keys() - seen_done:
            finish_tick[uid] = router.tick
            seen_done.add(uid)
    return roles, submit_tick, finish_tick, tick_lat


def solo_reference(cfg, params, requests, smoke: bool):
    """Uninterrupted per-request token streams on a single dedicated
    engine (same construction kwargs as every replica), each request
    served alone — the exact reference the parity audit compares
    against.  Reusing one engine reuses its jit caches."""
    from repro.serving.engine import Request
    from repro.serving.sampling import SamplingParams
    eng = mk_engine(cfg, params, smoke)
    out = {}
    for uid, req in sorted(requests.items()):
        ref = Request(uid, np.asarray(req.prompt, np.int32), req.n_tokens,
                      SamplingParams.greedy())
        eng.admit(ref)
        while ref.result is None:
            eng.step_once()
        out[uid] = np.asarray(ref.result)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced trace for the CI tier-2 smoke job")
    args = ap.parse_args()

    import jax
    from benchmarks.common import bench_config
    from repro.models import model as MD
    from repro.serving.router import ReplicaRouter
    from repro.analysis.invariants import audit_controller

    cfg = failover_config(bench_config())
    params = MD.init_params(jax.random.PRNGKey(0), cfg)   # f32 weights
    engines = [mk_engine(cfg, params, args.smoke)
               for _ in range(N_REPLICAS)]

    # ---- warmup + tick-time calibration: a throwaway router over the
    # SAME engines (their jit caches persist), running the same trace
    # shape and draining two replicas MID-run so the checkpoint pull and
    # the cross-replica suspend/resume push compile before anything is
    # timed ---- #
    warm = ReplicaRouter(engines, checkpoint_every=CHECKPOINT_EVERY)
    for _, kw, _ in sorted(make_trace(cfg, smoke=True, tick_s=5e-3),
                           key=lambda t: t[0]):
        warm.submit(**kw)
    warm_lat = []
    while warm.pending_uids():
        n_busy = sum(1 for r in warm.replicas if r.alive and r.busy)
        ts = time.perf_counter()
        warm.step()
        warm_lat.append((n_busy, time.perf_counter() - ts))
        if warm.tick == 2 * CHECKPOINT_EVERY:
            warm.drain_replica(0)
            warm.drain_replica(1)
    busy_lat = [dt for n, dt in warm_lat if n == N_REPLICAS]
    tick_s = float(np.median(busy_lat if busy_lat
                             else [dt for _, dt in warm_lat]))
    trace = make_trace(cfg, args.smoke, tick_s)
    # kill after two checkpoint cadences, while the background backlog
    # still occupies every replica
    kill_tick = 3 * CHECKPOINT_EVERY if args.smoke else 4 * CHECKPOINT_EVERY
    print(f"calibrated tick time: {1e3 * tick_s:.1f} ms -> foreground "
          f"deadline {1e3 * DEADLINE_STEPS * tick_s:.0f} ms, "
          f"kill replica 0 at tick {kill_tick}")

    router = ReplicaRouter(engines, checkpoint_every=CHECKPOINT_EVERY,
                           kill_at=(0, kill_tick))
    roles, submit_tick, finish_tick, tick_lat = drive(router, trace)
    rep = router.report()

    # ---- parity + consistency audits ---- #
    refs = solo_reference(cfg, params, router.requests, args.smoke)
    parity_by_uid = {u: bool(np.array_equal(refs[u],
                                            np.asarray(router.done[u].result)))
                     for u in sorted(router.done)}
    all_parity = all(parity_by_uid.values()) and len(parity_by_uid) > 0
    ck_uids = sorted({e["uid"] for e in router.events
                      if e["event"] == "recover" and e["from_checkpoint"]})
    ck_parity = all(parity_by_uid[u] for u in ck_uids) and len(ck_uids) > 0

    journal_by_uid = {}
    for uid, j in sorted(router.journal_at_fail.items()):
        final = list(np.asarray(router.done[uid].result)) \
            if uid in router.done else []
        journal_by_uid[uid] = bool(final[:len(j)] == list(j))
    journal_ok = all(journal_by_uid.values()) and len(journal_by_uid) > 0

    invariants_ok = True
    for r in router.replicas:
        if not r.alive:
            continue
        try:
            audit_controller(r.engine.ctl)
        except AssertionError as e:
            invariants_ok = False
            print(f"replica {r.rid} invariant violation: {e}")

    # ---- foreground deadline hits, overall + failover window ---- #
    m = router.metrics
    fg_hits = [bool(m[u]["deadline_hit"]) for u in roles["fg"]]
    window = (kill_tick, kill_tick + FG_WINDOW_TICKS)
    fg_window = [u for u in roles["fg"]
                 if submit_tick[u] <= window[1]
                 and finish_tick.get(u, window[1]) >= window[0]]
    fg_window_hits = [bool(m[u]["deadline_hit"]) for u in fg_window]
    hit_rate = sum(fg_hits) / max(len(fg_hits), 1)
    hit_window = (sum(fg_window_hits) / len(fg_window_hits)
                  if fg_window_hits else 1.0)

    print(f"\n{'replica-kill trace':>28s}  {'value':>8s}")
    rows = [
        ("ticks", rep["ticks"]), ("kill_tick", kill_tick),
        ("submitted", rep["submitted"]), ("completed", rep["completed"]),
        ("lost_requests", rep["lost_requests"]),
        ("n_failovers", rep["n_failovers"]),
        ("recovered_with_checkpoint", rep["recovered_with_checkpoint"]),
        ("recovered_reprefill", rep["recovered_reprefill"]),
        ("requeued_items", rep["requeued_items"]),
        ("checkpoint_parity", ck_parity),
        ("all_token_parity", all_parity),
        ("journal_consistent", journal_ok),
        ("invariants_ok", invariants_ok),
        ("fg_deadline_hit_rate", round(hit_rate, 3)),
        ("fg_deadline_hit_window", round(hit_window, 3)),
        ("fg_in_window", len(fg_window)),
    ]
    for k, v in rows:
        print(f"{k:>28s}  {v!s:>8s}")

    report = {
        "n_replicas": N_REPLICAS,
        "n_lanes": N_LANES,
        "checkpoint_every": CHECKPOINT_EVERY,
        "deadline_steps": DEADLINE_STEPS,
        "fg_window_ticks": FG_WINDOW_TICKS,
        "fg_hit_floor": FG_HIT_FLOOR,
        "calibrated_tick_ms": round(1e3 * tick_s, 3),
        "kill_tick": kill_tick,
        "parity_by_uid": parity_by_uid,
        "checkpoint_recovered_uids": ck_uids,
        "journal_by_uid": journal_by_uid,
        "events": router.events,
        "router": rep,
        **dict(rows),
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "failover.json").write_text(json.dumps(report, indent=2))
    # machine-readable summary at the repo root (CI tier-2 asserts on it
    # via tools/check_bench.py --failover)
    bench = dict(rows)
    bench["checkpoint_audited"] = len(ck_uids)
    bench["journal_audited"] = len(journal_by_uid)
    bench["fg_hit_floor"] = FG_HIT_FLOOR
    bench["n_live"] = rep["n_live"]
    (pathlib.Path(__file__).resolve().parents[1]
     / "BENCH_failover.json").write_text(json.dumps(bench, indent=2))


if __name__ == "__main__":
    main()

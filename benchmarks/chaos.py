"""Chaos benchmark: serve under injected faults and memory pressure, and
assert the hardening contract — no unhandled exceptions, token parity for
every survivable fault, host-stash peak within budget, and clean terminal
statuses.

Three scenarios, each driven through the SLO scheduler on the paged
engine (tiny config, f32, greedy, ``burst_prefill=False`` — the repo's
exact-parity methodology):

* **dma_faults** — rate-scheduled pull/push/ring/stage faults plus an
  explicit ring burst long enough to trip the ring breaker (the engine
  drops the fetch ring to its depth-0 sync baseline while the breaker is
  open, then restores depth-1).  Asserts token parity against a clean run
  of the same trace, retries > 0, injections at >= 3 sites, and
  breaker_trips >= 1.

* **stash_pressure** — two arms over a recovery-off freeze-heavy config
  (recovery off because suspend/resume token parity is only *guaranteed*
  without rewalks — docs/robustness.md#suspend-resume-parity-envelope):

  - *parity arm*: budget set above the unbounded peak (pressure tops out
    ~0.8), with the throttle and shed rungs armed at low thresholds and
    the non-parity-preserving rungs (deepen-timers) disabled.  Asserts
    per-request token parity against the unbounded run, peak <= budget,
    and that throttling and shedding both fired.

  - *full-ladder arm*: budget well below the unbounded peak, every rung
    armed, recovery ON.  Parity is NOT asserted (deepened freeze timers
    legitimately change freeze decisions).  Neither is peak <= budget: a
    budget below the *correctness floor* — the frozen pages that must
    live SOMEWHERE to preserve lane data — cannot be met without data
    loss, and the exempt correctness-critical writers (overflow stash at
    install, forced eviction — see ``PagedController.stash_budget_bytes``)
    carry the stash to that floor regardless.  What IS asserted: the
    swap-out hard ceiling fired (``n_denied_offloads`` > 0), the
    deny/deepen rungs fired, every request ends in a clean terminal
    status, and the peak never exceeds the unbounded run's (the ceiling
    stopped all optimization-path growth).

* **nan_logits** — explicit host-side logit poisoning.  A single poison
  triggers one bounded page-aware rewind and the lane completes; a second
  poison inside ``quarantine_window`` retires the lane "quarantined".
  The unpoisoned peer request must be token-identical to a clean run in
  both cases (lane trajectories are per-lane pure).

Every scenario body runs under a catch-all: the headline criterion is
``unhandled_exceptions == 0`` — chaos may degrade modes, never crash the
server.  ``tools/check_bench.py --chaos`` asserts the named criteria in
CI tier-2.

    PYTHONPATH=src python -m benchmarks.chaos           # full
    PYTHONPATH=src python -m benchmarks.chaos --smoke   # CI tier-2
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import traceback
from typing import Dict, List, Optional, Tuple

import numpy as np

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"


def _recovery_cfg(cfg):
    """Aggressive freeze + entropy recovery: thaws, staging prefetch and
    rewinds all active (the dma_faults / nan_logits scenarios)."""
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.6, k_soft=0.7,
                             recovery_enabled=True,
                             entropy_abs_threshold=0.5, rewalk_tokens=6)
    return dataclasses.replace(cfg, freeze=fc, dtype="float32")


def _pressure_cfg(cfg):
    """Freeze-heavy with recovery OFF: pages stash steadily and
    suspend/resume is token-exact under arbitrary shed cycles (the
    stash_pressure parity arm's requirement)."""
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.6, k_soft=0.7,
                             recovery_enabled=False)
    return dataclasses.replace(cfg, freeze=fc, dtype="float32")


def _mk_engine(cfg, params, **kw):
    from repro.serving.engine import PagedContinuousEngine
    kw.setdefault("max_seq", 256)
    kw.setdefault("n_lanes", 2)
    kw.setdefault("max_active_pages", 6)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("async_pipeline", True)
    kw.setdefault("burst_prefill", False)
    return PagedContinuousEngine(cfg, params, **kw)


def _trace(cfg, n_req: int, n_tok: int, prompt_lo=16, prompt_hi=32,
           seed=3) -> List[Tuple[np.ndarray, int]]:
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, size=rng.randint(
        prompt_lo, prompt_hi)), n_tok) for _ in range(n_req)]


def _serve(eng, trace) -> Dict[int, "object"]:
    """Serve the trace through the SLO scheduler; uid -> Request."""
    from repro.serving.sampling import SamplingParams
    from repro.serving.scheduler import Scheduler
    sched = Scheduler(eng)
    for prompt, n_tok in trace:
        sched.submit(prompt, n_tok, SamplingParams.greedy())
    sched.run()
    return sched.done


def _tokens(done) -> Dict[int, List[int]]:
    return {u: list(map(int, r.result)) for u, r in done.items()}


def _parity(a: Dict[int, List[int]], b: Dict[int, List[int]],
            uids=None) -> bool:
    uids = sorted(a) if uids is None else uids
    return all(a.get(u) == b.get(u) for u in uids)


def scenario_dma_faults(cfg_base, params, smoke: bool) -> dict:
    from repro.serving.faults import ChaosConfig, FaultPlan
    cfg = _recovery_cfg(cfg_base)
    n_req, n_tok = (3, 32) if smoke else (4, 56)
    trace = _trace(cfg, n_req, n_tok)

    clean = _tokens(_serve(_mk_engine(cfg, params), trace))

    # rate faults on every transfer site + an explicit ring burst whose
    # per-op failure count exceeds the retry budget for several
    # consecutive ops -> the ring breaker trips and the engine serves
    # from the depth-0 sync baseline until cooldown
    burst = {("ring", i): FaultPlan(kind="fail", attempts=10)
             for i in range(12, 16)}
    burst[("pull", 2)] = FaultPlan(kind="slow", delay_s=0.002)
    chaos = ChaosConfig(seed=7,
                        rates={"pull": 0.25, "push": 0.25,
                               "ring": 0.1, "stage": 0.4},
                        attempts=1, explicit=burst,
                        max_retries=2, trip_after=2, cooldown_ops=8)
    eng = _mk_engine(cfg, params, chaos=chaos)
    faulted = _tokens(_serve(eng, trace))
    rs = eng.robust_snapshot()

    sites_hit = sum(1 for v in rs["injected_by_site"].values() if v)
    return {
        "token_parity": _parity(clean, faulted),
        "retries": rs["retries"],
        "injected": rs["injected"],
        "injected_by_site": rs["injected_by_site"],
        "sites_hit": sites_hit,
        "breaker_trips": rs["breaker_trips"],
        "slow_ops": sum(s["slow"] for s in rs["endpoints"].values()),
        "thaw_uploads": eng.ctl.n_thaw_upload,
        "endpoints": rs["endpoints"],
    }


def scenario_stash_pressure(cfg_base, params, smoke: bool) -> dict:
    from repro.serving.engine import LadderConfig
    cfg = _pressure_cfg(cfg_base)
    n_req, n_tok = (5, 32) if smoke else (6, 56)
    trace = _trace(cfg, n_req, n_tok, prompt_lo=16, prompt_hi=25)

    # unbounded reference: no budget, ladder never engages
    ref_eng = _mk_engine(cfg, params, max_active_pages=4)
    ref = _tokens(_serve(ref_eng, trace))
    unbounded_peak = ref_eng.peak_stash_bytes

    # -- parity arm: budget above the unbounded peak (pressure < 1.0),
    # throttle+shed armed low, non-parity rungs (deepen) disabled; the
    # deny rung is idle anyway (recovery off -> no staging prefetch)
    budget = int(unbounded_peak * 1.25) or 1
    ladder = LadderConfig(deny_prefetch=2.0, deepen_timers=2.0,
                          throttle_admissions=0.45, shed=0.6)
    eng = _mk_engine(cfg, params, max_active_pages=4,
                     stash_budget_bytes=budget, ladder=ladder)
    done = _serve(eng, trace)
    shed_uids = [u for u, r in done.items() if r.status == "shed-resumed"]
    parity_arm = {
        "budget_bytes": budget,
        "unbounded_peak_bytes": unbounded_peak,
        "peak_stash_bytes": eng.peak_stash_bytes,
        "peak_within_budget": eng.peak_stash_bytes <= budget,
        "token_parity": _parity(ref, _tokens(done)),
        "throttles": eng.robust["ladder_throttle"],
        "sheds": eng.robust["ladder_shed"],
        "shed_resumed": len(shed_uids),
        "statuses": sorted(r.status for r in done.values()),
    }

    # -- full-ladder arm: tight budget, every rung armed, recovery ON
    # (deny needs staging prefetch).  Parity is NOT asserted: deepened
    # timers change freeze decisions by design.
    cfg_full = _recovery_cfg(cfg_base)
    full_eng = _mk_engine(cfg_full, params, max_active_pages=4)
    _serve(full_eng, trace)
    full_peak = full_eng.peak_stash_bytes
    # tight enough that the deny-rung trims can't keep the stash clear of
    # the ceiling on their own (longer generations trim more)
    budget2 = max(int(full_peak * 0.4), 1)
    # shed disabled here: exporting a victim's pages relieves the stash
    # so effectively the swap-out ceiling would never be reached — and
    # shedding is already covered (with parity) by the arm above.  This
    # arm pins pressure AT the ceiling to prove the hard stop works.
    ladder2 = LadderConfig(deny_prefetch=0.3, deepen_timers=0.5,
                           throttle_admissions=0.7, shed=2.0)
    eng2 = _mk_engine(cfg_full, params, max_active_pages=4,
                      stash_budget_bytes=budget2, ladder=ladder2)
    done2 = _serve(eng2, trace)
    clean_status = all(r.status in ("completed", "shed-resumed")
                       for r in done2.values())
    full_arm = {
        "budget_bytes": budget2,
        "unbounded_peak_bytes": full_peak,
        "peak_stash_bytes": eng2.peak_stash_bytes,
        "peak_no_worse": eng2.peak_stash_bytes <= full_peak,
        "denied_offloads": eng2.ctl.n_denied_offloads,
        "denies": eng2.robust["ladder_deny"],
        "deepens": eng2.robust["ladder_deepen"],
        "throttles": eng2.robust["ladder_throttle"],
        "sheds": eng2.robust["ladder_shed"],
        "statuses_clean": clean_status,
        "statuses": sorted(r.status for r in done2.values()),
        "all_completed": len(done2) == n_req,
    }
    return {"parity_arm": parity_arm, "full_ladder_arm": full_arm}


def scenario_nan_logits(cfg_base, params, smoke: bool) -> dict:
    from repro.serving.faults import ChaosConfig, FaultPlan
    cfg = _recovery_cfg(cfg_base)
    n_tok = 32 if smoke else 48
    trace = _trace(cfg, 2, n_tok, prompt_lo=20, prompt_hi=28, seed=5)

    clean = _tokens(_serve(_mk_engine(cfg, params), trace))

    def poison_run(ops):
        chaos = ChaosConfig(seed=0, explicit={
            ("nan", k): FaultPlan(kind="nan", lane=0) for k in ops})
        eng = _mk_engine(cfg, params, chaos=chaos)
        done = _serve(eng, trace)
        return eng, done

    # single poison: one bounded rewind, the lane completes
    eng1, done1 = poison_run([30])
    # double poison inside quarantine_window: rewind, re-poison, retire
    eng2, done2 = poison_run([30, 33])

    # two requests, two lanes: uid 1 lands in lane 0 (the poisoned one),
    # uid 2 is the untouched peer in lane 1
    peer_uids1 = peer_uids2 = [2]
    return {
        "single": {
            "quarantine_rewinds": eng1.robust["quarantine_rewinds"],
            "quarantined": eng1.robust["quarantined"],
            "statuses": sorted(r.status for r in done1.values()),
            "all_completed": all(r.status == "completed"
                                 for r in done1.values()),
            "peer_parity": _parity(clean, _tokens(done1),
                                   uids=peer_uids1),
        },
        "double": {
            "quarantine_rewinds": eng2.robust["quarantine_rewinds"],
            "quarantined": eng2.robust["quarantined"],
            "statuses": sorted(r.status for r in done2.values()),
            "peer_parity": _parity(clean, _tokens(done2),
                                   uids=peer_uids2),
            "peer_completed": all(done2[u].status == "completed"
                                  for u in peer_uids2),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced traces for the CI tier-2 smoke job")
    args = ap.parse_args()

    import jax
    from repro.configs import get_config
    from repro.models import model as MD

    cfg_base = get_config("llama3-8b-tiny")
    params = MD.init_params(
        jax.random.PRNGKey(0),
        dataclasses.replace(cfg_base, dtype="float32"))

    report: dict = {"smoke": args.smoke}
    unhandled = 0
    for name, fn in (("dma_faults", scenario_dma_faults),
                     ("stash_pressure", scenario_stash_pressure),
                     ("nan_logits", scenario_nan_logits)):
        try:
            report[name] = fn(cfg_base, params, args.smoke)
            print(f"[{name}] ok")
        except Exception:
            unhandled += 1
            report[name] = {"error": traceback.format_exc()}
            print(f"[{name}] UNHANDLED EXCEPTION")
            traceback.print_exc()
    report["unhandled_exceptions"] = unhandled

    d = report.get("dma_faults", {})
    sp = report.get("stash_pressure", {})
    nn = report.get("nan_logits", {})
    pa, fa = sp.get("parity_arm", {}), sp.get("full_ladder_arm", {})
    bench = {
        "unhandled_exceptions": unhandled,
        "dma_token_parity": bool(d.get("token_parity")),
        "dma_retries": int(d.get("retries", 0)),
        "dma_sites_hit": int(d.get("sites_hit", 0)),
        "dma_breaker_trips": int(d.get("breaker_trips", 0)),
        "ladder_token_parity": bool(pa.get("token_parity")),
        "ladder_peak_within_budget": bool(pa.get("peak_within_budget")),
        "ladder_throttles": int(pa.get("throttles", 0)),
        "ladder_sheds": int(pa.get("sheds", 0)),
        "ladder_shed_resumed": int(pa.get("shed_resumed", 0)),
        "full_ladder_denied_offloads": int(fa.get("denied_offloads", 0)),
        "full_ladder_denies": int(fa.get("denies", 0)),
        "full_ladder_deepens": int(fa.get("deepens", 0)),
        "full_ladder_peak_no_worse": bool(fa.get("peak_no_worse")),
        "full_ladder_statuses_clean": bool(fa.get("statuses_clean")),
        "nan_single_recovered": bool(
            nn.get("single", {}).get("all_completed")
            and nn.get("single", {}).get("quarantine_rewinds", 0) >= 1
            and nn.get("single", {}).get("quarantined", 1) == 0),
        "nan_double_quarantined": bool(
            nn.get("double", {}).get("quarantined", 0) == 1),
        "nan_peer_parity": bool(
            nn.get("single", {}).get("peer_parity")
            and nn.get("double", {}).get("peer_parity")),
    }
    print("\n" + json.dumps(bench, indent=2))

    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "chaos.json").write_text(json.dumps(report, indent=2))
    (pathlib.Path(__file__).resolve().parents[1]
     / "BENCH_chaos.json").write_text(json.dumps(bench, indent=2))


if __name__ == "__main__":
    main()

"""Hypothesis property tests for the freeze state machine — split from
test_freeze.py so the unit tests stay collectable without hypothesis; this
module degrades to a skip (pip install -r requirements-dev.txt)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import FreezeConfig
from repro.core.freeze import freeze_update, init_freeze_state


def mk_cfg(**kw):
    base = dict(window=4, tau=0.5, k_soft=2.0, history=10**6,
                recovery_enabled=False)
    base.update(kw)
    return FreezeConfig(**base)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    seq=st.integers(8, 64),
    window=st.integers(0, 8),
    steps=st.integers(1, 10),
    ksoft=st.floats(0.5, 4.0),
)
def test_freeze_invariants(seed, seq, window, steps, ksoft):
    """System invariants hold for arbitrary relevance streams."""
    cfg = mk_cfg(window=window, k_soft=ksoft, tau=0.5)
    rng = np.random.RandomState(seed)
    state = init_freeze_state(2, seq)
    pos = seq - 1
    for step in range(steps):
        rel = jnp.asarray(rng.rand(2, seq).astype(np.float32))
        prev = state
        state, info = freeze_update(state, rel, jnp.int32(pos),
                                    jnp.int32(step), cfg)
        frozen = np.asarray(state.frozen)
        d = np.asarray(state.d)
        c = np.asarray(state.c)
        idx = np.arange(seq)[None, :]
        exists = np.broadcast_to(idx <= pos, frozen.shape)
        # 1. never freeze inside the sliding window or beyond pos
        assert not frozen[~exists].any()
        assert not frozen[:, max(0, pos - window + 1):].any()
        # 2. timers non-negative; frozen slots carry positive-or-zero timers
        assert (d >= 0).all()
        # 3. counters never decrease except via history decay (disabled here)
        assert (c >= np.asarray(prev.c) - 0).all()
        # 4. a slot cannot be both just_frozen and restored
        jf = np.asarray(info["just_frozen"])
        rs = np.asarray(info["restored"])
        assert not (jf & rs).any()
        # 5. active = exists & ~frozen
        np.testing.assert_array_equal(
            np.asarray(info["active"]), exists & ~frozen)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_reversibility_no_permanent_loss(seed):
    """Paper's core claim: freezing is reversible — any frozen token returns
    to the active set within a bounded number of steps once it stops being
    flagged (relevance above tau)."""
    cfg = mk_cfg(window=2, k_soft=1.0)
    state = init_freeze_state(1, 16)
    # aggressively freeze for a while
    for step in range(20):
        state, _ = freeze_update(state, jnp.zeros((1, 16)), jnp.int32(15),
                                 jnp.int32(step), cfg)
    max_d = int(np.asarray(state.d).max())
    # now everything is relevant: all slots must unfreeze within max_d+1 steps
    for step in range(20, 21 + max_d):
        state, _ = freeze_update(state, jnp.full((1, 16), 10.0),
                                 jnp.int32(15), jnp.int32(step), cfg)
    assert not np.asarray(state.frozen).any()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 8))
def test_per_lane_equals_scalar(seed, steps):
    """The per-lane (B,) pos/step path is trajectory-identical to the
    scalar path when all lanes share one clock."""
    cfg = mk_cfg(window=3, k_soft=1.0, history=5)
    rng = np.random.RandomState(seed)
    s1 = s2 = init_freeze_state(2, 12)
    for step in range(steps):
        rel = jnp.asarray(rng.rand(2, 12).astype(np.float32))
        s1, _ = freeze_update(s1, rel, jnp.int32(11), jnp.int32(step), cfg)
        s2, _ = freeze_update(s2, rel, jnp.full((2,), 11, jnp.int32),
                              jnp.full((2,), step, jnp.int32), cfg)
    for a, b in zip(s1, s2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""The streaming server front end (serving/server.py): scheduler-level
cancellation (mid-decode, mid-chunked-prefill, queued), freeze-native
pause/release backpressure, async streaming parity with the batch path,
client-disconnect cancellation with surviving-peer token parity, and the
stdlib HTTP/SSE round trip."""
import asyncio
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.analysis import audit_controller
from repro.configs import get_config
from repro.models import model as MD
from repro.serving.config import ServingConfig
from repro.serving.engine import PagedContinuousEngine, Request, RequestStatus
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler
from repro.serving.server import AsyncServingEngine, ServingServer
from repro.serving.tenancy import TenancyController, TenantConfig


@pytest.fixture(scope="module")
def tiny_f32():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.5, k_soft=1.0,
                             recovery_enabled=False)
    cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def paged_engine(cfg, params, n_lanes=2, pages=4, max_seq=128):
    return PagedContinuousEngine(cfg, params, serving=ServingConfig(
        max_seq=max_seq, n_lanes=n_lanes, max_active_pages=pages,
        prefill_chunk=8, burst_prefill=False))


def run_alone(cfg, params, req_args, **eng_kw):
    eng = paged_engine(cfg, params, **eng_kw)
    req = Request(1, *req_args)
    eng.admit(req)
    while req.result is None:
        eng.step_once()
    return np.asarray(req.result)


def _run(coro, timeout=300.0):
    asyncio.run(asyncio.wait_for(coro, timeout))


def _parse_sse(body: str):
    """[(event, data), ...] from a raw SSE byte stream."""
    out = []
    for block in body.split("\n\n"):
        block = block.strip()
        if not block:
            continue
        lines = block.split("\n")
        assert lines[0].startswith("event: ") and \
            lines[1].startswith("data: "), block
        out.append((lines[0][7:], json.loads(lines[1][6:])))
    return out


class TestSchedulerCancel:
    """The server's hooks, exercised synchronously (deterministic)."""

    def test_cancel_mid_decode(self, tiny_f32):
        cfg, params = tiny_f32
        rng = np.random.RandomState(0)
        prompt = rng.randint(0, cfg.vocab_size, size=20).astype(np.int32)
        ref = run_alone(cfg, params, (prompt, 32, SamplingParams.greedy()))
        sched = Scheduler(paged_engine(cfg, params))
        uid = sched.submit(prompt, 32, SamplingParams.greedy())
        for _ in range(12):
            sched.step()
        assert sched.cancel(uid)
        req = sched.done[uid]
        assert req.status == RequestStatus.CANCELLED
        # the partial result is the committed prefix of the solo run
        assert 1 <= len(req.result) < 32
        np.testing.assert_array_equal(req.result, ref[: len(req.result)])
        # lane freed, nothing stranded, controller accounting exact
        assert sched.engine.n_active_lanes == 0
        assert sched.metrics[uid]["finish_t"] is not None
        assert sched.metrics[uid]["deadline_hit"] is None
        audit_controller(sched.engine.ctl)
        assert not sched.cancel(uid)        # already finished: idempotent

    def test_cancel_mid_chunked_prefill(self, tiny_f32):
        cfg, params = tiny_f32
        rng = np.random.RandomState(1)
        prompt = rng.randint(0, cfg.vocab_size, size=40).astype(np.int32)
        sched = Scheduler(paged_engine(cfg, params, max_seq=160))
        eng = sched.engine
        uid = sched.submit(prompt, 8, SamplingParams.greedy())
        sched.step()                        # admit + first prefill chunk
        assert 0 in eng.prefills, "test premise: mid-prefill"
        assert sched.cancel(uid)
        assert sched.done[uid].status == RequestStatus.CANCELLED
        assert sched.done[uid].result.shape == (0,)
        assert 0 not in eng.prefills and eng.lanes[0].request is None
        audit_controller(eng.ctl)
        # the engine is unharmed: the next request serves with parity
        ref = run_alone(cfg, params, (prompt, 8, SamplingParams.greedy()),
                        max_seq=160)
        uid2 = sched.submit(prompt, 8, SamplingParams.greedy())
        sched.run()
        np.testing.assert_array_equal(ref, sched.done[uid2].result)

    def test_cancel_queued_and_suspended(self, tiny_f32):
        cfg, params = tiny_f32
        rng = np.random.RandomState(2)
        sched = Scheduler(paged_engine(cfg, params))
        mk = lambda: sched.submit(
            rng.randint(0, cfg.vocab_size, size=10), 16,
            SamplingParams.greedy())
        a, b, c = mk(), mk(), mk()          # 2 lanes: c stays queued
        assert sched.cancel(c)              # plain queued entry
        assert sched.done[c].result.shape == (0,)
        for _ in range(6):
            sched.step()
        snap = sched.pause(a)               # park a's lane (snapshot)
        assert snap is not None
        sched.release(snap)                 # now a queued LaneSnapshot
        assert sched.cancel(a)              # discard-snapshot path
        req = sched.done[a]
        assert req.status == RequestStatus.CANCELLED
        assert len(req.result) >= 1         # keeps its partial tokens
        sched.run()
        assert sched.done[b].result.shape == (16,)
        assert sched.n_cancelled == 2
        audit_controller(sched.engine.ctl)

    def test_pause_holds_release_resumes_with_parity(self, tiny_f32):
        cfg, params = tiny_f32
        rng = np.random.RandomState(3)
        prompt = rng.randint(0, cfg.vocab_size, size=20).astype(np.int32)
        ref = run_alone(cfg, params, (prompt, 24, SamplingParams.greedy()))
        sched = Scheduler(paged_engine(cfg, params))
        uid = sched.submit(prompt, 24, SamplingParams.greedy())
        for _ in range(8):
            sched.step()
        item = sched.pause(uid)
        assert item is not None
        assert sched.engine.n_active_lanes == 0
        for _ in range(4):                  # the scheduler cannot resume it
            sched.step()
        assert uid not in sched.done and not sched.queue
        sched.release(item)
        sched.run()
        np.testing.assert_array_equal(ref, sched.done[uid].result)


class TestAsyncServingEngine:
    def test_streaming_parity_with_batch_path(self, tiny_f32):
        """The streamed committed sequence (tokens + rewinds replayed)
        equals both the terminal event and the uninterrupted batch-path
        result."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(10)
        prompt = rng.randint(0, cfg.vocab_size, size=20).astype(np.int32)
        ref = run_alone(cfg, params, (prompt, 24, SamplingParams.greedy()))

        async def go():
            ae = AsyncServingEngine(Scheduler(paged_engine(cfg, params)))
            await ae.start()
            try:
                stream = await ae.submit(prompt, 24)
                fin = await stream.collect()
                assert fin["status"] == "completed"
                assert fin["streamed"] == fin["tokens"] == ref.tolist()
                st = await ae.stats()
                assert st["unhandled_exceptions"] == 0
                assert st["streams"] == 0 and st["done"] == 1
            finally:
                await ae.close()

        _run(go())

    def test_mid_decode_disconnect_peer_unaffected(self, tiny_f32):
        """Cancel one of two concurrent streams after 3 tokens: its lane
        frees (audit-clean, no stranded entry), its terminal carries the
        committed prefix of its solo run, and the SURVIVING stream's
        tokens are identical to a solo run — cancellation is invisible to
        the peer lane."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(11)
        vic_p = rng.randint(0, cfg.vocab_size, size=20).astype(np.int32)
        sur_p = rng.randint(0, cfg.vocab_size, size=16).astype(np.int32)
        ref_vic = run_alone(cfg, params, (vic_p, 48, SamplingParams.greedy()))
        ref_sur = run_alone(cfg, params, (sur_p, 24, SamplingParams.greedy()))
        sched = Scheduler(paged_engine(cfg, params))

        async def go():
            ae = AsyncServingEngine(sched)
            await ae.start()
            try:
                victim = await ae.submit(vic_p, 48)
                surv = await ae.submit(sur_p, 24)
                got = []
                async for ev in victim:
                    if ev["event"] == "token":
                        got.append(ev["token"])
                        if len(got) >= 3:
                            break
                assert await ae.cancel(victim.uid)
                fin_v = None
                async for ev in victim:     # drain to the terminal
                    if ev["event"] == "token":
                        got.append(ev["token"])
                    elif ev["event"] == "rewind":
                        del got[ev["to"]:]
                    else:
                        fin_v = ev
                assert fin_v["status"] == "cancelled"
                assert got == fin_v["tokens"]
                assert 3 <= len(got) < 48
                assert got == ref_vic[: len(got)].tolist()
                fin_s = await surv.collect()
                assert fin_s["status"] == "completed"
                assert fin_s["streamed"] == ref_sur.tolist()
                st = await ae.stats()
                assert st["n_cancelled"] == 1
                assert st["active_lanes"] == 0 and st["streams"] == 0
                assert st["unhandled_exceptions"] == 0
            finally:
                await ae.close()

        _run(go())
        assert all(m["finish_t"] is not None
                   for m in sched.metrics.values())
        audit_controller(sched.engine.ctl)

    def test_slow_consumer_pauses_and_resumes(self, tiny_f32):
        """A consumer that stops reading fills its bounded queue; the
        serve loop parks the request through Scheduler.pause (lane frees)
        and releases it when the queue drains — with full token parity."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(12)
        prompt = rng.randint(0, cfg.vocab_size, size=12).astype(np.int32)
        ref = run_alone(cfg, params, (prompt, 32, SamplingParams.greedy()))

        async def go():
            ae = AsyncServingEngine(Scheduler(paged_engine(cfg, params)),
                                    stream_capacity=6)
            await ae.start()
            try:
                stream = await ae.submit(prompt, 32)
                deadline = asyncio.get_running_loop().time() + 120
                while True:                 # read nothing: queue must fill
                    st = await ae.stats()
                    if st["n_paused"] >= 1:
                        break
                    assert asyncio.get_running_loop().time() < deadline, \
                        "backpressure never paused the request"
                    await asyncio.sleep(0.01)
                fin = await stream.collect()
                assert fin["status"] == "completed"
                assert fin["streamed"] == fin["tokens"] == ref.tolist()
                st = await ae.stats()
                assert st["n_paused"] >= 1 and st["n_resumed"] >= 1
                assert st["unhandled_exceptions"] == 0
            finally:
                await ae.close()

        _run(go())


class TestHTTPServer:
    def test_sse_roundtrip_with_tenant(self, tiny_f32):
        cfg, params = tiny_f32
        rng = np.random.RandomState(20)
        prompt = rng.randint(0, cfg.vocab_size, size=12).astype(np.int32)
        ref = run_alone(cfg, params, (prompt, 10, SamplingParams.greedy()))

        async def go():
            eng = paged_engine(cfg, params)
            ten = TenancyController([TenantConfig("gold", weight=3.0)])
            srv = ServingServer(
                AsyncServingEngine(Scheduler(eng, tenancy=ten)), port=0)
            await srv.start()
            try:
                r, w = await asyncio.open_connection("127.0.0.1", srv.port)
                body = json.dumps({"prompt": prompt.tolist(),
                                   "n_tokens": 10}).encode()
                w.write(("POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                         "X-Tenant: gold\r\n"
                         f"Content-Length: {len(body)}\r\n\r\n").encode()
                        + body)
                await w.drain()
                raw = (await r.read()).decode()
                w.close()
                head, _, sse = raw.partition("\r\n\r\n")
                assert head.startswith("HTTP/1.1 200")
                assert "text/event-stream" in head
                evs = _parse_sse(sse)
                toks = []
                for ev, data in evs[:-1]:
                    if ev == "token":
                        assert data["index"] == len(toks)
                        toks.append(data["token"])
                    elif ev == "rewind":
                        del toks[data["to"]:]
                assert evs[-1][0] == "done"
                assert evs[-1][1]["status"] == "completed"
                assert toks == evs[-1][1]["tokens"] == ref.tolist()
                st = await srv.engine.stats()
                assert st["tenants"]["gold"]["completed"] == 1
                # health endpoint serves the engine facade
                r2, w2 = await asyncio.open_connection("127.0.0.1",
                                                       srv.port)
                w2.write(b"GET /v1/health HTTP/1.1\r\n\r\n")
                await w2.drain()
                h = json.loads((await r2.read()).decode()
                               .partition("\r\n\r\n")[2])
                w2.close()
                assert h["n_lanes"] == 2 and h["n_active_lanes"] == 0
            finally:
                await srv.close()

        _run(go())

    def test_disconnect_mid_stream_cancels(self, tiny_f32):
        cfg, params = tiny_f32
        rng = np.random.RandomState(21)
        prompt = rng.randint(0, cfg.vocab_size, size=12).astype(np.int32)
        sched = Scheduler(paged_engine(cfg, params))

        async def go():
            srv = ServingServer(AsyncServingEngine(sched), port=0)
            await srv.start()
            try:
                r, w = await asyncio.open_connection("127.0.0.1", srv.port)
                body = json.dumps({"prompt": prompt.tolist(),
                                   "n_tokens": 64}).encode()
                w.write(("POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                         f"Content-Length: {len(body)}\r\n\r\n").encode()
                        + body)
                await w.drain()
                buf = b""
                while buf.count(b"event: token") < 3:
                    chunk = await r.read(256)
                    assert chunk, "stream ended before 3 tokens"
                    buf += chunk
                w.close()                   # mid-stream disconnect
                deadline = asyncio.get_running_loop().time() + 120
                while True:
                    st = await srv.engine.stats()
                    if st["n_cancelled"] >= 1 and st["active_lanes"] == 0:
                        break
                    assert asyncio.get_running_loop().time() < deadline, \
                        "disconnect never cancelled the request"
                    await asyncio.sleep(0.02)
                assert st["unhandled_exceptions"] == 0
            finally:
                await srv.close()

        _run(go())
        done = list(sched.done.values())
        assert len(done) == 1
        assert done[0].status == RequestStatus.CANCELLED
        audit_controller(sched.engine.ctl)

"""flash_attention / decode_attention vs naive softmax references."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import decode_attention, flash_attention


def naive_attention(q, k, v, causal, kv_mask=None, q_offset=0):
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    qf = q.astype(jnp.float32).reshape(B, Sq, KVH, G, hd)
    s = jnp.einsum("bqkgh,bskh->bqkgs", qf, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    mask = jnp.ones((B, Sq, Skv), bool)
    if kv_mask is not None:
        mask = mask & kv_mask[:, None, :]
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = mask & (jnp.arange(Skv)[None, None, :] <= qpos[None, :, None])
    s = jnp.where(mask[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd)


@pytest.mark.parametrize("Sq,Skv,H,KVH,hd,causal,qc,kc", [
    (16, 16, 4, 4, 32, True, 8, 8),
    (16, 16, 4, 2, 32, True, 4, 8),        # GQA
    (33, 33, 4, 1, 16, True, 8, 16),       # MQA, non-multiple chunks
    (8, 24, 2, 2, 32, False, 4, 8),        # cross-attn (Sq != Skv)
    (64, 64, 8, 2, 64, True, 64, 64),      # single chunk
])
def test_flash_vs_naive(Sq, Skv, H, KVH, hd, causal, qc, kc):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B = 2
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, KVH, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    ref = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_with_kv_mask():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    B, S, H, hd = 2, 32, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    mask = jax.random.bernoulli(ks[3], 0.7, (B, S))
    mask = mask.at[:, 0].set(True)   # keep causal rows non-empty
    out = flash_attention(q, k, v, causal=True, kv_mask=mask,
                          q_chunk=8, kv_chunk=8)
    ref = naive_attention(q, k, v, True, kv_mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_naive_and_relevance():
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 4)
    B, S, H, KVH, hd = 2, 48, 8, 4, 32
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    mask = jax.random.bernoulli(ks[3], 0.6, (B, S)).at[:, 0].set(True)
    out, rel = decode_attention(q, k, v, mask)
    ref = naive_attention(q[:, None], k, v, False, kv_mask=mask)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # Eq. 2: relevance = mean_h |q_h . k_j| (unmasked, unscaled)
    G = H // KVH
    raw = jnp.einsum("bkgh,bskh->bkgs",
                     q.reshape(B, KVH, G, hd).astype(jnp.float32),
                     k.astype(jnp.float32))
    rel_ref = jnp.mean(jnp.abs(raw), axis=(1, 2))
    np.testing.assert_allclose(np.asarray(rel), np.asarray(rel_ref),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_fully_masked_is_zero():
    B, S, H, hd = 1, 8, 2, 16
    q = jnp.ones((B, H, hd))
    k = jnp.ones((B, S, H, hd))
    v = jnp.ones((B, S, H, hd))
    out, _ = decode_attention(q, k, v, jnp.zeros((B, S), bool))
    assert not bool(jnp.isnan(out).any())
    np.testing.assert_array_equal(np.asarray(out), 0.0)

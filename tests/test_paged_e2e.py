"""End-to-end bounded-active paged decode: the jitted paged step + the host
PagedController drive a generation where the device pool is SMALLER than the
context — pages swap out/in through the host store and decoding keeps
producing finite logits (the long_500k serving mode at test scale)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.paging import PagedController
from repro.models import model as MD


def test_paged_decode_with_host_swapping():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.6, k_soft=1.0,
                             recovery_enabled=False)
    cfg = dataclasses.replace(cfg, freeze=fc)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    B, P = 1, 6                       # pool: 6 pages x 8 = 48 tokens resident
    n_steps = 80                      # context grows to 80 > 48 -> must swap
    state = MD.init_paged_decode_state(cfg, B, P)
    ctl = PagedController(cfg=cfg, batch=B, max_active_pages=P)

    step_fn = jax.jit(lambda tok, pos, stp, tail, st: MD.decode_step_paged(
        params, cfg, tok, pos, stp, tail, st))

    tok = jnp.zeros((B,), jnp.int32)
    tail_slot = None
    page = fc.page_size
    for step in range(n_steps):
        pos = step
        if pos % page == 0:
            # new tail page: host-side allocation (swap-out happens in tick)
            pool = {
                "k": np.array(state.k), "v": np.array(state.v),
                "page_table": np.array(state.page_table),
                "slot_mask": np.array(state.slot_mask),
            }
            fstate = {f: np.array(getattr(state.freeze, f))
                      for f in ("c", "d", "frozen", "frozen_at")}
            pool, fstate = ctl.tick(pool, fstate, step)
            tail_slot = ctl.alloc_tail(pool, pos // page)
            assert tail_slot is not None, \
                f"pool exhausted at step {step} (forced freeze failed)"
            state = state._replace(
                k=jnp.asarray(pool["k"]), v=jnp.asarray(pool["v"]),
                page_table=jnp.asarray(pool["page_table"]),
                slot_mask=jnp.asarray(pool["slot_mask"]),
                freeze=type(state.freeze)(
                    *(jnp.asarray(fstate[f])
                      for f in ("c", "d", "frozen", "frozen_at"))))
        logits, state, info = step_fn(tok, jnp.int32(pos), jnp.int32(step),
                                      jnp.asarray(tail_slot, jnp.int32), state)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any()), step
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

    # the context (80 tokens) exceeded the pool (48): swaps must have happened
    assert ctl.n_swap_out > 0, "no page was ever offloaded"
    # reversibility at page level: the host store retains every frozen page
    total_pages_seen = n_steps // page
    resident = int((np.array(state.page_table) >= 0).any(axis=0).sum())
    stored = len({k[2] for k in ctl.store})
    assert resident + stored >= total_pages_seen - 1  # tail may be partial

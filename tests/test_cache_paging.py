"""Cache structures, host offload controller, and paged-pool machinery."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import FreezeConfig
from repro.core.cache import HostOffloadController, KVCache
from repro.core.paging import (
    PagedController, init_page_freeze_state, page_freeze_update, paged_decode_attention, write_tail)
from repro.models.layers import decode_attention


class TestHostOffload:
    def _cache(self, L=2, B=1, S=64):
        key = jax.random.PRNGKey(0)
        k, v = jax.random.normal(key, (2, L, B, S, 2, 8))
        return KVCache(k=k, v=v)

    def test_offload_and_restore_roundtrip(self):
        cache = self._cache()
        orig_k = np.asarray(cache.k).copy()
        ctl = HostOffloadController(page_size=16)
        frozen = np.zeros((2, 1, 64), bool)
        frozen[:, :, 16:32] = True                      # page 1 fully frozen
        cache2 = ctl.sync(cache, frozen)
        assert ctl.offloaded_tokens == 2 * 1 * 16       # L*B*page tokens
        # device slots released (zeroed)
        assert np.asarray(cache2.k)[0, 0, 16:32].max() == 0
        # restore: unfreeze one token of the page
        frozen[:, :, 20] = False
        cache3 = ctl.sync(cache2, frozen)
        assert ctl.offloaded_tokens == 0
        np.testing.assert_array_equal(np.asarray(cache3.k), orig_k)

    def test_partial_page_not_offloaded(self):
        cache = self._cache()
        ctl = HostOffloadController(page_size=16)
        frozen = np.zeros((2, 1, 64), bool)
        frozen[:, :, 16:31] = True                      # 15/16 frozen
        ctl.sync(cache, frozen)
        assert ctl.offloaded_tokens == 0


class TestPagedPool:
    def test_write_tail_and_attention_equivalence(self):
        """Paged attention over a filled pool == flat masked attention."""
        key = jax.random.PRNGKey(1)
        B, P, page, H, hd = 2, 4, 16, 4, 32
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, H, hd))
        kp = jax.random.normal(ks[1], (B, P, page, H, hd))
        vp = jax.random.normal(ks[2], (B, P, page, H, hd))
        sm = jnp.ones((B, P, page), bool)
        out_p, _ = paged_decode_attention(q, kp, vp, sm)
        out_f, _ = decode_attention(
            q, kp.reshape(B, P * page, H, hd), vp.reshape(B, P * page, H, hd),
            jnp.ones((B, P * page), bool))
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_f),
                                   rtol=1e-5, atol=1e-5)

    def test_write_tail_places_token(self):
        B, P, page, KVH, hd = 1, 2, 4, 2, 8
        kp = jnp.zeros((B, P, page, KVH, hd))
        vp = jnp.zeros((B, P, page, KVH, hd))
        sm = jnp.zeros((B, P, page), bool)
        nk = jnp.ones((B, KVH, hd))
        kp, vp, sm = write_tail(kp, vp, sm, nk, nk * 2, jnp.int32(1),
                                jnp.int32(2))
        assert bool(sm[0, 1, 2]) and int(sm.sum()) == 1
        np.testing.assert_array_equal(np.asarray(kp[0, 1, 2]), 1.0)
        np.testing.assert_array_equal(np.asarray(vp[0, 1, 2]), 2.0)
        assert float(kp.sum()) == KVH * hd

    def test_forced_freeze_bounds_pool(self):
        """When the pool saturates, the lowest-relevance page is frozen even
        above tau — device memory stays bounded."""
        cfg = FreezeConfig(window=4, tau=0.0, page_size=4)  # tau=0: nothing flags
        B, P = 1, 4
        st = init_page_freeze_state(B, P)
        page_table = jnp.array([[10, 11, 12, 13]], jnp.int32)  # pool full
        rel = jnp.array([[5.0, 1.0, 7.0, 9.0]])
        new, info = page_freeze_update(st, rel, page_table, jnp.int32(13),
                                       jnp.int32(0), cfg)
        assert bool(info["just_frozen"][0, 1])     # lowest relevance, oldest ok
        assert int(new.d[0, 1]) >= 1

    def test_paged_controller_swap_cycle(self):
        cfg = get_config("llama3-8b-tiny")
        B, P, page = 1, 4, cfg.freeze.page_size
        L = 1
        kvh, hd = 2, 8
        rng = np.random.RandomState(0)
        pool = {
            "k": rng.rand(L, B, P, page, kvh, hd).astype(np.float32),
            "v": rng.rand(L, B, P, page, kvh, hd).astype(np.float32),
            "page_table": np.array([[[0, 1, 2, 3]]], np.int32).reshape(L, B, P),
            "slot_mask": np.ones((L, B, P, page), bool),
        }
        orig_page1 = pool["k"][0, 0, 1].copy()
        fstate = {
            "c": np.zeros((L, B, P), np.int32),
            "d": np.array([[[0, 2, 0, 0]]], np.int32).reshape(L, B, P),
            "frozen": np.array([[[False, True, False, False]]]).reshape(L, B, P),
            "frozen_at": np.zeros((L, B, P), np.int32),
        }
        ctl = PagedController(cfg=cfg, batch=B, max_active_pages=P)
        pool, fstate = ctl.tick(pool, fstate, step=0)
        assert ctl.n_swap_out == 1
        assert pool["page_table"][0, 0, 1] == -1          # slot freed
        # d=2 -> decremented to 1 at first tick; second tick restores
        pool, fstate = ctl.tick(pool, fstate, step=1, reserve_slots=0)
        assert ctl.n_swap_in == 1
        slot = list(pool["page_table"][0, 0]).index(1)
        np.testing.assert_array_equal(pool["k"][0, 0, slot], orig_page1)

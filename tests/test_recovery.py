"""Entropy-guided recovery ladder tests (paper §3.6, implemented)."""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FreezeConfig
from repro.core.freeze import init_freeze_state
from repro.core.recovery import (CALM, FR, RR, SR, WR, init_recovery_state,
                                 recovery_update, token_entropy)


def mk_cfg(**kw):
    base = dict(recovery_enabled=True, entropy_abs_threshold=2.0,
                entropy_rel_factor=100.0, calm_steps_to_deescalate=4)
    base.update(kw)
    return FreezeConfig(**base)


def flat_logits(v=64):
    return jnp.zeros((1, v))          # max entropy = log(v) ~ 4.16


def peaked_logits(v=64):
    z = jnp.full((1, v), -30.0)
    return z.at[0, 0].set(30.0)       # ~zero entropy


def warm(rec, fz, cfg, n=10):
    for s in range(n):
        rec, fz, _ = recovery_update(rec, fz, peaked_logits(), jnp.int32(s), cfg)
    return rec, fz


def test_entropy_values():
    assert float(token_entropy(flat_logits())[0]) > 4.0
    assert float(token_entropy(peaked_logits())[0]) < 0.01


def test_escalation_ladder():
    cfg = mk_cfg()
    fz = init_freeze_state(1, 8)
    rec = init_recovery_state(1)
    rec, fz = warm(rec, fz, cfg)
    levels = []
    for s in range(10, 15):
        rec, fz, info = recovery_update(rec, fz, flat_logits(), jnp.int32(s), cfg)
        levels.append(int(rec.level[0]))
    # SR -> WR -> FR -> RR -> (reset to CALM after RR)
    assert levels[:4] == [SR, WR, FR, RR - RR]  # RR resets to CALM
    # rr_request fired exactly on the 4th spike
    assert levels[3] == CALM


def test_rr_request_and_reset():
    cfg = mk_cfg()
    fz = init_freeze_state(1, 8)
    rec = init_recovery_state(1)
    rec, fz = warm(rec, fz, cfg)
    fired = []
    for s in range(10, 16):
        rec, fz, info = recovery_update(rec, fz, flat_logits(), jnp.int32(s), cfg)
        fired.append(bool(info["rr_request"][0]))
    assert fired[3]                     # 4th consecutive spike triggers RR
    assert sum(fired) >= 1


def test_fr_clears_freeze_state():
    cfg = mk_cfg()
    fz = init_freeze_state(1, 8)._replace(
        frozen=jnp.ones((1, 8), bool), d=jnp.full((1, 8), 3, jnp.int32))
    rec = init_recovery_state(1)
    rec, _ = warm(rec, init_freeze_state(1, 8), cfg)
    rec = rec._replace(level=jnp.array([WR], jnp.int32))  # next spike -> FR
    rec, fz, info = recovery_update(rec, fz, flat_logits(), jnp.int32(20), cfg)
    assert int(rec.level[0]) == FR
    assert not np.asarray(fz.frozen).any()


def test_deescalation_on_calm():
    cfg = mk_cfg(calm_steps_to_deescalate=3)
    fz = init_freeze_state(1, 8)
    rec = init_recovery_state(1)
    rec, fz = warm(rec, fz, cfg)
    rec, fz, _ = recovery_update(rec, fz, flat_logits(), jnp.int32(10), cfg)
    assert int(rec.level[0]) == SR
    for s in range(11, 20):
        rec, fz, _ = recovery_update(rec, fz, peaked_logits(), jnp.int32(s), cfg)
    assert int(rec.level[0]) == CALM


def test_disabled_recovery_never_spikes():
    cfg = mk_cfg(recovery_enabled=False)
    fz = init_freeze_state(1, 8)
    rec = init_recovery_state(1)
    for s in range(20):
        rec, fz, info = recovery_update(rec, fz, flat_logits(), jnp.int32(s), cfg)
        assert not bool(info["spike"].any())
    assert int(rec.level[0]) == CALM


def test_per_sequence_independence():
    """Only the spiking sequence in the batch is intervened."""
    cfg = mk_cfg()
    fz = init_freeze_state(2, 8)._replace(
        frozen=jnp.ones((2, 8), bool), d=jnp.full((2, 8), 9, jnp.int32))
    rec = init_recovery_state(2)
    for s in range(10):
        both = jnp.concatenate([peaked_logits(), peaked_logits()])
        rec, _, _ = recovery_update(rec, init_freeze_state(2, 8), both,
                                    jnp.int32(s), cfg)
    mixed = jnp.concatenate([flat_logits(), peaked_logits()])
    rec, fz, info = recovery_update(rec, fz, mixed, jnp.int32(10), cfg)
    f = np.asarray(fz.frozen)
    assert not f[0].any()     # seq 0 spiked at SR -> d>1 slots unfrozen
    assert f[1].all()         # seq 1 calm -> untouched

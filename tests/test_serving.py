"""Serving engine + scheduler + sampling behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams, sample
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, window=8, history=10**6,
                             tau_mode="quantile", quantile=0.5,
                             recovery_enabled=False, k_soft=1.0, page_size=8)
    cfg = dataclasses.replace(cfg, freeze=fc)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestSampling:
    def test_greedy_is_argmax(self):
        logits = jnp.array([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
        t = sample(logits, jax.random.PRNGKey(0), SamplingParams.greedy())
        np.testing.assert_array_equal(np.asarray(t), [1, 0])

    def test_top_k_restricts_support(self):
        logits = jnp.array([[0.0, 10.0, 9.0, -50.0]])
        p = SamplingParams(temperature=1.0, top_k=2, top_p=1.0)
        for seed in range(20):
            t = int(sample(logits, jax.random.PRNGKey(seed), p)[0])
            assert t in (1, 2)

    def test_top_p_restricts_support(self):
        logits = jnp.array([[10.0, 9.5, -10.0, -10.0]])
        p = SamplingParams(temperature=1.0, top_k=0, top_p=0.8)
        for seed in range(20):
            t = int(sample(logits, jax.random.PRNGKey(seed), p)[0])
            assert t in (0, 1)

    def test_batched_per_lane_support(self):
        """sample_batched applies each row's own params: greedy row, top-k
        row and top-p row restricted exactly as the single-request sampler
        restricts them."""
        from repro.serving.sampling import sample_batched
        logits = jnp.array([[0.0, 5.0, 1.0, -1.0],
                            [0.0, 10.0, 9.0, -50.0],
                            [10.0, 9.5, -10.0, -10.0]])
        temp = jnp.array([0.0, 1.0, 1.0])
        topk = jnp.array([0, 2, 0])
        topp = jnp.array([1.0, 1.0, 0.8])
        for seed in range(20):
            t = np.asarray(sample_batched(logits, jax.random.PRNGKey(seed),
                                          temp, topk, topp))
            assert t[0] == 1                  # greedy = argmax
            assert t[1] in (1, 2)             # top-k=2
            assert t[2] in (0, 1)             # top-p=0.8


class TestEngine:
    def test_generation_with_compression(self, tiny):
        cfg, params = tiny
        eng = Engine(cfg, params, max_seq=200)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                              0, cfg.vocab_size)}
        res = eng.generate(batch, 120, SamplingParams(temperature=0.7))
        assert res.tokens.shape == (2, 120)
        assert res.compression > 0.3          # freeze actually engaged
        # oscillation: active cache is not monotone (rolling restore works)
        d = np.diff(res.active_kv)
        assert (d > 0).any() and (d < 0).any()
        # offload engaged at least once (page-batched host transfers)
        assert max(res.offloaded_tokens) > 0

    def test_freeze_disabled_baseline(self, tiny):
        cfg, params = tiny
        eng = Engine(cfg, params, max_seq=120, enable_freeze=False)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 16),
                                              0, cfg.vocab_size)}
        res = eng.generate(batch, 60, SamplingParams.greedy())
        assert res.compression == 0.0
        np.testing.assert_array_equal(np.diff(res.active_kv), 1.0)  # linear

    def test_greedy_freeze_off_deterministic(self, tiny):
        cfg, params = tiny
        eng = Engine(cfg, params, max_seq=96, enable_freeze=False)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (1, 16),
                                              0, cfg.vocab_size)}
        r1 = eng.generate(batch, 40, SamplingParams.greedy())
        r2 = eng.generate(batch, 40, SamplingParams.greedy())
        np.testing.assert_array_equal(r1.tokens, r2.tokens)

    def test_rewind_telemetry_stays_aligned(self, tiny):
        """Regression: the Rewalk-Regeneration continue path used to skip
        the offloaded_tokens append, so after any rewind the telemetry
        lists drifted out of alignment."""
        cfg, params = tiny
        fc = dataclasses.replace(cfg.freeze, recovery_enabled=True,
                                 entropy_abs_threshold=0.0)
        eng = Engine(dataclasses.replace(cfg, freeze=fc), params, max_seq=160)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (1, 16),
                                              0, cfg.vocab_size)}
        res = eng.generate(batch, 48, SamplingParams(temperature=0.7))
        assert res.rewinds >= 1
        n = len(res.active_kv)
        assert n > 47     # rewind steps add loop iterations beyond n_tokens-1
        assert len(res.frozen_kv) == len(res.total_kv) \
            == len(res.offloaded_tokens) == len(res.entropy) == n


class TestScheduler:
    def test_fifo_batches(self, tiny):
        cfg, params = tiny
        eng = Engine(cfg, params, max_seq=64, enable_freeze=False)
        sched = Scheduler(eng, batch_size=2)
        rng = np.random.RandomState(0)
        uids = [sched.submit(rng.randint(0, cfg.vocab_size, size=8), 10)
                for _ in range(3)]
        sched.run()
        assert set(uids) <= set(sched.done)
        for u in uids:
            assert sched.done[u].result.shape == (10,)

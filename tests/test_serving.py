"""Serving engine + scheduler + sampling behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams, sample
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, window=8, history=10**6,
                             tau_mode="quantile", quantile=0.5,
                             recovery_enabled=False, k_soft=1.0, page_size=8)
    cfg = dataclasses.replace(cfg, freeze=fc)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestSampling:
    def test_greedy_is_argmax(self):
        logits = jnp.array([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
        t = sample(logits, jax.random.PRNGKey(0), SamplingParams.greedy())
        np.testing.assert_array_equal(np.asarray(t), [1, 0])

    def test_top_k_restricts_support(self):
        logits = jnp.array([[0.0, 10.0, 9.0, -50.0]])
        p = SamplingParams(temperature=1.0, top_k=2, top_p=1.0)
        for seed in range(20):
            t = int(sample(logits, jax.random.PRNGKey(seed), p)[0])
            assert t in (1, 2)

    def test_top_p_restricts_support(self):
        logits = jnp.array([[10.0, 9.5, -10.0, -10.0]])
        p = SamplingParams(temperature=1.0, top_k=0, top_p=0.8)
        for seed in range(20):
            t = int(sample(logits, jax.random.PRNGKey(seed), p)[0])
            assert t in (0, 1)


class TestEngine:
    def test_generation_with_compression(self, tiny):
        cfg, params = tiny
        eng = Engine(cfg, params, max_seq=200)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                              0, cfg.vocab_size)}
        res = eng.generate(batch, 120, SamplingParams(temperature=0.7))
        assert res.tokens.shape == (2, 120)
        assert res.compression > 0.3          # freeze actually engaged
        # oscillation: active cache is not monotone (rolling restore works)
        d = np.diff(res.active_kv)
        assert (d > 0).any() and (d < 0).any()
        # offload engaged at least once (page-batched host transfers)
        assert max(res.offloaded_tokens) > 0

    def test_freeze_disabled_baseline(self, tiny):
        cfg, params = tiny
        eng = Engine(cfg, params, max_seq=120, enable_freeze=False)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 16),
                                              0, cfg.vocab_size)}
        res = eng.generate(batch, 60, SamplingParams.greedy())
        assert res.compression == 0.0
        np.testing.assert_array_equal(np.diff(res.active_kv), 1.0)  # linear

    def test_greedy_freeze_off_deterministic(self, tiny):
        cfg, params = tiny
        eng = Engine(cfg, params, max_seq=96, enable_freeze=False)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (1, 16),
                                              0, cfg.vocab_size)}
        r1 = eng.generate(batch, 40, SamplingParams.greedy())
        r2 = eng.generate(batch, 40, SamplingParams.greedy())
        np.testing.assert_array_equal(r1.tokens, r2.tokens)


class TestScheduler:
    def test_fifo_batches(self, tiny):
        cfg, params = tiny
        eng = Engine(cfg, params, max_seq=64, enable_freeze=False)
        sched = Scheduler(eng, batch_size=2)
        rng = np.random.RandomState(0)
        uids = [sched.submit(rng.randint(0, cfg.vocab_size, size=8), 10)
                for _ in range(3)]
        sched.run()
        assert set(uids) <= set(sched.done)
        for u in uids:
            assert sched.done[u].result.shape == (10,)

"""Replica router: SLO-aware placement, heartbeat health-checking,
checkpoint-based failover and freeze-native lane migration across
replicas (serving/router.py).

Parity methodology: greedy + f32 + ``burst_prefill=False`` makes every
request's token stream a pure function of the request itself, so an
uninterrupted solo run is an exact reference for any placement,
migration or recovery path.  Recovery is OFF, so the committed-token
journal is append-only and the journal-prefix check is exact."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.analysis.invariants import audit_controller
from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import PagedContinuousEngine, Request
from repro.serving.faults import FaultInjector, FaultPlan, FaultSchedule
from repro.serving.router import ReplicaRouter
from repro.serving.sampling import SamplingParams


@pytest.fixture(scope="module")
def tiny_f32():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.5, k_soft=1.0,
                             recovery_enabled=False)
    cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def mk_engine(cfg, params):
    return PagedContinuousEngine(cfg, params, max_seq=128, n_lanes=2,
                                 max_active_pages=4, prefill_chunk=8,
                                 burst_prefill=False)


def mk_router(cfg, params, n=3, **kw):
    return ReplicaRouter([mk_engine(cfg, params) for _ in range(n)], **kw)


@pytest.fixture(scope="module")
def solo_ref(tiny_f32):
    """Memoized uninterrupted per-request reference tokens (one shared
    engine: lane trajectories are per-lane pure and the jit caches are
    reused)."""
    cfg, params = tiny_f32
    eng = mk_engine(cfg, params)
    cache = {}

    def ref(prompt, n_tokens):
        key = (prompt.tobytes(), n_tokens)
        if key not in cache:
            req = Request(1, prompt, n_tokens, SamplingParams.greedy())
            eng.admit(req)
            while req.result is None:
                eng.step_once()
            cache[key] = np.asarray(req.result)
        return cache[key]
    return ref


def mixed_trace(cfg):
    """Fixed mixed-SLO trace (same across soak seeds so the solo
    references are computed once): background priority 5 + deadlined
    foreground priority 0."""
    rng = np.random.RandomState(0)
    reqs = []
    for _ in range(4):
        reqs.append(dict(
            prompt=rng.randint(0, cfg.vocab_size, size=16).astype(np.int32),
            n_tokens=int(rng.randint(22, 30)),
            sampling=SamplingParams.greedy(), priority=5))
    for _ in range(2):
        reqs.append(dict(
            prompt=rng.randint(0, cfg.vocab_size, size=10).astype(np.int32),
            n_tokens=8, sampling=SamplingParams.greedy(), priority=0,
            deadline_ms=60_000.0))
    return reqs


def assert_parity_and_invariants(router, solo_ref, tag=""):
    assert router.report()["lost_requests"] == 0, tag
    for uid, req in router.requests.items():
        want = solo_ref(np.asarray(req.prompt, np.int32), req.n_tokens)
        np.testing.assert_array_equal(
            want, np.asarray(router.done[uid].result),
            err_msg=f"{tag} uid={uid}")
    # journal-at-failure must be a prefix of the final tokens (recovery
    # off -> the journal is append-only)
    for uid, j in router.journal_at_fail.items():
        assert list(np.asarray(router.done[uid].result))[:len(j)] \
            == list(j), f"{tag} uid={uid}"
    # exact stash/exported-bytes accounting on every survivor
    for r in router.replicas:
        if r.alive:
            audit_controller(r.engine.ctl)


class TestPlacement:
    def test_submissions_spread_over_idle_replicas(self, tiny_f32):
        cfg, params = tiny_f32
        router = mk_router(cfg, params)
        rng = np.random.RandomState(1)
        for _ in range(3):
            router.submit(rng.randint(0, cfg.vocab_size,
                                      size=8).astype(np.int32), 4,
                          SamplingParams.greedy())
        # each landed on a different (previously least-loaded) replica
        assert sorted(router.placed.values()) == [0, 1, 2]
        router.run()
        assert router.report()["lost_requests"] == 0

    def test_report_shape(self, tiny_f32):
        cfg, params = tiny_f32
        router = mk_router(cfg, params, n=2)
        rep = router.report()
        assert rep["n_replicas"] == 2 and rep["n_live"] == 2
        assert rep["lost_requests"] == 0 and rep["submitted"] == 0
        assert len(rep["replicas"]) == 2
        assert rep["replicas"][0]["health"]["n_active_lanes"] == 0


class TestFailover:
    def test_mid_trace_kill_zero_loss_token_parity(self, tiny_f32,
                                                   solo_ref):
        """Crash a replica mid-decode: every request still completes,
        checkpoint-recovered lanes resume token-identically on a
        survivor, and the journal/accounting audits hold."""
        cfg, params = tiny_f32
        router = mk_router(cfg, params, checkpoint_every=4,
                           kill_at=(0, 14))
        for kw in mixed_trace(cfg):
            router.submit(**kw)
        router.run()
        rep = router.report()
        assert rep["n_failovers"] == 1
        assert not router.replicas[0].alive
        assert router.replicas[0].fence_reason == "crash"
        # the kill landed after two checkpoint cadences, so at least one
        # in-flight lane recovered from a checkpoint
        assert rep["recovered_with_checkpoint"] >= 1
        assert_parity_and_invariants(router, solo_ref, "kill")

    def test_transient_hang_recovers_without_failover(self, tiny_f32):
        """A hang shorter than the heartbeat threshold must stall the
        replica, then recover in place — no failover, nothing moved."""
        cfg, params = tiny_f32
        router = mk_router(cfg, params, n=2, hang_threshold=4)
        router.replicas[0].injector = FaultInjector(FaultSchedule(
            explicit={("replica_hang", 4): FaultPlan(kind="hang",
                                                     attempts=2)}))
        rng = np.random.RandomState(2)
        for _ in range(3):
            router.submit(rng.randint(0, cfg.vocab_size,
                                      size=12).astype(np.int32), 12,
                          SamplingParams.greedy())
        router.run()
        rep = router.report()
        assert rep["n_failovers"] == 0 and rep["lost_requests"] == 0
        assert router.replicas[0].n_hang_ticks == 2
        assert all(r.alive for r in router.replicas)

    def test_hard_hang_fails_over_via_heartbeat(self, tiny_f32, solo_ref):
        """A hang past the threshold: the heartbeat (frozen wall_step
        with work queued) declares the replica dead and its work
        migrates — still zero loss, still token-identical."""
        cfg, params = tiny_f32
        router = mk_router(cfg, params, checkpoint_every=3,
                           hang_threshold=3)
        router.replicas[1].injector = FaultInjector(FaultSchedule(
            explicit={("replica_hang", 8): FaultPlan(kind="hang",
                                                     attempts=50)}))
        for kw in mixed_trace(cfg):
            router.submit(**kw)
        router.run()
        rep = router.report()
        assert rep["n_failovers"] == 1
        assert router.replicas[1].fence_reason == "hang"
        assert_parity_and_invariants(router, solo_ref, "hang")


class TestDrainRebalance:
    def test_drain_replica_migrates_live_load(self, tiny_f32, solo_ref):
        """drain_replica moves a live replica's queue + running lanes to
        the others through the suspend/adopt path; the drained replica
        ends empty but stays alive and placeable."""
        cfg, params = tiny_f32
        router = mk_router(cfg, params)
        for kw in mixed_trace(cfg):
            router.submit(**kw)
        for _ in range(10):
            router.step()
        victim = router.replicas[0]
        had_work = victim.busy
        moved = router.drain_replica(0)
        assert had_work and moved > 0
        assert all(l.request is None for l in victim.engine.lanes)
        assert not victim.sched.queue and victim.alive
        router.run()
        assert_parity_and_invariants(router, solo_ref, "drain")

    def test_rebalance_moves_queue_toward_idle_replica(self, tiny_f32):
        """Pile every request onto one replica's queue (adopt-level, as
        a failover would): the per-tick rebalance must move queued work
        to the idle replicas instead of letting them sit empty."""
        cfg, params = tiny_f32
        router = mk_router(cfg, params)
        rng = np.random.RandomState(3)
        for _ in range(6):
            router.submit(rng.randint(0, cfg.vocab_size,
                                      size=10).astype(np.int32), 10,
                          SamplingParams.greedy())
        # forcibly stack everything on replica 0
        for rid in (1, 2):
            for item, row in router.replicas[rid].sched.extract_pending():
                router.replicas[0].sched.adopt(item, row)
        router.run()
        rep = router.report()
        assert rep["lost_requests"] == 0
        assert rep["n_rebalanced"] > 0


def _soak(tiny_f32, solo_ref, seed):
    """One randomized kill-point run: seeded random victim + tick, mixed
    trace, zero lost + parity (checkpointed AND re-prefilled recoveries)
    + journal + exact accounting."""
    cfg, params = tiny_f32
    rng = np.random.RandomState(1000 + seed)
    kill = (int(rng.randint(0, 3)), int(rng.randint(4, 22)))
    router = mk_router(cfg, params, checkpoint_every=3 + seed % 3,
                       kill_at=kill)
    for kw in mixed_trace(cfg):
        router.submit(**kw)
    router.run()
    rep = router.report()
    assert rep["n_failovers"] == 1, f"seed={seed} kill={kill}"
    assert_parity_and_invariants(router, solo_ref,
                                 f"seed={seed} kill={kill}")


class TestKillPointSoak:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_randomized_kill_point(self, tiny_f32, solo_ref, seed):
        _soak(tiny_f32, solo_ref, seed)

    @pytest.mark.slow
    @pytest.mark.parametrize("seed", [2, 3, 4, 5])
    def test_randomized_kill_point_soak(self, tiny_f32, solo_ref, seed):
        _soak(tiny_f32, solo_ref, seed)

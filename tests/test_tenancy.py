"""Multi-tenant serving policy: WFQ weighted fair sharing, per-tenant
lane/rate quotas (serving/tenancy.py), the scheduler's preemption cost
model, the ``ServingConfig`` construction surface, and the
``repro.serving`` facade."""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving import config as serving_config_mod
from repro.serving.config import ServingConfig
from repro.serving.engine import (ContinuousEngine, PagedContinuousEngine,
                                  RequestStatus)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler
from repro.serving.tenancy import TenancyController, TenantConfig


@pytest.fixture(scope="module")
def tiny_f32():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.5, k_soft=1.0,
                             recovery_enabled=False)
    cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def paged_engine(cfg, params, n_lanes=2, pages=4, max_seq=128):
    return PagedContinuousEngine(cfg, params, serving=ServingConfig(
        max_seq=max_seq, n_lanes=n_lanes, max_active_pages=pages,
        prefill_chunk=8, burst_prefill=False))


class TestTenancyController:
    """Pure host-side policy unit tests (fake clock, no engine)."""

    def _ctl(self, *tenants, clock=None):
        kw = {"clock": clock} if clock is not None else {}
        return TenancyController(tenants=tenants, **kw)

    def test_vtime_advances_inversely_with_weight(self):
        ctl = self._ctl(TenantConfig("heavy", weight=2.0),
                        TenantConfig("light", weight=1.0))
        ctl.note_admit("heavy", 1)
        ctl.note_admit("light", 2)
        ctl.note_progress("heavy", 1, 20)
        ctl.note_progress("light", 2, 20)
        assert ctl.vtime("heavy") == pytest.approx(10.0)
        assert ctl.vtime("light") == pytest.approx(20.0)
        snap = ctl.snapshot()
        assert snap["heavy"]["goodput_tokens"] == 20
        assert snap["light"]["goodput_tokens"] == 20

    def test_idle_tenant_snaps_to_active_floor(self):
        """A tenant returning from idle must not spend banked vtime
        credit against currently-backlogged tenants."""
        ctl = self._ctl(TenantConfig("busy"), TenantConfig("idle"))
        ctl.note_admit("busy", 1)
        ctl.note_progress("busy", 1, 30)
        assert ctl.vtime("idle") == 0.0
        ctl.note_enqueue("idle")
        assert ctl.vtime("idle") == pytest.approx(30.0)
        # already-active tenants are never snapped (their vtime is live)
        ctl.note_admit("idle", 2)
        ctl.note_progress("idle", 2, 10)
        ctl.note_enqueue("idle")
        assert ctl.vtime("idle") == pytest.approx(40.0)

    def test_lane_cap_blocks_and_releases(self):
        ctl = self._ctl(TenantConfig("t", max_lanes=1))
        assert ctl.may_admit("t")
        ctl.note_admit("t", 1)
        assert not ctl.may_admit("t")
        assert ctl.snapshot()["t"]["throttled_lanes"] == 1
        ctl.note_release("t", 1)      # suspended: lane slot frees
        assert ctl.may_admit("t")

    def test_token_bucket_rate_cap(self):
        t = [0.0]
        ctl = self._ctl(TenantConfig("t", tokens_per_s=10.0),
                        clock=lambda: t[0])
        ctl.note_admit("t", 1)
        ctl.note_progress("t", 1, 10)        # drains the full burst
        assert not ctl.may_admit("t")
        assert ctl.snapshot()["t"]["throttled_rate"] == 1
        t[0] = 0.5                           # half a second refills 5
        assert ctl.may_admit("t")
        assert ctl.snapshot()["t"]["bucket"] == pytest.approx(5.0)

    def test_rewind_progress_is_not_refunded(self):
        """Rewalk shrinks the committed count; the lane-time was spent, so
        the charge stays and only net-new tokens charge later."""
        ctl = self._ctl(TenantConfig("t"))
        ctl.note_admit("t", 1)
        ctl.note_progress("t", 1, 10)
        ctl.note_progress("t", 1, 6)         # rewind to 6: no refund
        assert ctl.vtime("t") == pytest.approx(10.0)
        ctl.note_progress("t", 1, 12)        # regrow past the charge mark
        assert ctl.vtime("t") == pytest.approx(12.0)
        assert ctl.snapshot()["t"]["goodput_tokens"] == 12

    def test_untenanted_bypasses_everything(self):
        ctl = self._ctl(TenantConfig("t", max_lanes=0, tokens_per_s=0.001))
        assert ctl.may_admit(None)
        assert ctl.vtime(None) == -float("inf")
        ctl.note_admit(None, 1)
        ctl.note_progress(None, 1, 100)
        ctl.note_done(None, 1, 100)
        assert ctl.snapshot() == {"t": ctl.snapshot()["t"]}

    def test_done_and_cancel_counters(self):
        ctl = self._ctl(TenantConfig("t"))
        ctl.note_admit("t", 1)
        ctl.note_admit("t", 2)
        ctl.note_done("t", 1, 8)
        ctl.note_done("t", 2, 3, cancelled=True)
        snap = ctl.snapshot()["t"]
        assert snap["completed"] == 1 and snap["cancelled"] == 1
        assert snap["active_lanes"] == 0
        assert snap["goodput_tokens"] == 11

    def test_unregistered_tenant_uses_default_template(self):
        ctl = TenancyController(
            default=TenantConfig("tpl", weight=2.0, max_lanes=1))
        ctl.note_admit("new", 1)
        assert not ctl.may_admit("new")      # template's lane cap applies
        ctl.note_progress("new", 1, 10)
        assert ctl.vtime("new") == pytest.approx(5.0)


class TestSchedulerTenancy:
    def _sched(self, tiny_f32, tenants, clock=None, **kw):
        cfg, params = tiny_f32
        eng = paged_engine(cfg, params)
        ckw = {"clock": clock} if clock is not None else {}
        ten = TenancyController(tenants=tenants, **ckw)
        return Scheduler(eng, tenancy=ten, **ckw, **kw)

    def test_wfq_pop_order_tracks_vtime(self, tiny_f32):
        """Within a priority class, _pop_admissible picks the backlogged
        tenant with the smallest virtual time — not submission order."""
        sched = self._sched(tiny_f32, [TenantConfig("gold", weight=3.0),
                                       TenantConfig("bronze", weight=1.0)])
        rng = np.random.RandomState(0)
        ten = sched.tenancy
        for t in ("gold", "bronze", "gold", "bronze", "gold", "bronze"):
            sched.submit(rng.randint(0, 32, size=4), 4,
                         SamplingParams.greedy(), tenant=t)
        order = []
        uid = 100
        while sched.queue:
            item = sched._pop_admissible()
            order.append(item.tenant)
            # simulate serving 12 tokens to the popped tenant
            uid += 1
            ten.note_admit(item.tenant, uid)
            ten.note_progress(item.tenant, uid, 12)
            ten.note_done(item.tenant, uid, 12)
        # vtime per pop: gold +4, bronze +12 -> gold is picked 3x as often
        # until its backlog runs out: G B G G G B B B... with 3 each the
        # exact order is G(0) B(0) G(4) G(8) B(12)... seq breaks the 0-0 tie
        assert order == ["gold", "bronze", "gold", "gold",
                         "bronze", "bronze"]

    def test_rate_capped_hog_cannot_starve_peer(self, tiny_f32):
        """A hog whose token bucket is exhausted stops being admitted (the
        frozen fake clock never refills it) while the uncapped tenant's
        whole backlog completes."""
        t = [0.0]
        sched = self._sched(
            tiny_f32,
            [TenantConfig("hog", tokens_per_s=1.0, burst_tokens=1.0),
             TenantConfig("ok")],
            clock=lambda: t[0])
        rng = np.random.RandomState(1)
        hog = [sched.submit(rng.randint(0, 32, size=8), 6,
                            SamplingParams.greedy(), tenant="hog")
               for _ in range(3)]
        ok = [sched.submit(rng.randint(0, 32, size=8), 6,
                           SamplingParams.greedy(), tenant="ok")
              for _ in range(3)]
        sched.run()
        for u in ok:
            assert sched.done[u].result.shape == (6,)
        snap = sched.tenancy.snapshot()
        # both free lanes seat a hog before any committed token drains the
        # bucket (the soft limit never throttles mid-request), so exactly
        # two hog requests complete; the third is throttled forever
        assert snap["hog"]["throttled_rate"] > 0
        assert sum(u in sched.done for u in hog) == 2
        assert len(sched.queue) == 1

    def test_lane_cap_bounds_concurrency(self, tiny_f32):
        """max_lanes=1 on a 2-lane engine: the capped tenant never holds
        both lanes even with a deep backlog, and the spare lane serves the
        other tenant."""
        sched = self._sched(tiny_f32, [TenantConfig("capped", max_lanes=1),
                                       TenantConfig("free")])
        rng = np.random.RandomState(2)
        for _ in range(3):
            sched.submit(rng.randint(0, 32, size=8), 8,
                         SamplingParams.greedy(), tenant="capped")
        sched.submit(rng.randint(0, 32, size=8), 8,
                     SamplingParams.greedy(), tenant="free")
        eng = sched.engine
        while sched.queue or sched.busy:
            sched.step()
            capped = sum(1 for l in eng.lanes if l.request is not None
                         and l.request.tenant == "capped")
            assert capped <= 1
        assert sched.tenancy.snapshot()["capped"]["throttled_lanes"] > 0
        assert len(sched.done) == 4

    def test_preempt_cost_model_gates_churn(self, tiny_f32):
        """With measured suspend/resume EMAs dwarfing the predicted queue
        wait, a deadline-missing head skips preemption (pure churn); with
        negligible cost the same situation preempts."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(3)
        for cost, expect_skip in ((1e6, True), (1e-9, False)):
            eng = paged_engine(cfg, params)
            sched = Scheduler(eng)
            assert sched.preempt_cost_s() == 0.0   # unmeasured: never veto
            for _ in range(2):
                sched.submit(rng.randint(0, 32, size=10), 48,
                             SamplingParams.greedy(), priority=5)
            for _ in range(10):                    # hogs mid-flight
                sched.step()
            sched._suspend_s = sched._resume_s = cost
            assert sched.preempt_cost_s() == pytest.approx(2 * cost)
            sched.submit(rng.randint(0, 32, size=8), 6,
                         SamplingParams.greedy(), priority=0,
                         deadline_ms=150.0)
            sched.run()
            if expect_skip:
                assert sched.n_preempt_skipped_cost >= 1
                assert sched.n_preemptions == 0
            else:
                assert sched.n_preemptions >= 1
            assert len(sched.done) == 3            # nobody lost either way

    def test_untenanted_path_is_unchanged(self, tiny_f32):
        """tenancy=None and tenant=None through a TenancyController must
        serve identically (greedy) — the pre-tenancy behaviour is the
        baseline contract."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(4)
        prompts = [rng.randint(0, cfg.vocab_size, size=10) for _ in range(4)]
        results = []
        for tenancy in (None, TenancyController()):
            sched = Scheduler(paged_engine(cfg, params), tenancy=tenancy)
            uids = [sched.submit(p, 8, SamplingParams.greedy())
                    for p in prompts]
            sched.run()
            results.append([sched.done[u].result.tolist() for u in uids])
        assert results[0] == results[1]


class TestServingConfig:
    def test_legacy_kwargs_warn_once(self, tiny_f32):
        cfg, params = tiny_f32
        serving_config_mod._LEGACY_WARNED = False
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            ContinuousEngine(cfg, params, max_seq=32, n_lanes=1)
            ContinuousEngine(cfg, params, max_seq=32, n_lanes=1)
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)
               and "ServingConfig" in str(x.message)]
        assert len(dep) == 1

    def test_mixing_surfaces_raises(self, tiny_f32):
        cfg, params = tiny_f32
        sv = ServingConfig(max_seq=32, n_lanes=1)
        with pytest.raises(TypeError, match="not both"):
            ContinuousEngine(cfg, params, serving=sv, max_seq=32)
        with pytest.raises(TypeError, match="not both"):
            ContinuousEngine(cfg, params, serving=sv, async_pipeline=False)

    def test_unknown_kwarg_raises(self, tiny_f32):
        cfg, params = tiny_f32
        with pytest.raises(TypeError, match="unknown engine kwarg"):
            ContinuousEngine(cfg, params, max_seq=32, n_lanes=1,
                             definitely_not_a_knob=1)

    def test_paged_requires_max_active_pages(self, tiny_f32):
        cfg, params = tiny_f32
        with pytest.raises(TypeError, match="max_active_pages"):
            PagedContinuousEngine(cfg, params, serving=ServingConfig(
                max_seq=32, n_lanes=1))

    def test_config_and_legacy_build_identical_engines(self, tiny_f32):
        cfg, params = tiny_f32
        sv = ServingConfig(max_seq=64, n_lanes=2, max_active_pages=4,
                           prefill_chunk=8, burst_prefill=False)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            a = PagedContinuousEngine(cfg, params, max_seq=64, n_lanes=2,
                                      max_active_pages=4, prefill_chunk=8,
                                      burst_prefill=False)
        b = PagedContinuousEngine(cfg, params, serving=sv)
        assert a.serving == b.serving


class TestFacade:
    def test_facade_exports_resolve(self):
        import repro.serving as S
        for name in S.__all__:
            assert getattr(S, name) is not None, name
        assert S.Scheduler is Scheduler
        assert S.TenancyController is TenancyController
        assert S.ServingConfig is ServingConfig

    def test_request_status_is_str_compatible(self):
        """The enum replaced ad-hoc strings; every sink that compared,
        serialized or sorted the old strings must keep working."""
        assert RequestStatus.COMPLETED == "completed"
        assert str(RequestStatus.CANCELLED) == "cancelled"
        assert json.dumps(RequestStatus.SHED) == '"shed"'
        assert sorted([RequestStatus.SHED, RequestStatus.COMPLETED]) \
            == [RequestStatus.COMPLETED, RequestStatus.SHED]

"""Sharding rules: divisibility fallbacks and spec structure (AbstractMesh —
no devices needed)."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import abstract_mesh
from repro.models import model as MD
from repro.sharding import rules as RU

SP = abstract_mesh((16, 16), ("data", "model"))
MP = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def leaves_with_paths(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, P))[0]


def find(specs, *frags):
    """Match path fragments; a fragment starting with '=' requires an exact
    path-component match (so 'embed' doesn't also hit 'unembed')."""
    out = []
    for path, spec in leaves_with_paths(specs):
        comps = [str(getattr(p, "name", getattr(p, "key", p))) for p in path]
        name = "/".join(comps)
        ok = all((f[1:] in comps) if f.startswith("=") else (f in name)
                 for f in frags)
        if ok:
            out.append((name, spec))
    assert out, frags
    return out


class TestParamSpecs:
    def test_llama3_train_2d(self):
        specs = RU.param_pspecs(SP, MD.schema(get_config("llama3-8b")))
        (_, wq), = find(specs, "blocks", "l0", "attn", "wq")
        assert wq == P(None, "data", "model", None)        # stacked + 2D
        (_, emb), = find(specs, "=embed")
        assert emb == P("model", "data")

    def test_llama4_heads_fall_back_to_replicated(self):
        """40 q-heads % 16 != 0 -> heads dim replicated; FFN still sharded."""
        specs = RU.param_pspecs(SP, MD.schema(get_config("llama4-scout-17b-a16e")))
        (_, wq), = find(specs, "l0", "attn", "wq")
        assert wq[2] is None                               # heads replicated
        (_, wup), = find(specs, "l0", "ffn", "w_up")
        assert wup[1] == "model"                           # experts sharded

    def test_granite_mqa_kv_replicated(self):
        specs = RU.param_pspecs(SP, MD.schema(get_config("granite-20b")))
        (_, wk), = find(specs, "l0", "attn", "wk")
        assert wk[2] is None                               # kv=1 replicated
        (_, wq), = find(specs, "l0", "attn", "wq")
        assert wq[2] == "model"                            # 48 q heads shard

    def test_whisper_vocab_padded_shards(self):
        cfg = get_config("whisper-base")
        assert cfg.vocab_size == 51865 and cfg.padded_vocab == 51968
        assert cfg.padded_vocab % 16 == 0
        specs = RU.param_pspecs(SP, MD.schema(cfg))
        (_, emb), = find(specs, "=embed")
        assert emb[0] == "model"

    def test_multipod_fsdp_over_pod_and_data(self):
        specs = RU.param_pspecs(MP, MD.schema(get_config("mistral-large-123b")))
        (_, emb), = find(specs, "=embed")
        assert emb == P("model", ("pod", "data"))

    def test_infer_mode_drops_fsdp(self):
        specs = RU.param_pspecs(SP, MD.schema(get_config("llama3-8b")),
                                mode="infer")
        (_, emb), = find(specs, "=embed")
        assert emb == P("model", None)

    def test_param_bytes_estimate(self):
        sch = MD.schema(get_config("llama3-8b"))
        b_train = RU.param_bytes_per_chip(SP, sch, "train")
        b_infer = RU.param_bytes_per_chip(SP, sch, "infer")
        total = 2 * sum(int(np.prod(p.shape)) for p in
                        jax.tree_util.tree_leaves(
                            sch, is_leaf=lambda x: hasattr(x, "axes")))
        assert b_train < b_infer <= total
        assert b_infer < 2 * 2**30                         # ~1GB/chip @ 8B


class TestStateSpecs:
    def test_cache_seq_sharded_over_model(self):
        cfg = get_config("llama3-8b")
        state = jax.eval_shape(lambda: MD.init_decode_state(cfg, 128, 32768))
        specs = RU.decode_state_pspecs(cfg, SP, state)
        assert specs.cache_k == P(None, "data", "model", None, None)
        assert specs.freeze.c == P(None, "data", "model")

    def test_batch1_replicates(self):
        cfg = get_config("llama3-8b")
        state = jax.eval_shape(lambda: MD.init_decode_state(cfg, 1, 1024))
        specs = RU.decode_state_pspecs(cfg, SP, state)
        assert specs.cache_k[1] is None                    # B=1: no data shard

    def test_paged_pool_sharded(self):
        cfg = get_config("jamba-1.5-large-398b")
        state = jax.eval_shape(lambda: MD.init_paged_decode_state(cfg, 1, 1024))
        specs = RU.decode_state_pspecs(cfg, SP, state)
        assert specs.k == P(None, None, "model", None, None, None)
        assert specs.mamba["ssm"][2] == "model"            # d_inner sharded

"""Property-based tests for core.quant — hypothesis-driven widening of the
deterministic seeded checks in tests/test_quant.py.

The whole module skips when ``hypothesis`` is unavailable (the pinned CI
image does not ship it, and the repo policy is to gate — never install —
missing dependencies).  Coverage does not regress on skip: the seeded
sweeps in tests/test_quant.py exercise the same invariants on fixed
RandomState pages, so these tests only *widen* the searched page space
when the library happens to be present.

Properties (docs/quantization.md documents the envelope):

* **round-trip bound** — for any page at any magnitude,
  ``|x - dequantize(quantize(x))| <= roundtrip_bound(x)`` elementwise
  (int8: half a quantization step ``scale/2``; fp8 e4m3: half-ulp
  relative plus a subnormal floor),
* **scale correctness** — all-zero heads get scale exactly 1.0 with an
  all-zero payload (dequant exact); a single outlier pins its head's
  scale to ``|outlier| / qmax`` and survives the round trip to within
  float32 arithmetic; extreme magnitudes (1e-20 .. 1e20) keep scales
  finite and the bound intact,
* **no double quantization** — any freeze->stash->thaw->rewind cycle
  (quantize once, then arbitrarily interleaved pool-dtype installs and
  ``narrow_payload`` stashes) leaves the payload BYTE-stable: the error
  never compounds past the single round-trip bound.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings                # noqa: E402
from hypothesis import strategies as st               # noqa: E402

from repro.core import quant                          # noqa: E402

MODES = [quant.QUANT_INT8] + (
    [quant.QUANT_FP8] if quant.fp8_supported() else [])
_QMAX = {quant.QUANT_INT8: 127.0, quant.QUANT_FP8: 448.0}

# the device pool dtypes a quantized payload round-trips through
POOL_DTYPES = [np.float32]
try:                                                  # bf16 pool, if present
    from ml_dtypes import bfloat16 as _BF16
    POOL_DTYPES.append(_BF16)
except ImportError:                                   # pragma: no cover
    pass


def _page(seed: int, mag: int, page=8, kvh=4, hd=8) -> np.ndarray:
    rs = np.random.RandomState(seed)
    return (rs.standard_normal((page, kvh, hd)) * 10.0 ** mag
            ).astype(np.float32)


@given(seed=st.integers(0, 2**31 - 1), mag=st.integers(-20, 20),
       mode=st.sampled_from(MODES))
@settings(max_examples=200, deadline=None)
def test_roundtrip_error_within_bound(seed, mag, mode):
    page = _page(seed, mag)
    payload, sc = quant.quantize_page(page, mode)
    assert payload.dtype.itemsize == 1
    assert np.isfinite(sc).all()
    dq = quant.dequantize_page(payload, sc)
    bound = quant.roundtrip_bound(page, mode, sc)
    assert (np.abs(page - dq) <= bound).all()


@given(seed=st.integers(0, 2**31 - 1), mode=st.sampled_from(MODES),
       zero_head=st.integers(0, 3))
@settings(max_examples=100, deadline=None)
def test_all_zero_head_scale_is_identity(seed, mode, zero_head):
    page = _page(seed, mag=0)
    page[:, zero_head, :] = 0.0
    payload, sc = quant.quantize_page(page, mode)
    assert sc[zero_head] == 1.0
    dq = quant.dequantize_page(payload, sc)
    np.testing.assert_array_equal(dq[:, zero_head, :], 0.0)
    # fully-zero page: every head degrades to the identity scale
    z_payload, z_sc = quant.quantize_page(np.zeros_like(page), mode)
    np.testing.assert_array_equal(z_sc, 1.0)
    np.testing.assert_array_equal(
        quant.dequantize_page(z_payload, z_sc), 0.0)


@given(seed=st.integers(0, 2**31 - 1), mode=st.sampled_from(MODES),
       outlier=st.floats(1e3, 1e6, allow_nan=False, allow_infinity=False),
       sign=st.sampled_from([-1.0, 1.0]))
@settings(max_examples=100, deadline=None)
def test_single_outlier_pins_head_scale(seed, mode, outlier, sign):
    page = _page(seed, mag=-2)          # background far below the outlier
    page[3, 1, 2] = sign * outlier
    payload, sc = quant.quantize_page(page, mode)
    np.testing.assert_allclose(sc[1], outlier / _QMAX[mode], rtol=1e-6)
    # the outlier itself sits on the grid's endpoint and survives exactly
    # (to f32 arithmetic); the swamped background stays inside the bound
    dq = quant.dequantize_page(payload, sc)
    np.testing.assert_allclose(dq[3, 1, 2], page[3, 1, 2], rtol=1e-5)
    bound = quant.roundtrip_bound(page, mode, sc)
    assert (np.abs(page - dq) <= bound).all()


@given(seed=st.integers(0, 2**31 - 1), mode=st.sampled_from(MODES),
       mag=st.integers(-3, 3), cycles=st.integers(1, 4),
       pool_dtype=st.sampled_from(POOL_DTYPES))
@settings(max_examples=100, deadline=None)
def test_freeze_stash_thaw_cycles_never_double_quantize(
        seed, mode, mag, cycles, pool_dtype):
    """Model the controller's page lifecycle: freeze-time quantize once,
    then any number of stash (``narrow_payload`` from the pool dtype) /
    thaw (payload re-installed into the pool dtype) round trips.  The
    payload must be byte-stable across every cycle — re-quantization
    would drift it — and the final dequant error stays within the ONE
    round-trip bound."""
    page = _page(seed, mag)
    payload, sc = quant.quantize_page(page, mode)
    ref_bytes = payload.tobytes()
    pool_page = np.asarray(payload, np.float32).astype(pool_dtype)
    for _ in range(cycles):
        stashed = quant.narrow_payload(pool_page, mode)     # stash
        assert stashed.tobytes() == ref_bytes
        # quantizing ON-GRID values with the stored scales is a no-op: a
        # host-dequantized page (the ensure_resident path) re-quantizes
        # to the same bytes instead of drifting
        requant, _ = quant.quantize_page(
            quant.dequantize_page(stashed, sc), mode, scales=sc)
        assert requant.tobytes() == ref_bytes
        pool_page = np.asarray(stashed, np.float32).astype(pool_dtype)  # thaw
    dq = quant.dequantize_page(quant.narrow_payload(pool_page, mode), sc)
    bound = quant.roundtrip_bound(page, mode, sc)
    assert (np.abs(page - dq) <= bound).all()

"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned arch family runs one forward + one train step + a short decode on
CPU, asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as MD
from repro.models.transformer import PATCH_STUB_DIM
from repro.training import train_step as TS

ARCHS = list_archs()


def tiny_batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.multimodal:
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, PATCH_STUB_DIM), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch + "-tiny")
    assert cfg.num_layers <= 2 and cfg.d_model <= 512 and cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = MD.init_params(key, cfg)
    batch = tiny_batch(cfg, key)
    logits, aux = MD.train_logits(params, cfg, batch, remat=False)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    if cfg.num_experts:
        assert float(aux) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nan(arch):
    cfg = dataclasses.replace(get_config(arch + "-tiny"), dtype="float32")
    key = jax.random.PRNGKey(1)
    state = TS.init_train_state(key, cfg)
    batch = tiny_batch(cfg, key)
    if "frames" in batch:
        batch["frames"] = batch["frames"].astype(jnp.float32)
    if "patch_embeds" in batch:
        batch["patch_embeds"] = batch["patch_embeds"].astype(jnp.float32)
    state, metrics = TS.train_step(state, batch, cfg)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    assert int(state.opt.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_no_nan(arch):
    cfg = get_config(arch + "-tiny")
    key = jax.random.PRNGKey(2)
    params = MD.init_params(key, cfg)
    B, S, Smax = 2, 8, 32
    batch = tiny_batch(cfg, key, B, S)
    st = MD.init_decode_state(cfg, B, Smax)
    logits, st = MD.prefill(params, cfg, batch, st)
    assert logits.shape == (B, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(3):
        logits, st, info = MD.decode_step(params, cfg, tok, jnp.int32(S + i),
                                          jnp.int32(i), st)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).is_encoder_decoder
                                  and get_config(a).num_heads > 0])
def test_paged_decode_smoke(arch):
    """Bounded-active paged decode lowers + runs for every attention arch."""
    cfg = get_config(arch + "-tiny")
    cfg = dataclasses.replace(
        cfg, freeze=dataclasses.replace(cfg.freeze, page_size=8))
    key = jax.random.PRNGKey(3)
    params = MD.init_params(key, cfg)
    B, P = 2, 4
    st = MD.init_paged_decode_state(cfg, B, P)
    # pretend pages 0..2 already hold context; decode token at pos 24
    st = st._replace(page_table=jnp.broadcast_to(
        jnp.array([0, 1, 2, 3], jnp.int32), st.page_table.shape).copy(),
        slot_mask=st.slot_mask.at[:, :, :3].set(True))
    tok = jnp.zeros((B,), jnp.int32)
    logits, st, info = MD.decode_step_paged(
        params, cfg, tok, jnp.int32(24), jnp.int32(0), jnp.int32(3), st)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert bool(st.slot_mask[:, :, 3, 0].all())   # tail write landed

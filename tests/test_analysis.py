"""Self-tests for the static-analysis suite (tools/analysis) and its
runtime companion (repro.analysis.trace_guard).

Each pass gets seeded-violation fixtures (must flag), clean fixtures
(must stay silent), and suppression fixtures (flag silenced by a
reasoned ``# hotpath: ok(...)``).  The suite's acceptance criterion —
zero unsuppressed findings over ``src/`` — is asserted here too, so a
regression that reintroduces a hot-path sync fails tier-1, not just the
CI analysis job.
"""
import pathlib
import textwrap

import pytest

from tools.analysis import (ALL_PASSES, REPO_CONFIG, Config, Context,
                            Diagnostic, DonationPass, HostSyncPass,
                            RetracePass, SourceFile, run_passes)

ROOT = pathlib.Path(__file__).resolve().parents[1]

FIX_CONFIG = Config(
    hot_functions=frozenset({"Eng.step_once"}),
    device_roots=frozenset({"state", "logits"}),
    bucketed_functions=frozenset({"Eng.warm"}),
)


def run_fixture(src, passes, config=FIX_CONFIG):
    sf = SourceFile("fixture.py", text=textwrap.dedent(src), config=config)
    ctx = Context(config)
    ctx.add_file(sf)
    diags = []
    for p in passes:
        for d in p.run(sf, ctx):
            if d.line in sf.suppressions:
                d.suppressed = sf.suppressions[d.line]
            diags.append(d)
    return sf, diags


def active(diags):
    return [d for d in diags if d.suppressed is None]


# ===================================================================== #
# hostsync
# ===================================================================== #
HOT_SYNCS = """
    import numpy as np
    import jax
    import jax.numpy as jnp

    class Eng:
        def step_once(self):
            a = int(self.state.freeze.frozen.sum())        # flag: int()
            b = np.asarray(self.state.recovery.steps_seen)  # flag: asarray
            host = jax.device_get(self.state)               # flag: get
            c = self.state.tokens.item()                    # flag: item
            for t in self.state.tokens:                     # flag: iterate
                pass
            ok = int(self.pos[0])         # host mirror: NOT flagged
            toks = [t for t in self.pos]  # host comprehension: NOT flagged
            return a, b, host, c, ok, toks

        def admit_helper(self):
            # identical syncs outside a hot region: allowed
            return np.asarray(self.state.freeze.frozen), int(self.state.n)
"""


def test_hostsync_flags_each_sync_kind():
    _, diags = run_fixture(HOT_SYNCS, [HostSyncPass()])
    msgs = " | ".join(d.message for d in active(diags))
    assert len(active(diags)) == 5
    for needle in ("int()", "np.asarray", "device_get", ".item()",
                   "iterating a device value"):
        assert needle in msgs


def test_hostsync_ignores_cold_functions_and_host_values():
    _, diags = run_fixture(HOT_SYNCS, [HostSyncPass()])
    for d in active(diags):
        assert 7 <= d.line <= 12, f"unexpected finding: {d.render()}"


def test_hostsync_comprehension_over_device_value():
    src = """
        import jax.numpy as jnp

        class Eng:
            def step_once(self):
                return [int(t) for t in self.state.toks]
    """
    _, diags = run_fixture(src, [HostSyncPass()])
    assert len(active(diags)) == 1
    assert "comprehension over a device value" in active(diags)[0].message


def test_hostsync_inline_hot_marker_and_suppression():
    src = """
        import numpy as np

        def tick(state):
            # hotpath: hot
            bad = np.asarray(state.frozen)
            fine = np.asarray(state.frozen)  # hotpath: ok(boundary-tick batch pull)
            return bad, fine

        def cold(state):
            return np.asarray(state.frozen)   # not hot: silent
    """
    sf, diags = run_fixture(src, [HostSyncPass()])
    acts, sups = active(diags), [d for d in diags if d.suppressed]
    assert len(acts) == 1 and acts[0].line == 6
    assert len(sups) == 1 and sups[0].suppressed == \
        "boundary-tick batch pull"


def test_suppression_on_preceding_line():
    src = """
        import numpy as np

        class Eng:
            def step_once(self):
                # hotpath: ok(materialized once per admission)
                return np.asarray(self.state.frozen)
    """
    _, diags = run_fixture(src, [HostSyncPass()])
    assert not active(diags)
    assert diags and diags[0].suppressed == "materialized once per admission"


def test_suppression_without_reason_is_reported():
    src = textwrap.dedent("""
        import numpy as np

        class Eng:
            def step_once(self):
                return np.asarray(self.state.frozen)  # hotpath: ok
    """)
    sf = SourceFile("fixture.py", text=src, config=FIX_CONFIG)
    assert sf.bad_suppressions, "a reasonless suppression must be reported"
    # and it does NOT silence the finding
    ctx = Context(FIX_CONFIG)
    ctx.add_file(sf)
    diags = list(HostSyncPass().run(sf, ctx))
    assert diags and all(d.line not in sf.suppressions for d in diags)


def test_github_render_format():
    d = Diagnostic("src/x.py", 12, 3, "hostsync", "msg here")
    out = d.render("github")
    assert out.startswith("::error file=src/x.py,line=12,col=3,")
    assert out.endswith("::msg here")


# ===================================================================== #
# donation
# ===================================================================== #
DONATED_STATE = """
    import functools
    import jax

    def decode_step(params, token, state):
        return token, state

    def write_lane(cfg, state, lane_state, lane):
        return state

    class Eng:
        def __init__(self, params, cfg):
            self._step = jax.jit(functools.partial(decode_step, params),
                                 donate_argnames=("state",))
            self._write = jax.jit(functools.partial(write_lane, cfg),
                                  donate_argnames=("state", "lane_state"))

        def bad_step(self, tok):
            logits, out = self._step(tok, state=self.state)
            return self.state.freeze.frozen        # read-after-donate

        def good_step(self, tok):
            logits, self.state = self._step(tok, state=self.state)
            return self.state                      # rebound first: ok

        def bad_write(self, ls):
            self.state = self._write(self.state, ls, 0)
            return ls.cache_k                      # lane_state donated

        def good_write(self, ls):
            self.state = self._write(self.state, ls, 0)
            ls = self.fresh()
            return ls.cache_k                      # rewritten first: ok
"""


def test_donation_flags_read_after_donate_keyword():
    _, diags = run_fixture(DONATED_STATE, [DonationPass()])
    lines = {d.line for d in active(diags)}
    assert 20 in lines, "self.state read after keyword donation must flag"


def test_donation_flags_positional_donation_through_partial():
    _, diags = run_fixture(DONATED_STATE, [DonationPass()])
    msgs = [d for d in active(diags) if "'ls'" in d.message]
    assert len(msgs) == 1 and msgs[0].line == 28, \
        "positional lane_state donation (partial-shifted) must flag"


def test_donation_same_statement_rebind_and_rewrite_are_clean():
    _, diags = run_fixture(DONATED_STATE, [DonationPass()])
    lines = {d.line for d in active(diags)}
    assert lines == {20, 28}, f"only the seeded bugs flag, got {lines}"


def test_donation_suppression():
    src = """
        import jax

        def f(state, x):
            return x

        class Eng:
            def __init__(self):
                self._f = jax.jit(f, donate_argnums=(0,))

            def use(self, x):
                out = self._f(self.state, x)
                return self.state  # hotpath: ok(CPU backend copies, audited)
    """
    _, diags = run_fixture(src, [DonationPass()])
    assert not active(diags) and len(diags) == 1


# ===================================================================== #
# retrace
# ===================================================================== #
RETRACE_SRC = """
    import jax
    import jax.numpy as jnp

    def f(x, n):
        return x

    class Eng:
        def __init__(self):
            self._step = jax.jit(f)
            self._chunk = jax.jit(f, static_argnames=("n",))

        def bad_scalar(self, x):
            return self._step(x, 0)                 # weak-typed scalar

        def ok_static_scalar(self, x):
            return self._chunk(x, n=4)              # static: fine

        def bad_unhashable(self, x):
            return self._chunk(x, n=[1, 2])         # unhashable static

        def bad_open_shape(self, m):
            return self._step(jnp.zeros((1, m)), jnp.int32(0))

        def warm(self, m):
            return self._step(jnp.zeros((1, m)), jnp.int32(0))
"""


def test_retrace_flags_python_scalar():
    _, diags = run_fixture(RETRACE_SRC, [RetracePass()])
    hits = [d for d in active(diags) if "python scalar" in d.message]
    assert len(hits) == 1 and hits[0].line == 14


def test_retrace_static_scalar_is_clean():
    _, diags = run_fixture(RETRACE_SRC, [RetracePass()])
    assert not any(d.line == 17 for d in active(diags))


def test_retrace_flags_unhashable_static():
    _, diags = run_fixture(RETRACE_SRC, [RetracePass()])
    hits = [d for d in active(diags) if "unhashable" in d.message]
    assert len(hits) == 1 and hits[0].line == 20


def test_retrace_flags_open_shape_outside_bucket_set():
    _, diags = run_fixture(RETRACE_SRC, [RetracePass()])
    hits = [d for d in active(diags) if "data-dependent shape" in d.message]
    assert len(hits) == 1 and hits[0].line == 23, \
        "same constructor in the bucketed warm() must NOT flag"


# ===================================================================== #
# the repo baseline: zero unsuppressed findings over src/
# ===================================================================== #
def test_src_baseline_is_clean():
    diags = run_passes([str(ROOT / "src")], ALL_PASSES, REPO_CONFIG)
    bad = [d.render() for d in diags if d.suppressed is None]
    assert not bad, "unsuppressed hot-path findings in src/:\n" \
        + "\n".join(bad)
    # every suppression that silences a finding carries a reason
    assert all(d.suppressed.strip() for d in diags if d.suppressed)


def test_repo_config_hot_functions_exist():
    """Config rot guard: every declared hot function must still resolve
    to a def somewhere under src/ (renames must update the config)."""
    import ast
    qualnames = set()
    for f in (ROOT / "src").rglob("*.py"):
        tree = ast.parse(f.read_text())

        def visit(node, scope):
            for ch in ast.iter_child_nodes(node):
                if isinstance(ch, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qualnames.add(".".join(scope + (ch.name,)))
                    visit(ch, scope + (ch.name,))
                elif isinstance(ch, ast.ClassDef):
                    visit(ch, scope + (ch.name,))
                else:
                    visit(ch, scope)

        visit(tree, ())
    missing = REPO_CONFIG.hot_functions - qualnames
    assert not missing, f"hot_functions not found in src/: {missing}"


# ===================================================================== #
# runtime: trace_guard
# ===================================================================== #
def test_trace_guard_counts_and_raises():
    import functools

    import jax
    import jax.numpy as jnp

    from repro.analysis import RetraceError, trace_guard

    f = jax.jit(functools.partial(lambda c, x: x * c, 2.0))

    class Obj:
        def __init__(self):
            self._step = f
            self.other = 41

    o = Obj()
    with trace_guard(o, label="warm") as tg:
        f(jnp.ones(3))
        f(jnp.ones(6))
    assert tg.n_retraces == 2 and tg.growth == {"Obj._step": 2}

    with trace_guard(o, max_new_compiles=0, label="steady") as tg:
        f(jnp.ones(3))          # cached: no growth, no raise
    assert tg.n_retraces == 0

    with pytest.raises(RetraceError):
        with trace_guard(o, max_new_compiles=0, label="grow"):
            f(jnp.ones(12))


def test_trace_guard_untracked_targets_degrade_gracefully():
    from repro.analysis import trace_guard

    class Plain:
        def __init__(self):
            self.x = 1

    with trace_guard(Plain(), label="nothing") as tg:
        pass
    assert tg.n_retraces == 0 and tg.untracked == ["Plain"]
    assert tg.summary()["n_tracked"] == 0

"""Dry-run machinery: spec building (no devices needed) + one real
512-device lower/compile in a subprocess (the full 10x4x2 sweep runs via
`python -m repro.launch.dryrun`; its artifacts live in experiments/dryrun)."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch import specs as SP
from repro.launch.mesh import abstract_mesh

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


class TestSpecs:
    def test_all_combos_build(self):
        """Every (arch x shape) either builds a StepBundle or is an
        explicit documented skip — nothing falls through."""
        mesh = abstract_mesh((16, 16), ("data", "model"))
        built = skipped = 0
        for arch in list_archs():
            cfg = get_config(arch)
            for shape in INPUT_SHAPES.values():
                if SP.skip_reason(cfg, shape):
                    skipped += 1
                    continue
                bundle = SP.build_step(cfg, shape, mesh)
                assert bundle.fn is not None
                built += 1
        assert built == 39 and skipped == 1   # whisper long_500k only

    def test_long_500k_uses_paged_path(self):
        mesh = abstract_mesh((16, 16), ("data", "model"))
        b = SP.build_step(get_config("mistral-large-123b"),
                          INPUT_SHAPES["long_500k"], mesh)
        assert b.static["kind"] == "decode_paged"
        assert b.static["active_tokens"] == SP.LONG_CONTEXT_ACTIVE_TOKENS

    def test_rwkv_long_500k_is_o1_state(self):
        mesh = abstract_mesh((16, 16), ("data", "model"))
        b = SP.build_step(get_config("rwkv6-1.6b"),
                          INPUT_SHAPES["long_500k"], mesh)
        assert b.static["kind"] == "decode"   # recurrent state, no paging

    def test_infer_mode_heuristic(self):
        mesh = abstract_mesh((16, 16), ("data", "model"))
        small = SP.param_mode(get_config("llama3-8b"),
                              INPUT_SHAPES["decode_32k"], mesh)
        big = SP.param_mode(get_config("jamba-1.5-large-398b"),
                            INPUT_SHAPES["decode_32k"], mesh)
        train = SP.param_mode(get_config("llama3-8b"),
                              INPUT_SHAPES["train_4k"], mesh)
        assert small == "infer" and big == "train" and train == "train"


@pytest.mark.slow
def test_one_real_512_device_compile(tmp_path):
    """whisper-base decode_32k: full lower+compile on the 16x16 mesh in a
    subprocess (XLA_FLAGS must be set before jax init)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-base",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads((tmp_path / "whisper-base__decode_32k__sp.json")
                     .read_text())
    assert rec["ok"] and rec["chips"] == 256
    assert rec["roofline"]["memory_s"] > 0

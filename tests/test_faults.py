"""Chaos hardening (serving/faults.py + engine/controller integration):

* deterministic fault scheduling: same seed -> identical injection
  sequence; explicit (site, op) plans override the rate draw,
* retry/backoff + circuit-breaker state machine semantics (trip on
  consecutive *operation* failures, op-count cooldown, half-open probe),
* ``Endpoint.call``: the wrapped transfer runs exactly once (donation
  safety), best-effort endpoints surface ``Endpoint.FAILED``,
  must-succeed endpoints absorb exhausted budgets without raising,
* engine integration: a tripped ring breaker drops the fetch ring to the
  depth-0 sync baseline token-identically; rate-scheduled DMA faults are
  token-invisible,
* host-stash budget: the swap-out hard ceiling, the degradation ladder's
  throttle/shed rungs (token parity in the recovery-off envelope), and
  the S1 regression — discarding a suspended snapshot releases its
  exported pages instead of leaking them,
* NaN quarantine: one poisoned step -> bounded rewind and completion; a
  re-poison inside the window -> the lane retires "quarantined",
* the runtime invariant auditor accepts healthy controllers and flags
  corrupted gauges, and a seeded random admit/suspend/resume/discard/step
  storm keeps every invariant intact with exact stash accounting.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.analysis import InvariantViolation, audit_controller
from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import LadderConfig, PagedContinuousEngine
from repro.serving.faults import (ChaosConfig, CircuitBreaker, Endpoint,
                                  FaultInjector, FaultPlan, FaultSchedule,
                                  RetryPolicy)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler


# ---------------------------------------------------------------- unit --

class TestFaultSchedule:
    def test_seed_determinism(self):
        a = FaultSchedule(seed=3, rates={"pull": 0.3, "ring": 0.2})
        b = FaultSchedule(seed=3, rates={"pull": 0.3, "ring": 0.2})
        seq_a = [(s, i, a.plan(s, i) is not None)
                 for s in ("pull", "ring") for i in range(200)]
        seq_b = [(s, i, b.plan(s, i) is not None)
                 for s in ("pull", "ring") for i in range(200)]
        assert seq_a == seq_b
        hits = sum(1 for _, _, h in seq_a if h)
        assert 0 < hits < 400          # some, not all

    def test_seed_changes_schedule(self):
        a = FaultSchedule(seed=1, rates={"pull": 0.3})
        b = FaultSchedule(seed=2, rates={"pull": 0.3})
        assert [a.plan("pull", i) is not None for i in range(200)] \
            != [b.plan("pull", i) is not None for i in range(200)]

    def test_explicit_overrides_rate(self):
        plan = FaultPlan(kind="slow", delay_s=0.5)
        s = FaultSchedule(seed=0, rates={"pull": 0.0},
                          explicit={("pull", 7): plan})
        assert s.plan("pull", 6) is None
        assert s.plan("pull", 7) is plan

    def test_nan_site_draws_nan_kind(self):
        s = FaultSchedule(seed=0, rates={"nan": 1.0})
        assert s.plan("nan", 0).kind == "nan"

    def test_injector_counts(self):
        inj = FaultInjector(FaultSchedule(
            seed=0, explicit={("pull", 1): FaultPlan()}))
        assert inj.next_plan("pull") is None
        assert inj.next_plan("pull") is not None
        assert inj.op_counts["pull"] == 2
        assert inj.n_injected == 1


class TestCircuitBreaker:
    def test_trips_on_consecutive_failures(self):
        br = CircuitBreaker(trip_after=3, cooldown_ops=2)
        for _ in range(2):
            br.record(False)
        assert br.state == "closed"
        br.record(True)                 # success resets the streak
        for _ in range(3):
            br.record(False)
        assert br.state == "open" and br.n_trips == 1

    def test_cooldown_then_half_open_probe(self):
        br = CircuitBreaker(trip_after=1, cooldown_ops=2)
        br.record(False)
        assert not br.allow()           # 1 cooldown op burned
        assert br.allow()               # cooldown done -> half-open probe
        assert br.state == "half_open"
        br.record(True)
        assert br.state == "closed"

    def test_half_open_failure_reopens(self):
        br = CircuitBreaker(trip_after=1, cooldown_ops=1)
        br.record(False)
        assert br.allow() and br.state == "half_open"
        br.record(False)
        assert br.state == "open" and br.n_trips == 2


class TestEndpoint:
    def _ep(self, explicit, must_succeed=True, max_retries=2):
        inj = FaultInjector(FaultSchedule(seed=0, explicit=explicit))
        return Endpoint("pull", inj,
                        retry=RetryPolicy(max_retries=max_retries),
                        breaker=CircuitBreaker(trip_after=1, cooldown_ops=2),
                        must_succeed=must_succeed)

    def test_fn_runs_exactly_once(self):
        calls = []
        ep = self._ep({("pull", 0): FaultPlan(attempts=2)})
        out = ep.call(lambda: calls.append(1) or "ok")
        assert out == "ok" and len(calls) == 1
        assert ep.n_retries == 2 and ep.n_exhausted == 0

    def test_best_effort_returns_failed(self):
        ep = self._ep({("pull", 0): FaultPlan(attempts=9)},
                      must_succeed=False)
        assert ep.call(lambda: "ok") is Endpoint.FAILED
        assert ep.n_exhausted == 1 and ep.breaker.tripped

    def test_must_succeed_never_raises(self):
        ep = self._ep({("pull", 0): FaultPlan(attempts=9)})
        assert ep.call(lambda: "ok") == "ok"
        assert ep.n_exhausted >= 1 and ep.breaker.n_trips >= 1

    def test_slow_fault_counts(self):
        ep = self._ep({("pull", 0): FaultPlan(kind="slow")})
        assert ep.call(lambda: 5) == 5
        assert ep.n_slow == 1 and ep.n_retries == 0


# --------------------------------------------------- engine integration --

@pytest.fixture(scope="module")
def chaos_cfg():
    """Aggressive freeze + recovery: stash, thaws, staging and rewinds
    all active (mirrors test_async_pipeline.thaw_rewind_cfg)."""
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.6, k_soft=0.7,
                             recovery_enabled=True,
                             entropy_abs_threshold=0.5, rewalk_tokens=6)
    cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def pressure_cfg(chaos_cfg):
    """Same freeze pressure with recovery OFF — the envelope in which
    suspend/resume (and therefore the shed rung) is token-exact."""
    cfg, _ = chaos_cfg
    cfg = dataclasses.replace(cfg, freeze=dataclasses.replace(
        cfg.freeze, recovery_enabled=False))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk(cfg, params, **kw):
    kw.setdefault("max_seq", 256)
    kw.setdefault("n_lanes", 2)
    kw.setdefault("max_active_pages", 6)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("rewind_cooldown", 12)
    kw.setdefault("async_pipeline", True)
    kw.setdefault("burst_prefill", False)
    return PagedContinuousEngine(cfg, params, **kw)


def _serve(eng, cfg, lens, seed=0):
    s = Scheduler(eng)
    rng = np.random.RandomState(seed)
    uids = [s.submit(rng.randint(0, cfg.vocab_size, size=pl), n,
                     SamplingParams.greedy())
            for pl, n in lens]
    s.run()
    return [s.done[u] for u in uids]


def _toks(done):
    return [list(map(int, r.result)) for r in done]


LENS = [(28, 40), (20, 36)]


@pytest.fixture(scope="module")
def clean_ref(chaos_cfg):
    cfg, params = chaos_cfg
    return _toks(_serve(_mk(cfg, params), cfg, LENS))


class TestChaosEngine:
    def test_dma_fault_token_parity(self, chaos_cfg, clean_ref):
        """Rate-scheduled transient faults on every DMA site must be
        retried into token invisibility."""
        cfg, params = chaos_cfg
        chaos = ChaosConfig(seed=7, rates={"pull": 0.3, "push": 0.3,
                                           "ring": 0.2, "stage": 0.5})
        eng = _mk(cfg, params, chaos=chaos)
        done = _serve(eng, cfg, LENS)
        rs = eng.robust_snapshot()
        assert rs["retries"] > 0, "schedule must exercise the retry path"
        assert _toks(done) == clean_ref

    def test_ring_breaker_depth0_fallback(self, chaos_cfg, clean_ref):
        """A fault burst past the retry budget trips the ring breaker;
        the engine serves from the depth-0 sync baseline while it is
        open and the tokens must not change."""
        cfg, params = chaos_cfg
        chaos = ChaosConfig(
            seed=0, max_retries=2, trip_after=2, cooldown_ops=6,
            explicit={("ring", i): FaultPlan(attempts=10)
                      for i in range(5, 9)})
        eng = _mk(cfg, params, chaos=chaos)
        done = _serve(eng, cfg, LENS)
        rs = eng.robust_snapshot()
        assert rs["breaker_trips"] >= 1
        assert eng.ep_ring.n_exhausted >= 1
        assert _toks(done) == clean_ref

    def test_quarantine_single_poison_recovers(self, chaos_cfg, clean_ref):
        """One poisoned step: a bounded page-aware rewind absorbs it and
        both requests complete (the peer token-identically)."""
        cfg, params = chaos_cfg
        chaos = ChaosConfig(seed=0, explicit={
            ("nan", 30): FaultPlan(kind="nan", lane=0)})
        eng = _mk(cfg, params, chaos=chaos)
        done = _serve(eng, cfg, LENS)
        assert eng.robust["quarantine_rewinds"] == 1
        assert eng.robust["quarantined"] == 0
        assert [r.status for r in done] == ["completed", "completed"]
        # lane 1's peer is untouched: exact parity
        assert _toks(done)[1] == clean_ref[1]

    def test_quarantine_repoison_retires(self, chaos_cfg):
        """A second poison inside quarantine_window retires the lane with
        status 'quarantined'; the peer still completes."""
        cfg, params = chaos_cfg
        chaos = ChaosConfig(seed=0, explicit={
            ("nan", 30): FaultPlan(kind="nan", lane=0),
            ("nan", 33): FaultPlan(kind="nan", lane=0)})
        eng = _mk(cfg, params, chaos=chaos)
        done = _serve(eng, cfg, LENS)
        assert eng.robust["quarantined"] == 1
        statuses = sorted(r.status for r in done)
        assert statuses == ["completed", "quarantined"]

    def test_invariant_auditor_clean_run(self, chaos_cfg):
        """debug_invariants audits every boundary tick of a faulted run
        without firing."""
        cfg, params = chaos_cfg
        chaos = ChaosConfig(seed=11, rates={"pull": 0.2, "stage": 0.3})
        eng = _mk(cfg, params, chaos=chaos, debug_invariants=True)
        _serve(eng, cfg, [(24, 24)])
        audit_controller(eng.ctl)


class TestStashBudget:
    def test_ladder_throttle_shed_parity(self, pressure_cfg):
        """Budget above the unbounded peak with throttle+shed armed low:
        both rungs fire, every shed request resumes and finishes, peak
        stays under budget, and tokens match the unbounded run
        (recovery-off parity envelope)."""
        cfg, params = pressure_cfg
        lens = [(20, 28)] * 4
        ref_eng = _mk(cfg, params, max_active_pages=4)
        ref = _toks(_serve(ref_eng, cfg, lens))
        budget = int(ref_eng.peak_stash_bytes * 1.25) or 1
        eng = _mk(cfg, params, max_active_pages=4,
                  stash_budget_bytes=budget,
                  ladder=LadderConfig(deny_prefetch=2.0, deepen_timers=2.0,
                                      throttle_admissions=0.45, shed=0.6))
        done = _serve(eng, cfg, lens)
        assert eng.robust["ladder_throttle"] > 0
        assert eng.robust["ladder_shed"] > 0
        assert any(r.status == "shed-resumed" for r in done)
        assert all(r.status in ("completed", "shed-resumed") for r in done)
        assert eng.peak_stash_bytes <= budget
        assert _toks(done) == ref

    def test_swap_out_hard_ceiling(self, pressure_cfg):
        """A tiny budget (no ladder relief) forces the tick's swap-out
        rung to deny new stash allocations at the ceiling — pages stay
        resident and the run still completes."""
        cfg, params = pressure_cfg
        eng = _mk(cfg, params, max_active_pages=4,
                  stash_budget_bytes=1,
                  ladder=LadderConfig(deny_prefetch=2.0, deepen_timers=2.0,
                                      throttle_admissions=2.0, shed=2.0))
        done = _serve(eng, cfg, [(20, 24)])
        assert eng.ctl.n_denied_offloads > 0
        assert done[0].status == "completed"
        # the only stash writers left are correctness-critical
        assert eng.ctl.stash_bytes == sum(
            k.nbytes + v.nbytes for k, v in eng.ctl.store.values())

    def test_deepen_rung_skips_timer_decrements(self, pressure_cfg):
        """Pressure past the deepen threshold halves the forced-freeze
        timer cadence (n_deepen_skips advances)."""
        cfg, params = pressure_cfg
        eng = _mk(cfg, params, max_active_pages=4,
                  stash_budget_bytes=1,
                  ladder=LadderConfig(deny_prefetch=2.0, deepen_timers=0.0,
                                      throttle_admissions=2.0, shed=2.0))
        _serve(eng, cfg, [(20, 24)])
        assert eng.robust["ladder_deepen"] > 0
        assert eng.ctl.n_deepen_skips > 0


class TestSnapshotLifecycle:
    def test_discard_snapshot_releases_exported(self, pressure_cfg):
        """S1 regression: a suspended lane's exported pages must be
        releasable without resuming — dropping the snapshot without
        ``discard_snapshot`` leaks the bytes AND the exported_bytes
        gauge (phantom ladder pressure forever)."""
        cfg, params = pressure_cfg
        eng = _mk(cfg, params, max_active_pages=4)
        s = Scheduler(eng)
        rng = np.random.RandomState(0)
        s.submit(rng.randint(0, cfg.vocab_size, size=24), 40,
                 SamplingParams.greedy())
        for _ in range(12):
            s.step()
        snap = eng.suspend_lane(0)
        assert snap is not None and snap.stashed
        assert eng.ctl.exported_bytes > 0
        eng.discard_snapshot(snap)
        assert eng.ctl.exported_bytes == 0
        assert snap.stashed is None
        eng.discard_snapshot(snap)           # idempotent
        audit_controller(eng.ctl)
        # the freed lane serves a fresh request cleanly
        done = _serve(eng, cfg, [(16, 12)])
        assert done[0].status == "completed"

    def test_auditor_flags_corrupt_gauge(self, pressure_cfg):
        cfg, params = pressure_cfg
        eng = _mk(cfg, params, max_active_pages=4)
        _serve(eng, cfg, [(20, 24)])
        audit_controller(eng.ctl)
        eng.ctl.stash_bytes += 123           # corrupt the gauge
        with pytest.raises(InvariantViolation):
            audit_controller(eng.ctl)
        eng.ctl.stash_bytes -= 123

    def test_seeded_random_op_storm(self, pressure_cfg):
        """Deterministic mirror of the hypothesis property test
        (tests/test_chaos_properties.py): a seeded storm of
        admit/step/suspend/resume/discard ops never breaks a controller
        invariant and the stash accounting stays exact."""
        from repro.serving.engine import Request
        cfg, params = pressure_cfg
        eng = _mk(cfg, params, max_active_pages=4)
        rng = np.random.RandomState(4)
        snaps, uid = [], 0

        def active(e):
            return [i for i in range(e.n_lanes)
                    if e.lanes[i].request is not None or i in e.prefills]

        for op in rng.randint(0, 10, size=120):
            act = active(eng)
            if op <= 1 and len(act) < eng.n_lanes:
                uid += 1
                eng.admit(Request(
                    uid,
                    np.asarray(rng.randint(0, cfg.vocab_size, size=int(
                        rng.randint(8, 24))), np.int32),
                    int(rng.randint(8, 32)), SamplingParams.greedy()))
            elif op == 2 and act:
                snap = eng.suspend_lane(act[0])
                if snap is not None:
                    snaps.append(snap)
            elif op == 3 and snaps and len(active(eng)) < eng.n_lanes:
                eng.resume_lane(snaps.pop())
            elif op == 4 and snaps:
                eng.discard_snapshot(snaps.pop())
            else:
                eng.step_once()
            audit_controller(eng.ctl)
            assert eng.ctl.stash_bytes == sum(
                k.nbytes + v.nbytes for k, v in eng.ctl.store.values())
        for snap in snaps:
            eng.discard_snapshot(snap)
        assert eng.ctl.exported_bytes == 0

"""Continuous-batching engine: mid-stream admission, lane-reuse state reset,
per-lane sampling, and throughput vs the static FIFO baseline."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import ContinuousEngine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler

# the mixed-length request trace from the acceptance criteria: 8 requests,
# n_tokens spanning 8..64, served on 4 lanes
TRACE = [64, 8, 8, 8, 32, 16, 8, 8]
N_LANES = 4


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama3-8b-tiny")
    # aggressive freeze (quantile tau, k_soft=1) so even 8-token requests
    # freeze slots, making the lane-reuse reset observable; recovery ladder
    # enabled but spike-free (huge thresholds) so steps_seen advances
    # deterministically without rewinds
    fc = dataclasses.replace(cfg.freeze, window=4, history=10**6,
                             tau_mode="quantile", quantile=0.6, k_soft=1.0,
                             page_size=8, recovery_enabled=True,
                             entropy_abs_threshold=1e9,
                             entropy_rel_factor=1e9)
    cfg = dataclasses.replace(cfg, freeze=fc)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def run_trace(cfg, params):
    eng = ContinuousEngine(cfg, params, max_seq=160, n_lanes=N_LANES,
                           debug_lane_checks=True)
    sched = Scheduler(eng)
    rng = np.random.RandomState(0)
    uids = [sched.submit(rng.randint(0, cfg.vocab_size, size=16), n,
                         SamplingParams(temperature=0.7))
            for n in TRACE]
    sched.run()
    return eng, sched, uids


@pytest.fixture(scope="module")
def trace_run(tiny):
    return run_trace(*tiny)


class TestContinuousBatching:
    def test_all_requests_complete(self, trace_run):
        _, sched, uids = trace_run
        assert set(uids) == set(sched.done)
        for u, n in zip(uids, TRACE):
            assert sched.done[u].result.shape == (n,)

    def test_admission_mid_stream(self, trace_run):
        """A later request starts before the longest early request finishes
        — the head-of-line blocking the static batcher cannot avoid."""
        eng, _, uids = trace_run
        finish = {e["uid"]: e["wall_step"] for e in eng.events
                  if e["event"] == "finish"}
        late_admits = [e["wall_step"] for e in eng.events
                       if e["event"] == "admit" and e["uid"] in uids[N_LANES:]]
        assert late_admits, "queue never spilled past the first batch"
        assert min(late_admits) < finish[uids[0]]

    def test_lane_reuse_resets_freeze_and_recovery(self, trace_run):
        """Reused lanes carry frozen slots and a warmed recovery ladder from
        their previous occupant; admission must wipe both."""
        eng, _, _ = trace_run
        admits = [e for e in eng.events if e["event"] == "admit"]
        reuses = [e for e in admits if e["wall_step"] > 0]
        assert reuses, "no lane was ever reused"
        assert any(e["frozen_before"] > 0 for e in reuses)
        assert any(e["recovery_steps_before"] > 0 for e in reuses)
        assert all(e["frozen_after"] == 0 for e in admits)
        assert all(e["recovery_steps_after"] == 0 for e in admits)

    def test_throughput_beats_static_batching(self, trace_run):
        """Deterministic step-count comparison: the static FIFO batcher runs
        every batch for max(n_tokens) steps, so the trace costs
        sum(max over each batch) jitted steps; continuous batching retires
        and refills lanes mid-stream and must finish in fewer."""
        eng, _, _ = trace_run
        static_steps = sum(max(TRACE[i:i + N_LANES])
                           for i in range(0, len(TRACE), N_LANES))
        assert eng.wall_step < static_steps

    def test_telemetry_per_request(self, trace_run):
        """Every request gets aligned per-step telemetry for exactly the
        steps it was resident, and the freeze actually engages."""
        _, sched, uids = trace_run
        for u, n in zip(uids, TRACE):
            t = sched.done[u].telemetry
            # n-1 decode steps (the first token comes from prefill)
            assert len(t.active_kv) == len(t.frozen_kv) == len(t.total_kv) \
                == len(t.offloaded_tokens) == len(t.entropy) == n - 1
        long_t = sched.done[uids[0]].telemetry
        assert long_t.compression > 0.3


class TestLaneResetHelpers:
    """The standalone lane-granular reset helpers (the engine's admission
    scatter is the wholesale equivalent; these cover partial resets, e.g. a
    future cancel-without-readmit path) must zero exactly one lane."""

    def test_recovery_reset_lane(self):
        import jax.numpy as jnp
        from repro.core.recovery import RecoveryState, reset_lane
        rec = RecoveryState(ema_entropy=jnp.full((3,), 2.5),
                            level=jnp.full((3,), 4, jnp.int32),
                            calm_steps=jnp.full((3,), 7, jnp.int32),
                            steps_seen=jnp.full((3,), 9, jnp.int32))
        new = reset_lane(rec, 1)
        for field in new:
            arr = np.asarray(field)
            assert arr[1] == 0
            assert (arr[[0, 2]] != 0).all()

    def test_cache_reset_lane(self):
        import jax.numpy as jnp
        from repro.core.cache import KVCache, reset_lane
        cache = KVCache(k=jnp.ones((2, 3, 4, 2, 8)),
                        v=jnp.full((2, 3, 4, 2, 8), 2.0))
        new = reset_lane(cache, 2)
        assert not np.asarray(new.k[:, 2]).any()
        assert not np.asarray(new.v[:, 2]).any()
        assert (np.asarray(new.k[:, :2]) == 1.0).all()
        assert (np.asarray(new.v[:, :2]) == 2.0).all()


class TestPerLaneSampling:
    """Regression for the static scheduler bug that applied batch[0]'s
    SamplingParams to every request in the batch."""

    def test_two_temperatures_in_one_batch(self, tiny):
        cfg, params = tiny
        rng = np.random.RandomState(1)
        prompt = rng.randint(0, cfg.vocab_size, size=16)
        eng = ContinuousEngine(cfg, params, max_seq=96, n_lanes=2)
        sched = Scheduler(eng)
        cold = sched.submit(prompt, 24, SamplingParams.greedy())
        hot = sched.submit(prompt, 24, SamplingParams(temperature=5.0,
                                                      top_k=0, top_p=1.0))
        sched.run()
        # same prompt, same prefill, co-resident lanes: only the sampling
        # params differ, so differing outputs prove they were honored
        assert not np.array_equal(sched.done[cold].result,
                                  sched.done[hot].result)

    def test_same_params_same_prompt_agree(self, tiny):
        """Control arm: two greedy lanes over one prompt must coincide."""
        cfg, params = tiny
        rng = np.random.RandomState(1)
        prompt = rng.randint(0, cfg.vocab_size, size=16)
        eng = ContinuousEngine(cfg, params, max_seq=96, n_lanes=2)
        sched = Scheduler(eng)
        a = sched.submit(prompt, 24, SamplingParams.greedy())
        b = sched.submit(prompt, 24, SamplingParams.greedy())
        sched.run()
        np.testing.assert_array_equal(sched.done[a].result,
                                      sched.done[b].result)

"""PagedContinuousEngine: token-stream parity with the contiguous engine,
chunked-prefill interleaving (no head-of-line blocking), bounded-pool decode
with host swapping, and per-lane reset guarantees."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import ContinuousEngine, PagedContinuousEngine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def tiny_f32():
    """f32 tiny model (exact argmax parity across summation orders) with a
    small page size so pools stay cheap."""
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             recovery_enabled=False)
    cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestParity:
    """With freezing disabled and a pool large enough for the whole trace,
    paged and contiguous continuous batching are the same math — token
    streams must be identical."""

    def test_identical_token_streams(self, tiny_f32):
        cfg, params = tiny_f32
        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, cfg.vocab_size, size=n)
                   for n in (16, 10, 16, 7)]
        n_toks = [12, 8, 10, 9]

        def run(paged):
            if paged:
                eng = PagedContinuousEngine(
                    cfg, params, max_seq=96, n_lanes=2, max_active_pages=8,
                    enable_freeze=False, prefill_chunk=8)
            else:
                eng = ContinuousEngine(cfg, params, max_seq=96, n_lanes=2,
                                       enable_freeze=False, offload=False)
            s = Scheduler(eng)
            uids = [s.submit(p, n, SamplingParams.greedy())
                    for p, n in zip(prompts, n_toks)]
            s.run()
            return [s.done[u].result for u in uids]

        for i, (a, b) in enumerate(zip(run(False), run(True))):
            np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


class TestChunkedPrefill:
    def test_resident_lane_decodes_during_long_admission(self, tiny_f32):
        """A long prompt admitted while another lane is decoding must be
        prefilled in fine-grained chunks, with the resident lane producing
        decode steps between admit_start and admit-complete."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(1)
        from repro.serving.engine import Request
        eng = PagedContinuousEngine(cfg, params, max_seq=160, n_lanes=2,
                                    max_active_pages=12, enable_freeze=False,
                                    prefill_chunk=8)
        short = Request(1, rng.randint(0, cfg.vocab_size, size=8).astype(
            np.int32), 40, SamplingParams.greedy())
        long = Request(2, rng.randint(0, cfg.vocab_size, size=60).astype(
            np.int32), 8, SamplingParams.greedy())
        eng.admit(short)
        while eng.prefills:          # install the short request...
            eng.step_once()
        eng.step_once()              # ...and start decoding it
        eng.admit(long)              # now the engine is busy: chunked path
        finished = []
        while len(finished) < 2:
            finished += eng.step_once()
        assert {r.uid for r in finished} == {1, 2}
        assert short.result.shape == (40,)
        assert long.result.shape == (8,)
        ev = {(e["event"], e["uid"]): e["wall_step"] for e in eng.events}
        start, done = ev[("admit_start", 2)], ev[("admit", 2)]
        # 60-token prompt -> 64 bucket -> 8 chunks of 8, one per decode
        # step: the resident lane advanced throughout the admission
        chunks = [e for e in eng.events if e["event"] == "prefill_chunk"
                  and e["uid"] == 2]
        assert len(chunks) == 8
        assert done - start >= 8, "admission did not interleave with decode"

    def test_idle_engine_bursts_admission(self, tiny_f32):
        """With no resident decode work, chunking buys nothing: the burst
        schedule grows chunks to powers of two and admits in few steps."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(3)
        from repro.serving.engine import Request
        eng = PagedContinuousEngine(cfg, params, max_seq=160, n_lanes=2,
                                    max_active_pages=12, enable_freeze=False,
                                    prefill_chunk=8)
        req = Request(1, rng.randint(0, cfg.vocab_size, size=60).astype(
            np.int32), 8, SamplingParams.greedy())
        eng.admit(req)
        eng.step_once()
        chunks = [e for e in eng.events if e["event"] == "prefill_chunk"]
        assert len(chunks) == 1 and chunks[0]["done"] == 64

    def test_overflow_prompt_pages_survive_install(self):
        """A prompt whose pages exceed the device pool must keep its oldest
        pages in the host store after install (regression: write_lane's
        internal drop_lane used to delete the just-stashed overflow), and
        they must swap back in during decode so early context is never
        permanently lost."""
        cfg = get_config("llama3-8b-tiny")
        fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                                 tau_mode="quantile", quantile=0.6,
                                 k_soft=1.0, recovery_enabled=False)
        cfg = dataclasses.replace(cfg, freeze=fc)
        params = MD.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(4)
        from repro.serving.engine import Request
        eng = PagedContinuousEngine(cfg, params, max_seq=256, n_lanes=1,
                                    max_active_pages=6, prefill_chunk=16)
        # 48-token prompt -> 64 bucket = 8 pages > 5 resident: 3 overflow
        req = Request(1, rng.randint(0, cfg.vocab_size, size=48).astype(
            np.int32), 40, SamplingParams(temperature=0.7))
        eng.admit(req)
        while eng.prefills:
            eng.step_once()
        overflow = {k[2] for k in eng.ctl.store if k[1] == 0}
        assert overflow == {0, 1, 2}, \
            f"overflow prompt pages lost at install: {overflow}"
        swaps_before = eng.ctl.n_swap_in
        while eng.lanes[0].request is not None:
            eng.step_once()
        assert eng.ctl.n_swap_in > swaps_before, \
            "overflow pages never swapped back in during decode"

    def test_no_decode_lane_still_progresses(self, tiny_f32):
        """An admission into an otherwise-empty engine must complete even
        though no decode steps run."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(2)
        eng = PagedContinuousEngine(cfg, params, max_seq=96, n_lanes=1,
                                    max_active_pages=8, enable_freeze=False,
                                    prefill_chunk=8)
        s = Scheduler(eng)
        uid = s.submit(rng.randint(0, cfg.vocab_size, size=30), 6,
                       SamplingParams.greedy())
        s.run()
        assert s.done[uid].result.shape == (6,)


class TestBoundedPool:
    @pytest.fixture(scope="class")
    def bounded_run(self):
        cfg = get_config("llama3-8b-tiny")
        fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                                 tau_mode="quantile", quantile=0.6,
                                 k_soft=1.0, recovery_enabled=False)
        cfg = dataclasses.replace(cfg, freeze=fc)
        params = MD.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        eng = PagedContinuousEngine(cfg, params, max_seq=256, n_lanes=2,
                                    max_active_pages=6, prefill_chunk=16)
        s = Scheduler(eng)
        uids = [s.submit(rng.randint(0, cfg.vocab_size, size=sp), n,
                         SamplingParams(temperature=0.7))
                for sp, n in ((48, 60), (12, 20), (20, 24))]
        s.run()
        return eng, s, uids

    def test_all_complete_and_swapping_happened(self, bounded_run):
        eng, s, uids = bounded_run
        for u, n in zip(uids, (60, 20, 24)):
            assert s.done[u].result.shape == (n,)
        # context (64 prompt bucket + 60 decode) far exceeds the 48-slot
        # pool: pages must have been swapped out and back in
        assert eng.ctl.n_swap_out > 0
        assert eng.ctl.n_swap_in > 0

    def test_active_kv_is_bounded_by_pool(self, bounded_run):
        """The whole point: per-lane active KV never exceeds P * page even
        though the context grows past it."""
        eng, s, uids = bounded_run
        t = s.done[uids[0]].telemetry
        pool_slots = 6 * 8
        assert max(t.active_kv) <= pool_slots
        assert t.total_kv[-1] > pool_slots       # context outgrew the pool
        assert t.compression > 0.3

    def test_lane_reuse_leaks_nothing(self, bounded_run):
        """After the run every lane retired: page tables must be unmapped
        and the controller's per-lane store empty."""
        eng, _, _ = bounded_run
        assert int(np.asarray((eng.state.page_table >= 0).sum())) == 0
        assert not eng.ctl.frozen_meta
        assert eng.kv_device_bytes == eng.state.k.nbytes + eng.state.v.nbytes

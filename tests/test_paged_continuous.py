"""PagedContinuousEngine: token-stream parity with the contiguous engine,
chunked-prefill interleaving (no head-of-line blocking), bounded-pool decode
with host swapping, and per-lane reset guarantees."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import (ContinuousEngine, PagedContinuousEngine,
                                  Request)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def tiny_f32():
    """f32 tiny model (exact argmax parity across summation orders) with a
    small page size so pools stay cheap."""
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             recovery_enabled=False)
    cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestParity:
    """With freezing disabled and a pool large enough for the whole trace,
    paged and contiguous continuous batching are the same math — token
    streams must be identical."""

    def test_identical_token_streams(self, tiny_f32):
        cfg, params = tiny_f32
        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, cfg.vocab_size, size=n)
                   for n in (16, 10, 16, 7)]
        n_toks = [12, 8, 10, 9]

        def run(paged):
            if paged:
                eng = PagedContinuousEngine(
                    cfg, params, max_seq=96, n_lanes=2, max_active_pages=8,
                    enable_freeze=False, prefill_chunk=8)
            else:
                eng = ContinuousEngine(cfg, params, max_seq=96, n_lanes=2,
                                       enable_freeze=False, offload=False)
            s = Scheduler(eng)
            uids = [s.submit(p, n, SamplingParams.greedy())
                    for p, n in zip(prompts, n_toks)]
            s.run()
            return [s.done[u].result for u in uids]

        for i, (a, b) in enumerate(zip(run(False), run(True))):
            np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


class TestChunkedPrefill:
    def test_resident_lane_decodes_during_long_admission(self, tiny_f32):
        """A long prompt admitted while another lane is decoding must be
        prefilled in fine-grained chunks, with the resident lane producing
        decode steps between admit_start and admit-complete."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(1)
        from repro.serving.engine import Request
        eng = PagedContinuousEngine(cfg, params, max_seq=160, n_lanes=2,
                                    max_active_pages=12, enable_freeze=False,
                                    prefill_chunk=8)
        short = Request(1, rng.randint(0, cfg.vocab_size, size=8).astype(
            np.int32), 40, SamplingParams.greedy())
        long = Request(2, rng.randint(0, cfg.vocab_size, size=60).astype(
            np.int32), 8, SamplingParams.greedy())
        eng.admit(short)
        while eng.prefills:          # install the short request...
            eng.step_once()
        eng.step_once()              # ...and start decoding it
        eng.admit(long)              # now the engine is busy: chunked path
        finished = []
        while len(finished) < 2:
            finished += eng.step_once()
        assert {r.uid for r in finished} == {1, 2}
        assert short.result.shape == (40,)
        assert long.result.shape == (8,)
        ev = {(e["event"], e["uid"]): e["wall_step"] for e in eng.events}
        start, done = ev[("admit_start", 2)], ev[("admit", 2)]
        # 60-token prompt -> 64 bucket -> 8 chunks of 8, one per decode
        # step: the resident lane advanced throughout the admission
        chunks = [e for e in eng.events if e["event"] == "prefill_chunk"
                  and e["uid"] == 2]
        assert len(chunks) == 8
        assert done - start >= 8, "admission did not interleave with decode"

    def test_idle_engine_bursts_admission(self, tiny_f32):
        """With no resident decode work, chunking buys nothing: the burst
        schedule grows chunks to powers of two and admits in few steps."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(3)
        from repro.serving.engine import Request
        eng = PagedContinuousEngine(cfg, params, max_seq=160, n_lanes=2,
                                    max_active_pages=12, enable_freeze=False,
                                    prefill_chunk=8)
        req = Request(1, rng.randint(0, cfg.vocab_size, size=60).astype(
            np.int32), 8, SamplingParams.greedy())
        eng.admit(req)
        eng.step_once()
        chunks = [e for e in eng.events if e["event"] == "prefill_chunk"]
        assert len(chunks) == 1 and chunks[0]["done"] == 64

    def test_overflow_prompt_pages_survive_install(self):
        """A prompt whose pages exceed the device pool must keep its oldest
        pages in the host store after install (regression: write_lane's
        internal drop_lane used to delete the just-stashed overflow), and
        they must swap back in during decode so early context is never
        permanently lost."""
        cfg = get_config("llama3-8b-tiny")
        fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                                 tau_mode="quantile", quantile=0.6,
                                 k_soft=1.0, recovery_enabled=False)
        cfg = dataclasses.replace(cfg, freeze=fc)
        params = MD.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(4)
        from repro.serving.engine import Request
        eng = PagedContinuousEngine(cfg, params, max_seq=256, n_lanes=1,
                                    max_active_pages=6, prefill_chunk=16)
        # 48-token prompt -> 64 bucket = 8 pages > 5 resident: 3 overflow
        req = Request(1, rng.randint(0, cfg.vocab_size, size=48).astype(
            np.int32), 40, SamplingParams(temperature=0.7))
        eng.admit(req)
        while eng.prefills:
            eng.step_once()
        overflow = {k[2] for k in eng.ctl.store if k[1] == 0}
        assert overflow == {0, 1, 2}, \
            f"overflow prompt pages lost at install: {overflow}"
        swaps_before = eng.ctl.n_swap_in
        while eng.lanes[0].request is not None:
            eng.step_once()
        assert eng.ctl.n_swap_in > swaps_before, \
            "overflow pages never swapped back in during decode"

    def test_no_decode_lane_still_progresses(self, tiny_f32):
        """An admission into an otherwise-empty engine must complete even
        though no decode steps run."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(2)
        eng = PagedContinuousEngine(cfg, params, max_seq=96, n_lanes=1,
                                    max_active_pages=8, enable_freeze=False,
                                    prefill_chunk=8)
        s = Scheduler(eng)
        uid = s.submit(rng.randint(0, cfg.vocab_size, size=30), 6,
                       SamplingParams.greedy())
        s.run()
        assert s.done[uid].result.shape == (6,)


class TestRecovery:
    """Entropy-guided recovery on the paged path: parity with the
    contiguous oracle, page-granular rewinds, and host thaw servicing."""

    def test_recovery_token_parity_with_contiguous_oracle(self, tiny_f32):
        """With freezing never firing (fixed tau = 0) but sustained entropy
        spikes, both engines run the identical recovery ladder — including
        RR rewinds, which on the paged path exercise the device-side slot
        invalidation and replay.  Token streams must be identical to the
        contiguous engine (the oracle), and rewinds must actually happen
        or the test is vacuous."""
        cfg, params = tiny_f32
        fc = dataclasses.replace(cfg.freeze, tau_mode="fixed", tau=0.0,
                                 recovery_enabled=True,
                                 entropy_abs_threshold=0.5, rewalk_tokens=4)
        cfg = dataclasses.replace(cfg, freeze=fc)
        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, cfg.vocab_size, size=n)
                   for n in (16, 10, 16, 7)]
        n_toks = [14, 10, 12, 9]

        def run(paged):
            if paged:
                eng = PagedContinuousEngine(
                    cfg, params, max_seq=96, n_lanes=2, max_active_pages=10,
                    prefill_chunk=8, rewind_cooldown=8)
            else:
                eng = ContinuousEngine(cfg, params, max_seq=96, n_lanes=2,
                                       offload=False, rewind_cooldown=8)
            s = Scheduler(eng)
            uids = [s.submit(p, n, SamplingParams.greedy())
                    for p, n in zip(prompts, n_toks)]
            s.run()
            rewinds = sum(s.done[u].telemetry.rewinds for u in uids)
            return [s.done[u].result for u in uids], rewinds

        (a, rw_c), (b, rw_p) = run(False), run(True)
        assert rw_c > 0, "no rewinds fired — parity test is vacuous"
        assert rw_p == rw_c
        for i, (x, y) in enumerate(zip(a, b)):
            np.testing.assert_array_equal(x, y, err_msg=f"request {i}")

    @pytest.mark.parametrize("async_pipeline", [False, True])
    def test_rewind_landing_on_page_boundary(self, tiny_f32, async_pipeline):
        """A rewind whose target position is exactly a page boundary must
        unmap the (now wholly invalid) tail page and leave its
        re-allocation to the next page-boundary tick; greedy replay then
        reproduces the never-rewound stream.  Runs in BOTH pipeline
        modes: `_rewind_lane` drains the async result ring at entry, so
        an injected rewind sees current host bookkeeping instead of
        surgery computed against a position one deferred commit stale
        (the per-iteration flush below only keeps the drive loop's pos
        reads exact — the drain is what makes the rewind itself safe)."""
        cfg, params = tiny_f32
        fc = dataclasses.replace(cfg.freeze, recovery_enabled=True,
                                 entropy_abs_threshold=1e9,  # no organic RR
                                 rewalk_tokens=8)
        cfg = dataclasses.replace(cfg, freeze=fc)
        rng = np.random.RandomState(5)
        prompt = rng.randint(0, cfg.vocab_size, size=14).astype(np.int32)

        def run(rewind):
            eng = PagedContinuousEngine(cfg, params, max_seq=96, n_lanes=1,
                                        max_active_pages=10, prefill_chunk=8,
                                        async_pipeline=async_pipeline)
            req = Request(1, prompt, 30, SamplingParams.greedy())
            eng.admit(req)
            while eng.prefills:
                eng.step_once()
            # bucket 16 -> pos starts 16; 16 commits -> pos 32 (flush per
            # iteration so the async ring's deferred commit can't make the
            # loop overshoot the boundary-landing target)
            while int(eng.pos[0]) < 32:
                eng.step_once()
                eng.flush()
            if rewind:
                assert eng._rewind_lane(0)
                assert int(eng.pos[0]) == 24 and 24 % eng.page == 0
                pt = np.asarray(eng.state.page_table[:, 0])
                assert (pt[pt >= 0] < 24 // eng.page).all(), \
                    "wholly-rewound pages must be unmapped"
            while eng.lanes[0].request is not None:
                eng.step_once()
                eng.flush()
            return req.result

        base, rew = run(False), run(True)
        np.testing.assert_array_equal(base, rew)

    def test_thaw_with_full_pool_evicts_coldest(self, tiny_f32):
        """thaw_lane on a saturated pool must evict the coldest resident
        page (frozen pages first), stash it with the forced-freeze timer,
        and install the thawed page in its slot."""
        cfg, params = tiny_f32
        from repro.core.paging import PagedController
        L, P, page = 2, 4, cfg.freeze.page_size
        kvh, hd = 2, cfg.head_dim
        ctl = PagedController(cfg=cfg, batch=1, max_active_pages=P)
        rng = np.random.RandomState(0)
        pool = {"k": rng.randn(L, 1, P, page, kvh, hd).astype(np.float32),
                "v": rng.randn(L, 1, P, page, kvh, hd).astype(np.float32),
                "page_table": np.tile(np.arange(5, 9, dtype=np.int32),
                                      (L, 1, 1)),
                "slot_mask": np.ones((L, 1, P, page), bool)}
        fstate = {"c": np.tile(np.array([3, 0, 1, 0], np.int32), (L, 1, 1)),
                  "d": np.zeros((L, 1, P), np.int32),
                  "frozen": np.tile(np.array([True, False, False, False]),
                                    (L, 1, 1)),
                  "frozen_at": np.zeros((L, 1, P), np.int32)}
        stash_k = rng.randn(page, kvh, hd).astype(np.float32)
        for l in range(L):
            ctl.stash(l, 0, 2, stash_k, stash_k, d=50)
        n = ctl.thaw_lane(pool, fstate, 0, 0, keep_gids=(8,),
                          reserve_slots=0)
        assert n == L and ctl.n_thaw == L
        for l in range(L):
            # gid 2 resident and un-frozen, in the evicted page's slot
            where = np.nonzero(pool["page_table"][l, 0] == 2)[0]
            assert len(where) == 1 and where[0] == 0, \
                "thaw must land in the frozen victim's slot"
            assert not fstate["frozen"][l, 0, where[0]]
            np.testing.assert_array_equal(pool["k"][l, 0, where[0]], stash_k)
            # the frozen victim (gid 5) was stashed in turn, durable timer
            key = (l, 0, 5)
            assert key in ctl.store and key in ctl.frozen_meta
            assert ctl.frozen_meta[key]["d"] == cfg.freeze.page_size
            assert 5 not in pool["page_table"][l, 0]

    def test_thaw_of_chunked_prefill_overflow_page(self):
        """A page stashed at install because the prompt overflowed the
        device pool must be recoverable by an entropy-driven thaw: with
        the pool still saturated, thaw_lane evicts a cold resident page
        and remaps the overflow page into its slot."""
        cfg = get_config("llama3-8b-tiny")
        fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                                 tau_mode="quantile", quantile=0.6,
                                 k_soft=1.0, recovery_enabled=False)
        cfg = dataclasses.replace(cfg, freeze=fc)
        params = MD.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(4)
        eng = PagedContinuousEngine(cfg, params, max_seq=256, n_lanes=1,
                                    max_active_pages=6, prefill_chunk=16)
        # 48-token prompt -> 64 bucket = 8 pages > 5 resident: gids 0..2
        # overflow into the host store at install
        req = Request(1, rng.randint(0, cfg.vocab_size, size=48).astype(
            np.int32), 40, SamplingParams(temperature=0.7))
        eng.admit(req)
        while eng.prefills:
            eng.step_once()
        assert {k[2] for k in eng.ctl.frozen_meta if k[1] == 0} \
            >= {0, 1, 2}
        pool, fstate = eng._pull_lanes([0])
        n = eng.ctl.thaw_lane(pool, fstate, 0, 0,
                              keep_gids=eng._keep_gids(0), reserve_slots=1)
        assert n > 0 and eng.ctl.n_thaw == n
        thawed = [gid for gid in (0, 1, 2)
                  if all((pool["page_table"][l, 0] == gid).any()
                         for l in range(eng.L_attn))]
        assert thawed, "no overflow prompt page came back resident"
        eng._push_lanes(pool, fstate, [0])
        # decode still completes after the host rearranged the pool
        while eng.lanes[0].request is not None:
            eng.step_once()
        assert req.result.shape == (40,)

    def test_entropy_spikes_drive_thaws_end_to_end(self):
        """Full loop: freeze pressure stashes pages, sustained entropy
        spikes escalate to FR, pending thaws are serviced at page-boundary
        ticks, and every request still completes with no host-store
        leaks."""
        cfg = get_config("llama3-8b-tiny")
        fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                                 tau_mode="quantile", quantile=0.6,
                                 k_soft=0.7, recovery_enabled=True,
                                 entropy_abs_threshold=0.5, rewalk_tokens=6)
        cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
        params = MD.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        eng = PagedContinuousEngine(cfg, params, max_seq=256, n_lanes=2,
                                    max_active_pages=6, prefill_chunk=16,
                                    rewind_cooldown=12)
        s = Scheduler(eng)
        uids = [s.submit(rng.randint(0, cfg.vocab_size, size=sp), n,
                         SamplingParams(temperature=0.7))
                for sp, n in ((48, 70), (20, 50))]
        s.run()
        for u, n in zip(uids, (70, 50)):
            assert s.done[u].result.shape == (n,)
        assert eng.ctl.n_thaw > 0, "no thaw was ever serviced"
        assert sum(s.done[u].telemetry.rewinds for u in uids) > 0
        assert any(s.done[u].telemetry.recovery_events for u in uids)
        assert not eng.ctl.frozen_meta and not eng.ctl.store


class TestBoundedPool:
    @pytest.fixture(scope="class")
    def bounded_run(self):
        cfg = get_config("llama3-8b-tiny")
        fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                                 tau_mode="quantile", quantile=0.6,
                                 k_soft=1.0, recovery_enabled=False)
        cfg = dataclasses.replace(cfg, freeze=fc)
        params = MD.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        eng = PagedContinuousEngine(cfg, params, max_seq=256, n_lanes=2,
                                    max_active_pages=6, prefill_chunk=16)
        s = Scheduler(eng)
        uids = [s.submit(rng.randint(0, cfg.vocab_size, size=sp), n,
                         SamplingParams(temperature=0.7))
                for sp, n in ((48, 60), (12, 20), (20, 24))]
        s.run()
        return eng, s, uids

    def test_all_complete_and_swapping_happened(self, bounded_run):
        eng, s, uids = bounded_run
        for u, n in zip(uids, (60, 20, 24)):
            assert s.done[u].result.shape == (n,)
        # context (64 prompt bucket + 60 decode) far exceeds the 48-slot
        # pool: pages must have been swapped out and back in
        assert eng.ctl.n_swap_out > 0
        assert eng.ctl.n_swap_in > 0

    def test_active_kv_is_bounded_by_pool(self, bounded_run):
        """The whole point: per-lane active KV never exceeds P * page even
        though the context grows past it."""
        eng, s, uids = bounded_run
        t = s.done[uids[0]].telemetry
        pool_slots = 6 * 8
        assert max(t.active_kv) <= pool_slots
        assert t.total_kv[-1] > pool_slots       # context outgrew the pool
        assert t.compression > 0.3

    def test_lane_reuse_leaks_nothing(self, bounded_run):
        """After the run every lane retired: page tables must be unmapped
        and the controller's per-lane store empty."""
        eng, _, _ = bounded_run
        assert int(np.asarray((eng.state.page_table >= 0).sum())) == 0
        assert not eng.ctl.frozen_meta
        assert eng.kv_device_bytes == eng.state.k.nbytes + eng.state.v.nbytes

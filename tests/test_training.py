"""Training substrate: loss goes down; checkpoint roundtrip; MoE routing."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models import moe as MOE
from repro.training import checkpoint as CKPT
from repro.training import data as DATA
from repro.training import train_step as TS


def test_loss_decreases():
    cfg = dataclasses.replace(get_config("llama3-8b-tiny"), dtype="float32",
                              vocab_size=128)
    key = jax.random.PRNGKey(0)
    state = TS.init_train_state(key, cfg)
    it = DATA.synthetic_lm(DATA.DataConfig(cfg.vocab_size, 64, 8))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = TS.train_step(state, batch, cfg, lr=1e-3)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_checkpoint_roundtrip():
    cfg = get_config("olmoe-1b-7b-tiny")
    key = jax.random.PRNGKey(1)
    state = TS.init_train_state(key, cfg)
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(f"{d}/ck.msgpack", state.params)
        like = jax.tree_util.tree_map(jnp.zeros_like, state.params)
        restored = CKPT.restore(f"{d}/ck.msgpack", like)
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestMoE:
    def _cfg(self, **kw) -> ModelConfig:
        base = get_config("olmoe-1b-7b-tiny")
        return dataclasses.replace(base, dtype="float32", **kw)

    def test_routing_conservation(self):
        """With generous capacity, every token's combine weights sum to 1."""
        cfg = self._cfg(capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        from repro.models.layers import init_from_schema
        p = init_from_schema(key, MOE.moe_schema(cfg), jnp.float32)
        x = jax.random.normal(key, (2, 8, cfg.d_model))
        logits = jnp.einsum("bsd,de->bse", x, p["router"])
        probs = jax.nn.softmax(logits, -1)
        C = MOE.capacity(8, cfg)
        dispatch, combine, aux = MOE.route(probs, cfg, C)
        np.testing.assert_allclose(np.asarray(combine.sum(axis=(-1, -2))), 1.0,
                                   rtol=1e-5)
        # each (token, expert) pair dispatched at most once
        assert float(dispatch.max()) <= 1.0
        # capacity respected per expert
        assert (np.asarray(dispatch.sum(axis=1)) <= C + 1e-6).all()

    def test_capacity_drop(self):
        """With capacity 1 and identical tokens, most tokens drop."""
        cfg = self._cfg(capacity_factor=1e-6, experts_per_token=1)
        probs = jnp.ones((1, 8, cfg.num_experts)) / cfg.num_experts
        dispatch, combine, _ = MOE.route(probs, cfg, 1)
        assert float(dispatch.sum()) <= cfg.num_experts

    def test_expert_specialization_signal(self):
        """Aux loss is minimized by a uniform router, higher when collapsed."""
        cfg = self._cfg()
        E = cfg.num_experts
        uniform = jnp.ones((2, 16, E)) / E
        collapsed = jnp.zeros((2, 16, E)).at[..., 0].set(1.0)
        _, _, aux_u = MOE.route(uniform, cfg, 8)
        _, _, aux_c = MOE.route(collapsed, cfg, 8)
        assert float(aux_c) > float(aux_u)

    def test_moe_forward_padding(self):
        """Sequence not divisible by group size still works."""
        cfg = self._cfg()
        from repro.models.layers import init_from_schema
        p = init_from_schema(jax.random.PRNGKey(0), MOE.moe_schema(cfg),
                             jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 300, cfg.d_model))
        y, aux = MOE.moe_forward(p, x, cfg, group_size=256)
        assert y.shape == x.shape
        assert not bool(jnp.isnan(y).any())

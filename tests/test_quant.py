"""Deterministic seeded checks for core.quant — the always-on mirror of
the hypothesis properties in tests/test_quant_properties.py (which skip
entirely when the library is absent, as in the pinned CI image).

Covers the same invariants on fixed RandomState pages: the elementwise
round-trip bound across magnitudes, scale correctness on degenerate
pages (all-zero, single-outlier), and payload byte-stability across
freeze->stash->thaw->rewind width changes (no double quantization).
"""
import numpy as np
import pytest

from repro.core import quant

MODES = [quant.QUANT_INT8] + (
    [quant.QUANT_FP8] if quant.fp8_supported() else [])
_QMAX = {quant.QUANT_INT8: 127.0, quant.QUANT_FP8: 448.0}


def _page(seed: int, mag: int = 0, page=8, kvh=4, hd=8) -> np.ndarray:
    rs = np.random.RandomState(seed)
    return (rs.standard_normal((page, kvh, hd)) * 10.0 ** mag
            ).astype(np.float32)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("mag", [-20, -6, -1, 0, 1, 6, 20])
def test_roundtrip_error_within_bound(mode, mag):
    for seed in range(5):
        page = _page(seed, mag)
        payload, sc = quant.quantize_page(page, mode)
        assert payload.dtype.itemsize == 1          # the stash stores bytes
        assert np.isfinite(sc).all()
        dq = quant.dequantize_page(payload, sc)
        bound = quant.roundtrip_bound(page, mode, sc)
        assert (np.abs(page - dq) <= bound).all()


@pytest.mark.parametrize("mode", MODES)
def test_all_zero_page_and_head(mode):
    payload, sc = quant.quantize_page(np.zeros((8, 4, 8), np.float32), mode)
    np.testing.assert_array_equal(sc, 1.0)          # identity, never 0/inf
    np.testing.assert_array_equal(quant.dequantize_page(payload, sc), 0.0)
    page = _page(0)
    page[:, 2, :] = 0.0                             # one dead head
    payload, sc = quant.quantize_page(page, mode)
    assert sc[2] == 1.0
    np.testing.assert_array_equal(
        quant.dequantize_page(payload, sc)[:, 2, :], 0.0)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("sign", [-1.0, 1.0])
def test_single_outlier_pins_head_scale(mode, sign):
    page = _page(1, mag=-2)
    page[3, 1, 2] = sign * 5e4
    payload, sc = quant.quantize_page(page, mode)
    np.testing.assert_allclose(sc[1], 5e4 / _QMAX[mode], rtol=1e-6)
    dq = quant.dequantize_page(payload, sc)
    np.testing.assert_allclose(dq[3, 1, 2], page[3, 1, 2], rtol=1e-5)
    assert (np.abs(page - dq) <=
            quant.roundtrip_bound(page, mode, sc)).all()


@pytest.mark.parametrize("mode", MODES)
def test_cycles_never_double_quantize(mode):
    """quantize once, then stash/thaw width changes forever after: the
    payload bytes must be stable (narrow_payload and scale-carrying
    quantize_page are pure width casts on an already-quantized page)."""
    pool_dtypes = [np.float32]
    try:
        from ml_dtypes import bfloat16
        pool_dtypes.append(bfloat16)
    except ImportError:                             # pragma: no cover
        pass
    for pool_dtype in pool_dtypes:
        page = _page(2)
        payload, sc = quant.quantize_page(page, mode)
        ref_bytes = payload.tobytes()
        pool_page = np.asarray(payload, np.float32).astype(pool_dtype)
        for _ in range(3):
            stashed = quant.narrow_payload(pool_page, mode)
            assert stashed.tobytes() == ref_bytes
            # quantizing on-grid values with the stored scales is a no-op:
            # a host-dequantized page re-quantizes to the same bytes
            requant, _ = quant.quantize_page(
                quant.dequantize_page(stashed, sc), mode, scales=sc)
            assert requant.tobytes() == ref_bytes
            pool_page = np.asarray(stashed, np.float32).astype(pool_dtype)
        dq = quant.dequantize_page(quant.narrow_payload(pool_page, mode), sc)
        assert (np.abs(page - dq) <=
                quant.roundtrip_bound(page, mode, sc)).all()


def test_resolve_mode_validation():
    assert quant.resolve_mode("none") == quant.QUANT_NONE
    assert quant.resolve_mode("int8") == quant.QUANT_INT8
    with pytest.raises(ValueError, match="kv_quant"):
        quant.resolve_mode("int4")
    if quant.fp8_supported():
        assert quant.resolve_mode("fp8") == quant.QUANT_FP8

"""Unit + property tests for the ASR-KF-EGR freeze state machine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import FreezeConfig
from repro.core.freeze import (FreezeState, effective_tau, freeze_update,
                               full_reset, init_freeze_state, schedule,
                               soft_reset, window_reset)


def mk_cfg(**kw):
    base = dict(window=4, tau=0.5, k_soft=2.0, history=10**6,
                recovery_enabled=False)
    base.update(kw)
    return FreezeConfig(**base)


class TestSchedule:
    def test_paper_examples(self):
        """§3.4: c=4 -> d=1, c=9 -> d=1, c=16 -> d=2 (k=2)."""
        c = jnp.array([0, 1, 2, 3, 4, 9, 16, 25, 36])
        d = schedule(c, 2.0)
        np.testing.assert_array_equal(d, [0, 0, 0, 0, 1, 1, 2, 2, 3])

    def test_gentle_early(self):
        """First detections yield d=0 (no freeze)."""
        assert int(schedule(jnp.array(1), 2.0)) == 0
        assert int(schedule(jnp.array(3), 2.0)) == 0

    def test_sublinear_growth(self):
        c = jnp.arange(1, 1000)
        d = schedule(c, 2.0)
        # d grows strictly slower than linear: d <= sqrt(c)/2
        assert bool(jnp.all(d <= jnp.sqrt(c.astype(jnp.float32)) / 2))


class TestFreezeUpdate:
    def test_window_never_frozen(self):
        cfg = mk_cfg(window=4)
        state = init_freeze_state(1, 16)
        state = state._replace(c=jnp.full((1, 16), 100, jnp.int32))
        rel = jnp.zeros((1, 16))  # everything low-importance
        new, info = freeze_update(state, rel, jnp.int32(9), jnp.int32(0), cfg)
        frozen = np.asarray(new.frozen[0])
        # slots 6..9 are the K=4 most recent -> never frozen
        assert not frozen[6:10].any()
        # slots beyond pos don't exist -> never frozen
        assert not frozen[10:].any()
        # old low-importance slots with high counters freeze
        assert frozen[0:6].all()

    def test_counter_accumulates_then_freezes(self):
        """A token must be flagged repeatedly before it freezes (c=4 @ k=2)."""
        cfg = mk_cfg(window=2)
        state = init_freeze_state(1, 8)
        rel = jnp.zeros((1, 8))
        for step in range(3):
            state, info = freeze_update(state, rel, jnp.int32(7),
                                        jnp.int32(step), cfg)
            assert not bool(info["just_frozen"].any()), step
        state, info = freeze_update(state, rel, jnp.int32(7), jnp.int32(3), cfg)
        assert bool(info["just_frozen"][0, :6].all())

    def test_rolling_restore(self):
        """d=1 freeze lasts exactly one step, then the slot is restored."""
        cfg = mk_cfg(window=2)
        state = init_freeze_state(1, 8)
        state = state._replace(c=jnp.full((1, 8), 3, jnp.int32))
        rel = jnp.zeros((1, 8))
        state, info = freeze_update(state, rel, jnp.int32(7), jnp.int32(0), cfg)
        assert bool(state.frozen[0, 0])           # c=4 -> d=1 -> frozen
        high = jnp.full((1, 8), 10.0)
        state, info = freeze_update(state, high, jnp.int32(7), jnp.int32(1), cfg)
        assert bool(info["restored"][0, 0])
        assert not bool(state.frozen[0, 0])       # reversibility

    def test_frozen_excluded_from_flagging(self):
        cfg = mk_cfg(window=2)
        state = init_freeze_state(1, 8)
        state = state._replace(
            frozen=jnp.ones((1, 8), bool), d=jnp.full((1, 8), 5, jnp.int32))
        rel = jnp.zeros((1, 8))
        new, info = freeze_update(state, rel, jnp.int32(7), jnp.int32(0), cfg)
        assert not bool(info["just_frozen"].any())
        np.testing.assert_array_equal(np.asarray(new.c), 0)  # no new counts

    def test_history_decay(self):
        cfg = mk_cfg(window=2, history=4)
        state = init_freeze_state(1, 8)
        state = state._replace(c=jnp.full((1, 8), 2, jnp.int32))
        rel = jnp.full((1, 8), 10.0)  # nothing flagged
        new, _ = freeze_update(state, rel, jnp.int32(7), jnp.int32(3), cfg)
        np.testing.assert_array_equal(np.asarray(new.c), 1)  # decayed at step 3

    def test_quantile_tau_flags_fraction(self):
        cfg = mk_cfg(window=0, tau_mode="quantile", quantile=0.5)
        rel = jnp.arange(32, dtype=jnp.float32)[None, :]
        eligible = jnp.ones((1, 32), bool)
        tau = effective_tau(rel, eligible, cfg)
        frac = float(jnp.mean(rel < tau))
        assert 0.4 <= frac <= 0.6


class TestRecoveryActions:
    def _frozen_state(self):
        s = init_freeze_state(2, 8)
        return s._replace(
            frozen=jnp.ones((2, 8), bool),
            d=jnp.array([[1, 2, 3, 1, 2, 3, 1, 2]] * 2, jnp.int32),
            frozen_at=jnp.full((2, 8), 100, jnp.int32))

    def test_soft_reset_unfreezes_long_timers(self):
        s = self._frozen_state()
        sel = jnp.array([True, False])
        new = soft_reset(s, sel)
        f = np.asarray(new.frozen)
        assert not f[0][np.asarray(s.d[0]) > 1].any()
        assert f[0][np.asarray(s.d[0]) == 1].all()   # d=1 untouched by SR
        assert f[1].all()                             # unselected seq untouched

    def test_window_reset_only_recent(self):
        s = self._frozen_state()
        s = s._replace(frozen_at=jnp.array(
            [[0, 0, 0, 0, 100, 100, 100, 100]] * 2, jnp.int32))
        new = window_reset(s, jnp.array([True, True]), jnp.int32(110), 20)
        f = np.asarray(new.frozen)
        assert f[:, :4].all() and not f[:, 4:].any()

    def test_full_reset_clears_everything(self):
        s = self._frozen_state()
        s = s._replace(c=jnp.full((2, 8), 9, jnp.int32))
        new = full_reset(s, jnp.array([True, True]))
        assert not np.asarray(new.frozen).any()
        np.testing.assert_array_equal(np.asarray(new.c), 0)
        np.testing.assert_array_equal(np.asarray(new.d), 0)


# ------------------------------------------------------------------ #
# Property tests (hypothesis)
# ------------------------------------------------------------------ #
@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    seq=st.integers(8, 64),
    window=st.integers(0, 8),
    steps=st.integers(1, 10),
    ksoft=st.floats(0.5, 4.0),
)
def test_freeze_invariants(seed, seq, window, steps, ksoft):
    """System invariants hold for arbitrary relevance streams."""
    cfg = mk_cfg(window=window, k_soft=ksoft, tau=0.5)
    rng = np.random.RandomState(seed)
    state = init_freeze_state(2, seq)
    pos = seq - 1
    for step in range(steps):
        rel = jnp.asarray(rng.rand(2, seq).astype(np.float32))
        prev = state
        state, info = freeze_update(state, rel, jnp.int32(pos),
                                    jnp.int32(step), cfg)
        frozen = np.asarray(state.frozen)
        d = np.asarray(state.d)
        c = np.asarray(state.c)
        idx = np.arange(seq)[None, :]
        exists = np.broadcast_to(idx <= pos, frozen.shape)
        # 1. never freeze inside the sliding window or beyond pos
        assert not frozen[~exists].any()
        assert not frozen[:, max(0, pos - window + 1):].any()
        # 2. timers non-negative; frozen slots carry positive-or-zero timers
        assert (d >= 0).all()
        # 3. counters never decrease except via history decay (disabled here)
        assert (c >= np.asarray(prev.c) - 0).all()
        # 4. a slot cannot be both just_frozen and restored
        jf = np.asarray(info["just_frozen"])
        rs = np.asarray(info["restored"])
        assert not (jf & rs).any()
        # 5. active = exists & ~frozen
        np.testing.assert_array_equal(
            np.asarray(info["active"]), exists & ~frozen)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_reversibility_no_permanent_loss(seed):
    """Paper's core claim: freezing is reversible — any frozen token returns
    to the active set within a bounded number of steps once it stops being
    flagged (relevance above tau)."""
    cfg = mk_cfg(window=2, k_soft=1.0)
    rng = np.random.RandomState(seed)
    state = init_freeze_state(1, 16)
    # aggressively freeze for a while
    for step in range(20):
        state, _ = freeze_update(state, jnp.zeros((1, 16)), jnp.int32(15),
                                 jnp.int32(step), cfg)
    max_d = int(np.asarray(state.d).max())
    # now everything is relevant: all slots must unfreeze within max_d+1 steps
    for step in range(20, 21 + max_d):
        state, _ = freeze_update(state, jnp.full((1, 16), 10.0),
                                 jnp.int32(15), jnp.int32(step), cfg)
    assert not np.asarray(state.frozen).any()

"""Unit tests for the ASR-KF-EGR freeze state machine.  The hypothesis
property tests live in test_freeze_properties.py so this module stays
collectable where hypothesis is not installed."""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FreezeConfig
from repro.core.freeze import (FreezeState, effective_tau, freeze_update,
                               full_reset, init_freeze_state, schedule,
                               soft_reset, window_reset)


def mk_cfg(**kw):
    base = dict(window=4, tau=0.5, k_soft=2.0, history=10**6,
                recovery_enabled=False)
    base.update(kw)
    return FreezeConfig(**base)


class TestSchedule:
    def test_paper_examples(self):
        """§3.4: c=4 -> d=1, c=9 -> d=1, c=16 -> d=2 (k=2)."""
        c = jnp.array([0, 1, 2, 3, 4, 9, 16, 25, 36])
        d = schedule(c, 2.0)
        np.testing.assert_array_equal(d, [0, 0, 0, 0, 1, 1, 2, 2, 3])

    def test_gentle_early(self):
        """First detections yield d=0 (no freeze)."""
        assert int(schedule(jnp.array(1), 2.0)) == 0
        assert int(schedule(jnp.array(3), 2.0)) == 0

    def test_sublinear_growth(self):
        c = jnp.arange(1, 1000)
        d = schedule(c, 2.0)
        # d grows strictly slower than linear: d <= sqrt(c)/2
        assert bool(jnp.all(d <= jnp.sqrt(c.astype(jnp.float32)) / 2))


class TestFreezeUpdate:
    def test_window_never_frozen(self):
        cfg = mk_cfg(window=4)
        state = init_freeze_state(1, 16)
        state = state._replace(c=jnp.full((1, 16), 100, jnp.int32))
        rel = jnp.zeros((1, 16))  # everything low-importance
        new, info = freeze_update(state, rel, jnp.int32(9), jnp.int32(0), cfg)
        frozen = np.asarray(new.frozen[0])
        # slots 6..9 are the K=4 most recent -> never frozen
        assert not frozen[6:10].any()
        # slots beyond pos don't exist -> never frozen
        assert not frozen[10:].any()
        # old low-importance slots with high counters freeze
        assert frozen[0:6].all()

    def test_counter_accumulates_then_freezes(self):
        """A token must be flagged repeatedly before it freezes (c=4 @ k=2)."""
        cfg = mk_cfg(window=2)
        state = init_freeze_state(1, 8)
        rel = jnp.zeros((1, 8))
        for step in range(3):
            state, info = freeze_update(state, rel, jnp.int32(7),
                                        jnp.int32(step), cfg)
            assert not bool(info["just_frozen"].any()), step
        state, info = freeze_update(state, rel, jnp.int32(7), jnp.int32(3), cfg)
        assert bool(info["just_frozen"][0, :6].all())

    def test_rolling_restore(self):
        """d=1 freeze lasts exactly one step, then the slot is restored."""
        cfg = mk_cfg(window=2)
        state = init_freeze_state(1, 8)
        state = state._replace(c=jnp.full((1, 8), 3, jnp.int32))
        rel = jnp.zeros((1, 8))
        state, info = freeze_update(state, rel, jnp.int32(7), jnp.int32(0), cfg)
        assert bool(state.frozen[0, 0])           # c=4 -> d=1 -> frozen
        high = jnp.full((1, 8), 10.0)
        state, info = freeze_update(state, high, jnp.int32(7), jnp.int32(1), cfg)
        assert bool(info["restored"][0, 0])
        assert not bool(state.frozen[0, 0])       # reversibility

    def test_frozen_excluded_from_flagging(self):
        cfg = mk_cfg(window=2)
        state = init_freeze_state(1, 8)
        state = state._replace(
            frozen=jnp.ones((1, 8), bool), d=jnp.full((1, 8), 5, jnp.int32))
        rel = jnp.zeros((1, 8))
        new, info = freeze_update(state, rel, jnp.int32(7), jnp.int32(0), cfg)
        assert not bool(info["just_frozen"].any())
        np.testing.assert_array_equal(np.asarray(new.c), 0)  # no new counts

    def test_history_decay(self):
        cfg = mk_cfg(window=2, history=4)
        state = init_freeze_state(1, 8)
        state = state._replace(c=jnp.full((1, 8), 2, jnp.int32))
        rel = jnp.full((1, 8), 10.0)  # nothing flagged
        new, _ = freeze_update(state, rel, jnp.int32(7), jnp.int32(3), cfg)
        np.testing.assert_array_equal(np.asarray(new.c), 1)  # decayed at step 3

    def test_quantile_tau_flags_fraction(self):
        cfg = mk_cfg(window=0, tau_mode="quantile", quantile=0.5)
        rel = jnp.arange(32, dtype=jnp.float32)[None, :]
        eligible = jnp.ones((1, 32), bool)
        tau = effective_tau(rel, eligible, cfg)
        frac = float(jnp.mean(rel < tau))
        assert 0.4 <= frac <= 0.6


class TestRecoveryActions:
    def _frozen_state(self):
        s = init_freeze_state(2, 8)
        return s._replace(
            frozen=jnp.ones((2, 8), bool),
            d=jnp.array([[1, 2, 3, 1, 2, 3, 1, 2]] * 2, jnp.int32),
            frozen_at=jnp.full((2, 8), 100, jnp.int32))

    def test_soft_reset_unfreezes_long_timers(self):
        s = self._frozen_state()
        sel = jnp.array([True, False])
        new = soft_reset(s, sel)
        f = np.asarray(new.frozen)
        assert not f[0][np.asarray(s.d[0]) > 1].any()
        assert f[0][np.asarray(s.d[0]) == 1].all()   # d=1 untouched by SR
        assert f[1].all()                             # unselected seq untouched

    def test_window_reset_only_recent(self):
        s = self._frozen_state()
        s = s._replace(frozen_at=jnp.array(
            [[0, 0, 0, 0, 100, 100, 100, 100]] * 2, jnp.int32))
        new = window_reset(s, jnp.array([True, True]), jnp.int32(110), 20)
        f = np.asarray(new.frozen)
        assert f[:, :4].all() and not f[:, 4:].any()

    def test_full_reset_clears_everything(self):
        s = self._frozen_state()
        s = s._replace(c=jnp.full((2, 8), 9, jnp.int32))
        new = full_reset(s, jnp.array([True, True]))
        assert not np.asarray(new.frozen).any()
        np.testing.assert_array_equal(np.asarray(new.c), 0)
        np.testing.assert_array_equal(np.asarray(new.d), 0)


class TestLaneReset:
    def test_reset_lane_clears_only_that_lane(self):
        from repro.core.freeze import reset_lane
        s = init_freeze_state(3, 8)._replace(
            c=jnp.full((3, 8), 5, jnp.int32),
            d=jnp.full((3, 8), 2, jnp.int32),
            frozen=jnp.ones((3, 8), bool),
            frozen_at=jnp.full((3, 8), 7, jnp.int32))
        new = reset_lane(s, 1)
        assert not np.asarray(new.frozen[1]).any()
        np.testing.assert_array_equal(np.asarray(new.c[1]), 0)
        np.testing.assert_array_equal(np.asarray(new.frozen_at[1]), -1)
        for other in (0, 2):
            assert np.asarray(new.frozen[other]).all()
            np.testing.assert_array_equal(np.asarray(new.c[other]), 5)

    def test_reset_lane_stacked(self):
        """Works on the transformer's stacked (L, B, S) freeze state too."""
        from repro.core.freeze import reset_lane
        s = FreezeState(
            c=jnp.full((2, 3, 8), 5, jnp.int32),
            d=jnp.full((2, 3, 8), 2, jnp.int32),
            frozen=jnp.ones((2, 3, 8), bool),
            frozen_at=jnp.full((2, 3, 8), 7, jnp.int32))
        new = reset_lane(s, 2)
        assert not np.asarray(new.frozen[:, 2]).any()
        assert np.asarray(new.frozen[:, :2]).all()


class TestPerLaneStep:
    def test_per_lane_pos_and_step_match_scalar(self):
        """(B,) pos/step vectors with equal entries reproduce the scalar
        path exactly — the continuous-batching core is a strict
        generalization."""
        cfg = mk_cfg(window=2, history=4)
        rng = np.random.RandomState(0)
        s_scalar = init_freeze_state(2, 8)
        s_vec = init_freeze_state(2, 8)
        for step in range(6):
            rel = jnp.asarray(rng.rand(2, 8).astype(np.float32))
            s_scalar, i1 = freeze_update(s_scalar, rel, jnp.int32(7),
                                         jnp.int32(step), cfg)
            s_vec, i2 = freeze_update(
                s_vec, rel, jnp.full((2,), 7, jnp.int32),
                jnp.full((2,), step, jnp.int32), cfg)
            for a, b in zip(s_scalar, s_vec):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(i1["n_active"]),
                                          np.asarray(i2["n_active"]))

    def test_lanes_update_independently(self):
        """Different per-lane positions: the newer lane's window protects
        different slots than the older lane's."""
        cfg = mk_cfg(window=2)
        state = init_freeze_state(2, 16)._replace(
            c=jnp.full((2, 16), 100, jnp.int32))
        rel = jnp.zeros((2, 16))
        pos = jnp.array([15, 7], jnp.int32)
        step = jnp.array([9, 2], jnp.int32)
        new, _ = freeze_update(state, rel, pos, step, cfg)
        frozen = np.asarray(new.frozen)
        assert frozen[0, :14].all() and not frozen[0, 14:].any()
        assert frozen[1, :6].all() and not frozen[1, 6:].any()
        # frozen_at records each lane's own step counter
        fa = np.asarray(new.frozen_at)
        assert (fa[0, :14] == 9).all() and (fa[1, :6] == 2).all()

    def test_window_reset_per_lane_step(self):
        """WR with per-lane step counters: recency is judged against each
        lane's own clock."""
        s = init_freeze_state(2, 8)._replace(
            frozen=jnp.ones((2, 8), bool),
            d=jnp.full((2, 8), 2, jnp.int32),
            frozen_at=jnp.full((2, 8), 90, jnp.int32))
        # lane 0's clock is at 100 (frozen 10 ago: recent); lane 1's at 200
        new = window_reset(s, jnp.array([True, True]),
                           jnp.array([100, 200], jnp.int32), 20)
        f = np.asarray(new.frozen)
        assert not f[0].any() and f[1].all()

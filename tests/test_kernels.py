"""Pallas kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True
executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FreezeConfig
from repro.core import quant
from repro.core.freeze import init_freeze_state
from repro.kernels import ref
from repro.kernels.freeze_decode_attn import freeze_decode_attention
from repro.kernels.paged_decode_attn import paged_decode_attention_kernel
from repro.kernels.relevance_freeze import relevance_freeze_update

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}

# Documented numerics envelope for quantized paged attention vs the
# full-precision oracle (docs/quantization.md).  Per-element K/V error is
# bounded by core.quant.roundtrip_bound (int8: scale/2 with scale =
# max|x|/127; fp8 e4m3: ~6% relative); softmax mixing keeps the output
# error the same order as the payload error, and these bounds hold with
# >2x margin across the sweep below.  bf16 pools are covered too: int8
# payloads (ints <= 127) and fp8 payloads (3 mantissa bits) are exact in
# bf16, so the envelope — which dominates bf16's own 2e-2 — is unchanged.
QUANT_TOLS = {"int8": dict(rtol=5e-2, atol=5e-2),
              "fp8": dict(rtol=2e-1, atol=1e-1)}


def _quantize_pool(pool, flags, mode):
    """Quantize the flagged pages of a (B, P, page, KVH, hd) pool the way
    the controller stores them: integer-valued payload cast back into the
    pool dtype, per-page per-kv-head scales ((B, P, KVH) f32, 1.0 where
    unflagged)."""
    arr = np.asarray(pool, np.float32)
    B, P, _, KVH, _ = arr.shape
    scales = np.ones((B, P, KVH), np.float32)
    out = arr.copy()
    for b in range(B):
        for p in range(P):
            if not flags[b, p]:
                continue
            payload, sc = quant.quantize_page(arr[b, p], mode)
            out[b, p] = np.asarray(payload, np.float32)
            scales[b, p] = sc
    return jnp.asarray(out, pool.dtype), scales


@pytest.mark.parametrize("B,S,H,KVH,hd,blk", [
    (1, 512, 8, 8, 64, 128),
    (2, 1024, 8, 4, 64, 256),     # GQA
    (2, 512, 4, 1, 128, 128),     # MQA
    (3, 768, 16, 8, 128, 256),    # non-pow2 batch
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_freeze_decode_attn_sweep(B, S, H, KVH, hd, blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), dtype)
    mask = jax.random.bernoulli(ks[3], 0.5, (B, S)).at[:, 0].set(True)
    out_k, rel_k = freeze_decode_attention(q, k, v, mask, block_s=blk,
                                           interpret=True)
    out_r, rel_r = ref.freeze_decode_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **TOLS[dtype])
    # slot-exact relevance parity: inactive slots (also inside partially
    # active blocks) report exactly 0 in kernel and reference alike
    np.testing.assert_allclose(np.asarray(rel_k), np.asarray(rel_r),
                               **TOLS[dtype])
    np.testing.assert_array_equal(np.asarray(rel_k)[~np.asarray(mask)], 0.0)


def test_freeze_decode_attn_skips_frozen_blocks():
    """A fully-frozen block must not contribute — result equals attention
    over only the active blocks."""
    B, S, H, hd, blk = 1, 512, 4, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    mask = jnp.ones((B, S), bool).at[:, blk:2 * blk].set(False)
    out_k, rel_k = freeze_decode_attention(q, k, v, mask, block_s=blk,
                                           interpret=True)
    out_r, _ = ref.freeze_decode_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(rel_k[:, blk:2 * blk]), 0.0)


@pytest.mark.parametrize("B,P,page,H,KVH,hd", [
    (1, 4, 128, 8, 8, 64),
    (2, 8, 64, 8, 2, 64),     # GQA
    (2, 6, 128, 4, 1, 128),   # MQA
    (3, 5, 32, 16, 8, 128),   # non-pow2 batch/pool, small pages
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attn_sweep(B, P, page, H, KVH, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (B, P, page, KVH, hd), dtype)
    vp = jax.random.normal(ks[2], (B, P, page, KVH, hd), dtype)
    sm = jax.random.bernoulli(ks[3], 0.5, (B, P, page))
    sm = sm.at[:, 0, 0].set(True)
    sm = sm.at[:, -1].set(False)      # one dead (fully-frozen) page
    out_k, rel_k = paged_decode_attention_kernel(q, kp, vp, sm, interpret=True)
    out_r, rel_r = ref.paged_decode_attention_ref(q, kp, vp, sm)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **TOLS[dtype])
    np.testing.assert_allclose(np.asarray(rel_k), np.asarray(rel_r),
                               **TOLS[dtype])
    np.testing.assert_array_equal(np.asarray(rel_k[:, -1]), 0.0)


@pytest.mark.parametrize("B,P,page,H,KVH,hd", [
    (1, 4, 128, 8, 8, 64),
    (2, 6, 64, 8, 2, 64),
])
def test_paged_decode_attn_unmapped_page_skip(B, P, page, H, KVH, hd):
    """A slot whose page-table entry is -1 must be skipped even if its slot
    mask claims valid tokens (stale mask bits after a host swap-out) — the
    per-lane page table is authoritative.  Output equals attention over the
    mapped pages only; unmapped pages report relevance 0."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (B, P, page, KVH, hd))
    vp = jax.random.normal(ks[2], (B, P, page, KVH, hd))
    sm = jnp.ones((B, P, page), bool)               # stale: claims all valid
    pt = jnp.zeros((B, P), jnp.int32).at[:, 1].set(-1)   # slot 1 unmapped
    out_k, rel_k = paged_decode_attention_kernel(q, kp, vp, sm, pt,
                                                 interpret=True)
    out_r, rel_r = ref.paged_decode_attention_ref(q, kp, vp, sm, pt)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(rel_k), np.asarray(rel_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(rel_k[:, 1]), 0.0)
    # cross-check against a hand-masked pool: unmapped == fully dead page
    out_m, _ = ref.paged_decode_attention_ref(
        q, kp, vp, sm.at[:, 1].set(False))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m),
                               rtol=2e-5, atol=2e-5)

    # fully-unmapped lane: all pages -1 -> zero output, zero relevance
    pt_dead = jnp.full((B, P), -1, jnp.int32)
    out_d, rel_d = paged_decode_attention_kernel(q, kp, vp, sm, pt_dead,
                                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out_d), 0.0)
    np.testing.assert_array_equal(np.asarray(rel_d), 0.0)


@pytest.mark.parametrize("B,P,page,H,KVH,hd", [
    (1, 4, 128, 8, 8, 64),
    (2, 6, 64, 8, 2, 64),
    (3, 5, 32, 16, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attn_page_visible(B, P, page, H, KVH, hd, dtype):
    """The per-page visibility mask (the recovery ladder's thaw-aware
    ~frozen) must gate attention AND relevance exactly like zeroing the
    page's slot mask: invisible pages contribute nothing and report
    relevance 0; flipping a page back to visible (a thaw) restores it."""
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (B, P, page, KVH, hd), dtype)
    vp = jax.random.normal(ks[2], (B, P, page, KVH, hd), dtype)
    sm = jnp.ones((B, P, page), bool)
    pt = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    vis = jax.random.bernoulli(ks[3], 0.5, (B, P)).at[:, 0].set(True)
    out_k, rel_k = paged_decode_attention_kernel(q, kp, vp, sm, pt, vis,
                                                 interpret=True)
    out_r, rel_r = ref.paged_decode_attention_ref(q, kp, vp, sm, pt, vis)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **TOLS[dtype])
    np.testing.assert_allclose(np.asarray(rel_k), np.asarray(rel_r),
                               **TOLS[dtype])
    np.testing.assert_array_equal(np.asarray(rel_k)[~np.asarray(vis)], 0.0)
    # invisible == mask-dead: hand-fold the visibility into the slot mask
    out_m, rel_m = ref.paged_decode_attention_ref(
        q, kp, vp, sm & vis[..., None], pt)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_m, np.float32), **TOLS[dtype])
    # thaw: all-visible equals no mask at all
    out_t, rel_t = paged_decode_attention_kernel(
        q, kp, vp, sm, pt, jnp.ones((B, P), bool), interpret=True)
    out_n, rel_n = paged_decode_attention_kernel(q, kp, vp, sm, pt,
                                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out_t), np.asarray(out_n))
    np.testing.assert_array_equal(np.asarray(rel_t), np.asarray(rel_n))


@pytest.mark.parametrize("B,P,page,H,KVH,hd", [
    (1, 4, 128, 8, 8, 64),
    (2, 8, 64, 8, 2, 64),     # GQA
    (2, 6, 128, 4, 1, 128),   # MQA
    (3, 5, 32, 16, 8, 128),   # non-pow2 batch/pool, small pages
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode_name", ["int8", "fp8"])
def test_paged_decode_attn_quant_sweep(B, P, page, H, KVH, hd, dtype,
                                       mode_name):
    """Quantized paged attention with a MIXED pool per lane — hot
    (full-precision), frozen-invisible, and quantized pages coexisting —
    checked two ways: kernel vs the dequantizing reference at baseline
    tightness (same math), and kernel vs the FULL-PRECISION f32 oracle
    within the documented QUANT_TOLS envelope (the lossy bound this PR
    ships under)."""
    if mode_name == "fp8" and not quant.fp8_supported():
        pytest.skip("ml_dtypes float8_e4m3fn unavailable")
    mode = quant.MODES[mode_name]
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (B, P, page, KVH, hd), dtype)
    vp = jax.random.normal(ks[2], (B, P, page, KVH, hd), dtype)
    sm = jax.random.bernoulli(ks[3], 0.7, (B, P, page)).at[:, 0, 0].set(True)
    pt = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    # page states: slot 1 frozen-invisible everywhere, odd slots quantized
    vis = jnp.ones((B, P), bool).at[:, 1].set(False)
    flags = np.zeros((B, P), bool)
    flags[:, 1::2] = True            # includes the invisible slot 1
    kq, ksc = _quantize_pool(kp, flags, mode)
    vq, vsc = _quantize_pool(vp, flags, mode)
    pq = jnp.asarray(flags.astype(np.int32))
    sc = jnp.asarray(np.stack([ksc, vsc], axis=2))      # (B, P, 2, KVH)
    out_k, rel_k = paged_decode_attention_kernel(q, kq, vq, sm, pt, vis,
                                                 pq, sc, interpret=True)
    out_r, rel_r = ref.paged_decode_attention_ref(q, kq, vq, sm, pt, vis,
                                                  pq, sc)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **TOLS[dtype])
    np.testing.assert_allclose(np.asarray(rel_k), np.asarray(rel_r),
                               **TOLS[dtype])
    np.testing.assert_array_equal(np.asarray(rel_k[:, 1]), 0.0)
    # lossy envelope vs the full-precision oracle on the ORIGINAL pool
    out_f, rel_f = ref.paged_decode_attention_ref(
        jnp.asarray(q, jnp.float32), jnp.asarray(kp, jnp.float32),
        jnp.asarray(vp, jnp.float32), sm, pt, vis)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_f), **QUANT_TOLS[mode_name])
    np.testing.assert_allclose(np.asarray(rel_k), np.asarray(rel_f),
                               **QUANT_TOLS[mode_name])


def test_paged_decode_attn_quant_none_bit_identical():
    """kv_quant="none" must not perturb a single bit: explicit all-zero
    flags + all-one scales equals omitting the quant operands entirely
    (the kernel's where(quant, scale, 1.0) multiply is identity)."""
    B, P, page, H, KVH, hd = 2, 6, 64, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (B, P, page, KVH, hd))
    vp = jax.random.normal(ks[2], (B, P, page, KVH, hd))
    sm = jax.random.bernoulli(ks[3], 0.5, (B, P, page)).at[:, 0, 0].set(True)
    pt = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    pq = jnp.zeros((B, P), jnp.int32)
    sc = jnp.ones((B, P, 2, KVH), jnp.float32)
    out_q, rel_q = paged_decode_attention_kernel(q, kp, vp, sm, pt, None,
                                                 pq, sc, interpret=True)
    out_n, rel_n = paged_decode_attention_kernel(q, kp, vp, sm, pt,
                                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_n))
    np.testing.assert_array_equal(np.asarray(rel_q), np.asarray(rel_n))
    out_rq, rel_rq = ref.paged_decode_attention_ref(q, kp, vp, sm, pt, None,
                                                    pq, sc)
    out_rn, rel_rn = ref.paged_decode_attention_ref(q, kp, vp, sm, pt)
    np.testing.assert_array_equal(np.asarray(out_rq), np.asarray(out_rn))
    np.testing.assert_array_equal(np.asarray(rel_rq), np.asarray(rel_rn))


def test_paged_decode_attn_quant_skipped_pages_inert():
    """A quantized page that is unmapped (page_table -1) or invisible
    (page_visible False) must be skipped BEFORE its scale is ever applied:
    poison those pages' scales with 1e9 — any leak would blow up the
    softmax — and require bit-equality with the unquantized run plus
    exact relevance 0 on the skipped slots."""
    B, P, page, H, KVH, hd = 2, 6, 64, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (B, P, page, KVH, hd))
    vp = jax.random.normal(ks[2], (B, P, page, KVH, hd))
    sm = jnp.ones((B, P, page), bool)
    pt = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    pt = pt.at[:, 1].set(-1)                      # slot 1 unmapped
    vis = jnp.ones((B, P), bool).at[:, 2].set(False)   # slot 2 frozen
    pq = jnp.zeros((B, P), jnp.int32).at[:, 1].set(1).at[:, 2].set(1)
    sc = jnp.ones((B, P, 2, KVH), jnp.float32)
    sc = sc.at[:, 1].set(1e9).at[:, 2].set(1e9)   # poison skipped slots
    out_q, rel_q = paged_decode_attention_kernel(q, kp, vp, sm, pt, vis,
                                                 pq, sc, interpret=True)
    out_n, rel_n = paged_decode_attention_kernel(q, kp, vp, sm, pt, vis,
                                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_n))
    np.testing.assert_array_equal(np.asarray(rel_q), np.asarray(rel_n))
    np.testing.assert_array_equal(np.asarray(rel_q[:, 1:3]), 0.0)
    assert np.isfinite(np.asarray(out_q)).all()
    out_rq, rel_rq = ref.paged_decode_attention_ref(q, kp, vp, sm, pt, vis,
                                                    pq, sc)
    out_rn, _ = ref.paged_decode_attention_ref(q, kp, vp, sm, pt, vis)
    np.testing.assert_array_equal(np.asarray(out_rq), np.asarray(out_rn))
    np.testing.assert_array_equal(np.asarray(rel_rq[:, 1:3]), 0.0)


@pytest.mark.parametrize("B,S,blk", [(1, 256, 64), (2, 1024, 256), (4, 512, 512)])
@pytest.mark.parametrize("window,ksoft,history", [(8, 2.0, 10**6), (4, 1.0, 64)])
def test_relevance_freeze_sweep(B, S, blk, window, ksoft, history):
    cfg = FreezeConfig(window=window, tau=0.5, k_soft=ksoft, history=history)
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    st = init_freeze_state(B, S)._replace(
        c=jax.random.randint(ks[0], (B, S), 0, 20),
        d=jax.random.randint(ks[1], (B, S), 0, 5),
        frozen=jax.random.bernoulli(ks[2], 0.3, (B, S)))
    rel = jax.random.uniform(ks[3], (B, S))
    pos, step = jnp.int32(S - 5), jnp.int32(history - 1)
    new_k, act_k = relevance_freeze_update(st, rel, pos, step, cfg,
                                           block_s=blk, interpret=True)
    new_r, info = ref.relevance_freeze_ref(st, rel, pos, step, cfg)
    for f in ("c", "d", "frozen", "frozen_at"):
        np.testing.assert_array_equal(np.asarray(getattr(new_k, f)),
                                      np.asarray(getattr(new_r, f)), err_msg=f)
    np.testing.assert_array_equal(np.asarray(act_k), np.asarray(info["active"]))

"""SLO-aware scheduling: EDF/priority queue ordering, FIFO degradation,
freeze-native lane preemption (suspend/resume) and its token-parity
guarantee, the static scheduler's mixed-sampling guard."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.engine import (
    ContinuousEngine, Engine, PagedContinuousEngine, Request)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler, StaticScheduler


@pytest.fixture(scope="module")
def tiny_f32():
    """f32 tiny model (exact argmax parity across preemption) with a small
    page size so pools stay cheap and pages actually stash."""
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.5, k_soft=1.0,
                             recovery_enabled=False)
    cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def paged_engine(cfg, params, n_lanes=2, pages=4, max_seq=128):
    return PagedContinuousEngine(cfg, params, max_seq=max_seq,
                                 n_lanes=n_lanes, max_active_pages=pages,
                                 prefill_chunk=8,
                                 # deterministic chunk split: the reference
                                 # run interleaves admissions differently
                                 burst_prefill=False)


def run_alone(cfg, params, req_args, **eng_kw):
    """Uninterrupted single-request reference on a fresh engine."""
    eng = paged_engine(cfg, params, **eng_kw)
    req = Request(1, *req_args)
    eng.admit(req)
    while req.result is None:
        eng.step_once()
    return np.asarray(req.result)


class TestPreemptResumeParity:
    def test_paged_token_parity_across_lanes(self, tiny_f32):
        """Suspend mid-decode, serve another request in the victim's lane,
        resume into a DIFFERENT lane: the victim's tokens must be
        identical to an uninterrupted run — the pool-slice restore is
        byte-exact and the sampling key is snapshot-stable."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(0)
        prompt = rng.randint(0, cfg.vocab_size, size=20).astype(np.int32)
        args = (prompt, 32, SamplingParams.greedy())
        ref = run_alone(cfg, params, args)

        eng = paged_engine(cfg, params)
        req = Request(1, *args)
        eng.admit(req)
        for _ in range(12):
            eng.step_once()
        snap = eng.suspend_lane(0)
        assert snap is not None and snap.started
        assert eng.lanes[0].request is None
        filler = Request(2, rng.randint(0, cfg.vocab_size, size=10).astype(
            np.int32), 8, SamplingParams.greedy())
        eng.admit(filler, lane=0)
        while filler.result is None:
            eng.step_once()
        assert eng.resume_lane(snap, lane=1) == 1
        while req.result is None:
            eng.step_once()
        np.testing.assert_array_equal(ref, req.result)

    def test_parity_with_recovery_and_pending_thaw(self, tiny_f32):
        """Suspension while the recovery ladder is mid-escalation (stashed
        pages, a pending FR thaw) must carry the ladder scalars and the
        thaw mark through the snapshot — the continuation replays the
        exact thaw the uninterrupted run performs."""
        cfg, params = tiny_f32
        fc = dataclasses.replace(cfg.freeze, quantile=0.55, k_soft=0.7,
                                 recovery_enabled=True,
                                 entropy_abs_threshold=0.5, rewalk_tokens=8)
        cfg = dataclasses.replace(cfg, freeze=fc)
        rng = np.random.RandomState(3)
        prompt = rng.randint(0, cfg.vocab_size, size=40).astype(np.int32)
        args = (prompt, 36, SamplingParams.greedy())
        kw = dict(pages=5, max_seq=160)
        ref = run_alone(cfg, params, args, **kw)

        for cut in (14, 24):
            eng = paged_engine(cfg, params, **kw)
            req = Request(1, *args)
            eng.admit(req)
            for _ in range(cut):
                eng.step_once()
            snap = eng.suspend_lane(0)
            assert snap is not None and snap.started
            eng.resume_lane(snap, lane=1)
            while req.result is None:
                eng.step_once()
            np.testing.assert_array_equal(ref, req.result,
                                          err_msg=f"cut={cut}")

    def test_parity_across_many_suspend_resume_cycles(self, tiny_f32):
        """Regression for the documented parity-envelope bug: under the
        aggressive recovery config, >= 4 suspend/resume cycles used to
        diverge from the uninterrupted run.  Two causes, both fixed:
        (1) ``staged_keys`` bookkeeping was dropped on export (the staged
        device bytes themselves always survived — the pool slice spans
        the staging slots — but losing the mark de-scheduled the resumed
        lane's remap-only thaw install, feeding Rewalk a different
        path); (2) thaw-candidate and prefetch score ties resolved by
        dict insertion order, which export/import permutes.  Repeated
        migration is now exact at any cycle count."""
        cfg, params = tiny_f32
        fc = dataclasses.replace(cfg.freeze, quantile=0.55, k_soft=0.7,
                                 recovery_enabled=True,
                                 entropy_abs_threshold=0.5, rewalk_tokens=8)
        cfg = dataclasses.replace(cfg, freeze=fc)
        rng = np.random.RandomState(3)
        prompt = rng.randint(0, cfg.vocab_size, size=40).astype(np.int32)
        args = (prompt, 36, SamplingParams.greedy())
        kw = dict(pages=5, max_seq=160)
        ref = run_alone(cfg, params, args, **kw)

        eng = paged_engine(cfg, params, **kw)
        req = Request(1, *args)
        eng.admit(req)
        lane, cycles = 0, 0
        for steps in (14, 8, 8, 8, 8):
            for _ in range(steps):
                if req.result is not None:
                    break
                eng.step_once()
            if req.result is not None:
                break
            snap = eng.suspend_lane(lane)
            assert snap is not None
            lane = 1 - lane
            eng.resume_lane(snap, lane=lane)
            cycles += 1
        assert cycles >= 4, "test premise: at least 4 migration cycles"
        while req.result is None:
            eng.step_once()
        np.testing.assert_array_equal(ref, req.result)

    def test_preemption_under_full_host_pool(self, tiny_f32):
        """Suspend a lane whose device pool is saturated and whose host
        store already holds stashed pages: the whole-lane export must move
        every page into the snapshot (the store forgets the lane), survive
        the lane being reused, and restore exactly on resume."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(5)
        prompt = rng.randint(0, cfg.vocab_size, size=40).astype(np.int32)
        args = (prompt, 40, SamplingParams.greedy())
        kw = dict(pages=3, max_seq=160)       # minimum pool: max pressure
        ref = run_alone(cfg, params, args, **kw)

        eng = paged_engine(cfg, params, **kw)
        req = Request(1, *args)
        eng.admit(req)
        for _ in range(30):                   # deep in: store populated
            eng.step_once()
        assert any(k[1] == 0 for k in eng.ctl.store), \
            "test premise: lane 0 must have host-stashed pages"
        snap = eng.suspend_lane(0)
        assert snap is not None and len(snap.stashed) > 0
        # whole-lane export: nothing of lane 0 remains in the controller
        assert not any(k[1] == 0 for k in eng.ctl.store)
        assert not any(k[1] == 0 for k in eng.ctl.frozen_meta)
        filler = Request(2, rng.randint(0, cfg.vocab_size, size=16).astype(
            np.int32), 12, SamplingParams.greedy())
        eng.admit(filler, lane=0)
        while filler.result is None:
            eng.step_once()
        eng.resume_lane(snap, lane=1)
        while req.result is None:
            eng.step_once()
        np.testing.assert_array_equal(ref, req.result)

    def test_mid_prefill_suspend_cancels_and_readmits(self, tiny_f32):
        cfg, params = tiny_f32
        rng = np.random.RandomState(7)
        prompt = rng.randint(0, cfg.vocab_size, size=40).astype(np.int32)
        args = (prompt, 16, SamplingParams.greedy())
        ref = run_alone(cfg, params, args, max_seq=160)
        eng = paged_engine(cfg, params, max_seq=160)
        req = Request(1, *args)
        eng.admit(req)
        eng.step_once()                       # one prefill chunk
        assert 0 in eng.prefills
        snap = eng.suspend_lane(0)
        assert snap is not None and not snap.started
        assert 0 not in eng.prefills and eng.lanes[0].request is None
        eng.resume_lane(snap)                 # plain re-admit
        while req.result is None:
            eng.step_once()
        np.testing.assert_array_equal(ref, req.result)

    def test_install_time_preemption_via_admit_over(self, tiny_f32):
        """admit_over: the victim keeps decoding while the preemptor
        prefills in scratch, is suspended exactly at install, surfaces
        via drain_suspended, and still resumes token-identically."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(9)
        prompt = rng.randint(0, cfg.vocab_size, size=20).astype(np.int32)
        args = (prompt, 32, SamplingParams.greedy())
        ref = run_alone(cfg, params, args)

        eng = paged_engine(cfg, params)
        victim = Request(1, *args)
        eng.admit(victim)
        for _ in range(10):
            eng.step_once()
        gen_before = len(eng.lanes[0].generated)
        pre = Request(2, rng.randint(0, cfg.vocab_size, size=16).astype(
            np.int32), 8, SamplingParams.greedy())
        eng.admit_over(pre, 0)
        assert eng._free_lane() == 1          # lane 0 is spoken for
        snaps = []
        while pre.result is None:
            eng.step_once()
            snaps += eng.drain_suspended()
        assert len(snaps) == 1 and snaps[0].req is victim
        # the victim decoded during the preemptor's prefill (2 chunks)
        eng.flush()
        assert len(snaps[0].generated) > gen_before
        eng.resume_lane(snaps[0])
        while victim.result is None:
            eng.step_once()
        np.testing.assert_array_equal(ref, victim.result)

    def test_admit_over_victim_retires_mid_prefill(self, tiny_f32):
        """If the victim finishes on its own before the preemptor's
        prefill installs, no snapshot is produced and the install
        degenerates to a normal admission — and the orphaned lane (no
        request, prefill pending) still reads as busy to the scheduler."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(13)
        eng = paged_engine(cfg, params)
        victim = Request(1, rng.randint(0, cfg.vocab_size, size=10).astype(
            np.int32), 6, SamplingParams.greedy())
        eng.admit(victim)
        while len(eng.lanes[0].generated) < 4:
            eng.step_once()
        pre = Request(2, rng.randint(0, cfg.vocab_size, size=40).astype(
            np.int32), 8, SamplingParams.greedy())   # 5+ prefill chunks
        eng.admit_over(pre, 0)
        sched = Scheduler(eng)                # wraps the half-served state
        saw_orphan = False
        snaps = []
        while pre.result is None:
            eng.step_once()
            snaps += eng.drain_suspended()
            if eng.lanes[0].request is None and 0 in eng.prefills:
                saw_orphan = True
                assert sched.busy             # scheduler must keep driving
        assert victim.result is not None and victim.result.shape == (6,)
        assert snaps == [] and saw_orphan
        assert pre.result.shape == (8,)

    def test_contiguous_resume_completes(self, tiny_f32):
        """The contiguous fallback re-prefills prompt + generated; the
        continuation must complete with the right shape and keep the
        request's host bookkeeping consistent (exact token parity is the
        paged path's guarantee, not this one's)."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(11)
        eng = ContinuousEngine(cfg, params, max_seq=128, n_lanes=2)
        req = Request(1, rng.randint(0, cfg.vocab_size, size=20).astype(
            np.int32), 24, SamplingParams.greedy())
        eng.admit(req)
        for _ in range(9):
            eng.step_once()
        snap = eng.suspend_lane(0)
        assert snap is not None and snap.started
        assert eng.resume_lane(snap, lane=1) == 1
        while req.result is None:
            eng.step_once()
        assert req.result.shape == (24,)
        assert req.result[:len(snap.generated)].tolist() == snap.generated


class TestSchedulerPolicy:
    def _sched(self, tiny_f32, policy="slo", clock=None):
        cfg, params = tiny_f32
        eng = paged_engine(cfg, params)
        kw = {"clock": clock} if clock is not None else {}
        return Scheduler(eng, policy=policy, **kw)

    def test_edf_ordering_within_and_across_classes(self, tiny_f32):
        """Randomized EDF property: pops come out ordered by (priority,
        deadline, submission) regardless of submission order."""
        rng = np.random.RandomState(0)
        t = [0.0]
        sched = self._sched(tiny_f32, clock=lambda: t[0])
        for trial in range(30):
            sched.queue.clear()
            keys = []
            for _ in range(12):
                prio = int(rng.randint(0, 3))
                dl = None if rng.rand() < 0.3 else float(rng.randint(1, 500))
                uid = sched.submit(np.array([1, 2, 3], np.int32), 4,
                                   SamplingParams.greedy(), priority=prio,
                                   deadline_ms=dl)
                keys.append((prio, np.inf if dl is None else dl / 1e3, uid))
            popped = [sched._pop().uid for _ in range(12)]
            expect = [u for _, _, u in sorted(keys)]
            assert popped == expect, f"trial {trial}"

    def test_no_deadline_trace_degrades_to_fifo(self, tiny_f32):
        """Same priority, no deadlines: admission order must equal submit
        order and nothing is ever preempted — the old FIFO behaviour."""
        cfg, params = tiny_f32
        sched = self._sched(tiny_f32)
        rng = np.random.RandomState(1)
        uids = [sched.submit(rng.randint(0, cfg.vocab_size, size=10), 6,
                             SamplingParams.greedy()) for _ in range(5)]
        sched.run()
        admits = [e["uid"] for e in sched.engine.events
                  if e["event"] == "admit_start"]
        assert admits == uids
        assert sched.n_preemptions == 0
        for u in uids:
            assert sched.done[u].result.shape == (6,)
            assert sched.metrics[u]["deadline_hit"] is None

    def test_priority_jumps_queue_without_deadline(self, tiny_f32):
        """A higher class is admitted before earlier-submitted lower-class
        requests (strict classes) even with no deadline set."""
        cfg, params = tiny_f32
        sched = self._sched(tiny_f32)
        rng = np.random.RandomState(2)
        bg = [sched.submit(rng.randint(0, cfg.vocab_size, size=10), 12,
                           SamplingParams.greedy(), priority=5)
              for _ in range(4)]
        fg = sched.submit(rng.randint(0, cfg.vocab_size, size=10), 6,
                          SamplingParams.greedy(), priority=0)
        sched.run()
        admits = [e["uid"] for e in sched.engine.events
                  if e["event"] == "admit_start"]
        # lanes 0/1 take bg[0], bg[1] immediately; the fg must be admitted
        # before the remaining queued background
        assert admits.index(fg) < admits.index(bg[2])
        assert admits.index(fg) < admits.index(bg[3])

    def test_aging_bounds_starvation(self, tiny_f32):
        """Strict classes can starve: under a steady higher-class stream
        a background request waits forever.  With ``aging_s`` set, its
        effective class decays one level per ``aging_s`` waited, so the
        wait is bounded by ``priority * aging_s``; the tie then resolves
        by original submission seq, putting the aged request ahead of
        younger same-class arrivals."""
        cfg, params = tiny_f32
        rng = np.random.RandomState(8)
        t = [0.0]
        eng = paged_engine(cfg, params)
        aged = Scheduler(eng, policy="slo", clock=lambda: t[0],
                         aging_s=5.0)
        plain = Scheduler(eng, policy="slo", clock=lambda: t[0])
        subs = {}
        for s in (aged, plain):
            t[0] = 0.0
            bg = s.submit(rng.randint(0, cfg.vocab_size, size=8), 4,
                          SamplingParams.greedy(), priority=5)
            t[0] = 26.0           # 5 aging boundaries: class 5 -> 0
            fg = s.submit(rng.randint(0, cfg.vocab_size, size=8), 4,
                          SamplingParams.greedy(), priority=0)
            subs[id(s)] = (bg, fg)
        # without aging the younger foreground still jumps the queue
        bg, fg = subs[id(plain)]
        plain._apply_aging()
        assert plain._pop().uid == fg
        # with aging the background was promoted to class 0 and its
        # earlier submission wins the tie
        bg, fg = subs[id(aged)]
        aged._apply_aging()
        assert aged._pop().uid == bg

    def test_aging_promotion_is_bounded_and_floored(self, tiny_f32):
        """Effective priority never drops below 0 and never promotes a
        request that hasn't crossed an aging boundary."""
        cfg, params = tiny_f32
        t = [0.0]
        eng = paged_engine(cfg, params)
        sched = Scheduler(eng, policy="slo", clock=lambda: t[0],
                          aging_s=10.0)
        rng = np.random.RandomState(9)
        uid = sched.submit(rng.randint(0, cfg.vocab_size, size=8), 4,
                           SamplingParams.greedy(), priority=2)
        req = sched.queue[0][-1]
        assert sched._eff_priority(req) == 2
        t[0] = 9.9
        assert sched._eff_priority(req) == 2
        t[0] = 10.0
        assert sched._eff_priority(req) == 1
        t[0] = 1e6                # deep overtime: floored, not negative
        assert sched._eff_priority(req) == 0
        assert sched.metrics[uid]["priority"] == 2   # raw class untouched

    def test_deadline_preemption_end_to_end(self, tiny_f32):
        """Two background hogs + one deadlined foreground: the foreground
        preempts, completes, and the victims still finish with full-length
        results (the preempted generation is resumed, not restarted)."""
        cfg, params = tiny_f32
        sched = self._sched(tiny_f32)
        rng = np.random.RandomState(3)
        bg = [sched.submit(rng.randint(0, cfg.vocab_size, size=10), 48,
                           SamplingParams.greedy(), priority=5)
              for _ in range(2)]
        for _ in range(10):                   # hogs mid-flight, EMA warm
            sched.step()
        fg = sched.submit(rng.randint(0, cfg.vocab_size, size=8), 6,
                          SamplingParams.greedy(), priority=0,
                          deadline_ms=150.0)
        sched.run()
        assert sched.n_preemptions >= 1
        assert sum(m["preempted"] for m in sched.metrics.values()) >= 1
        assert sched.done[fg].result.shape == (6,)
        for u in bg:
            assert sched.done[u].result.shape == (48,)

    def test_scheduler_wraps_static_engine(self, tiny_f32):
        """The Engine-compat path (wrap into a ContinuousEngine) and the
        suspend fallback still serve a trace to completion."""
        cfg, params = tiny_f32
        eng = Engine(cfg, params, max_seq=96, enable_freeze=False)
        sched = Scheduler(eng, batch_size=2)
        rng = np.random.RandomState(4)
        uids = [sched.submit(rng.randint(0, cfg.vocab_size, size=8), 8)
                for _ in range(3)]
        sched.run()
        for u in uids:
            assert sched.done[u].result.shape == (8,)


class TestStaticSchedulerSamplingGuard:
    def test_mixed_sampling_batch_raises(self, tiny_f32):
        cfg, params = tiny_f32
        eng = Engine(cfg, params, max_seq=64, enable_freeze=False)
        sched = StaticScheduler(eng, batch_size=2)
        rng = np.random.RandomState(0)
        sched.submit(rng.randint(0, cfg.vocab_size, size=8), 6,
                     SamplingParams(temperature=0.7))
        sched.submit(rng.randint(0, cfg.vocab_size, size=8), 6,
                     SamplingParams.greedy())
        with pytest.raises(ValueError, match="mixes"):
            sched.run_once()

    def test_homogeneous_batch_still_serves(self, tiny_f32):
        cfg, params = tiny_f32
        eng = Engine(cfg, params, max_seq=64, enable_freeze=False)
        sched = StaticScheduler(eng, batch_size=2)
        rng = np.random.RandomState(0)
        uids = [sched.submit(rng.randint(0, cfg.vocab_size, size=8), 6,
                             SamplingParams.greedy()) for _ in range(2)]
        sched.run()
        for u in uids:
            assert sched.done[u].result.shape == (6,)

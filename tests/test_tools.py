"""Unit tests for the CI gate scripts: tools/check_bench.py (named
benchmark criteria on synthetic JSON) and tools/check_links.py
(markdown link/anchor fixtures)."""
import json

import pytest

from tools import check_bench, check_links


# ===================================================================== #
# check_bench — synthetic passing JSONs, then break one criterion at a
# time and assert exactly that named check fails
# ===================================================================== #
def good_report():
    return {
        "long_trace_contiguous": {"peak_kv_bytes": 400},
        "long_trace_paged": {"peak_kv_bytes": 200},
        "paged_mem_win": True,
        "needle": {"paged_recovery": {"retrieval_acc": 1.0}},
        "needle_acc_match": True,
        "needle_mem_win": True,
        "async_vs_sync": {},
    }


def good_bench():
    return {
        "step_latency_ms": {"sync": {"mean": 3.0}, "async": {"mean": 3.1}},
        "host_blocked_fraction": {"sync": 1.0, "async": 0.25},
        "peak_device_kv_bytes": {"contiguous": 400, "paged": 200},
        "token_parity": True,
        "thaws": 40,
        "thaw_remap_fraction": 0.75,
        "n_retraces": {"sync": 0, "async": 0},
        "blocking_transfers": {"sync": 350, "async": 80},
    }


def good_scheduling():
    arm = {"fg_deadline_hit_rate": 0.5, "fg_latency_p99_s": 0.6,
           "tokens_per_s": 500.0, "steady_tokens_per_step": 1.9}
    return {
        "fifo": dict(arm),
        "slo": dict(arm, fg_deadline_hit_rate=1.0, fg_latency_p99_s=0.05),
        "hit_rate_win": True,
        "fg_p99_win": True,
        "throughput_ok": True,
        "preemptions": 2,
        "preempt_resume_token_parity": True,
        "parity_audited": 2,
        "parity_by_uid": {"1": True, "4": True},
        "n_retraces": 0,
        "retrace_growth": {},
    }


def good_failover():
    return {
        "lost_requests": 0,
        "n_failovers": 1,
        "recovered_with_checkpoint": 2,
        "recovered_reprefill": 0,
        "checkpoint_parity": True,
        "checkpoint_audited": 2,
        "journal_consistent": True,
        "journal_audited": 2,
        "invariants_ok": True,
        "fg_deadline_hit_rate": 1.0,
        "fg_deadline_hit_window": 0.9,
        "fg_in_window": 4,
        "fg_hit_floor": 0.8,
    }


def run_main(tmp_path, report, bench, scheduling=None, failover=None,
             extra=()):
    rp = tmp_path / "report.json"
    bp = tmp_path / "bench.json"
    rp.write_text(json.dumps(report))
    bp.write_text(json.dumps(bench))
    argv = [str(rp), str(bp)]
    if scheduling is not None:
        sp = tmp_path / "scheduling.json"
        sp.write_text(json.dumps(scheduling))
        argv += ["--scheduling", str(sp)]
    if failover is not None:
        fp = tmp_path / "failover.json"
        fp.write_text(json.dumps(failover))
        argv += ["--failover", str(fp)]
    argv += list(extra)
    rc = check_bench.main(argv)
    return rc, list(check_bench.FAILURES)


def test_check_bench_all_green(tmp_path):
    rc, fails = run_main(tmp_path, good_report(), good_bench(),
                         good_scheduling(), good_failover(),
                         extra=["--max-retraces", "0"])
    assert rc == 0 and not fails


@pytest.mark.parametrize("mutate,expect", [
    (lambda r, b, s: r.update(paged_mem_win=False), "paged-mem-win"),
    (lambda r, b, s: r.update(needle_acc_match=False), "needle-acc-match"),
    (lambda r, b, s: r.update(needle_mem_win=False), "needle-mem-win"),
    (lambda r, b, s: b.update(token_parity=False), "async-token-parity"),
    (lambda r, b, s: b["host_blocked_fraction"].update({"async": 1.0}),
     "async-blocked-win"),
    (lambda r, b, s: b["blocking_transfers"].update({"async": 400}),
     "async-blocking-transfers"),
    (lambda r, b, s: b.update(thaws=0), "thaws-nonzero"),
    (lambda r, b, s: b.update(thaw_remap_fraction=0.2),
     "thaw-remap-fraction"),
    (lambda r, b, s: b["n_retraces"].update({"async": 3}), "max-retraces"),
    (lambda r, b, s: s.update(n_retraces=2), "sched-max-retraces"),
    (lambda r, b, s: s.update(preemptions=0), "preemptions-nonzero"),
    (lambda r, b, s: s.update(hit_rate_win=False), "deadline-hit-rate-win"),
    (lambda r, b, s: s.update(fg_p99_win=False), "fg-p99-win"),
    (lambda r, b, s: s.update(throughput_ok=False), "throughput-ok"),
    (lambda r, b, s: s.update(preempt_resume_token_parity=False),
     "preempt-resume-parity"),
])
def test_check_bench_each_criterion_fails_alone(tmp_path, mutate, expect):
    r, b, s = good_report(), good_bench(), good_scheduling()
    mutate(r, b, s)
    rc, fails = run_main(tmp_path, r, b, s, extra=["--max-retraces", "0"])
    assert rc == len(fails) == 1 and fails == [expect]


@pytest.mark.parametrize("mutate,expect", [
    (lambda f: f.update(n_failovers=0), "failover-fired"),
    (lambda f: f.update(lost_requests=2), "failover-zero-lost"),
    (lambda f: f.update(recovered_with_checkpoint=0),
     "failover-checkpoint-recovery"),
    (lambda f: f.update(checkpoint_parity=False),
     "failover-checkpoint-parity"),
    (lambda f: f.update(journal_consistent=False),
     "failover-journal-consistent"),
    (lambda f: f.update(invariants_ok=False), "failover-invariants"),
    (lambda f: f.update(fg_deadline_hit_window=0.5),
     "failover-fg-window-floor"),
    (lambda f: f.update(fg_in_window=0, fg_deadline_hit_window=1.0),
     "failover-fg-window-nonempty"),
])
def test_check_failover_each_criterion_fails_alone(tmp_path, mutate,
                                                   expect):
    f = good_failover()
    mutate(f)
    rc, fails = run_main(tmp_path, good_report(), good_bench(),
                         good_scheduling(), f,
                         extra=["--max-retraces", "0"])
    assert rc == len(fails) == 1 and fails == [expect]


def test_check_failover_missing_keys_fail_fast(tmp_path):
    f = good_failover()
    del f["journal_consistent"]
    rc, fails = run_main(tmp_path, good_report(), good_bench(),
                         good_scheduling(), f)
    assert rc >= 1 and "failover-keys" in fails


def test_check_bench_retraces_uncapped_without_flag(tmp_path):
    b = good_bench()
    b["n_retraces"]["async"] = 7
    s = good_scheduling()
    s["n_retraces"] = 7
    rc, fails = run_main(tmp_path, good_report(), b, s)
    assert rc == 0, "without --max-retraces the growth is report-only"


def test_check_bench_missing_keys_fail_fast(tmp_path):
    r = good_report()
    del r["paged_mem_win"]
    rc, fails = run_main(tmp_path, r, good_bench())
    assert rc >= 1 and "report-keys" in fails


# ===================================================================== #
# check_links — fixture markdown trees
# ===================================================================== #
def write_docs(tmp_path, files):
    for name, text in files.items():
        p = tmp_path / name
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)


def test_check_links_clean_tree(tmp_path, capsys):
    write_docs(tmp_path, {
        "README.md": "# Top\nSee [docs](docs/a.md) and "
                     "[section](docs/a.md#my-heading) and "
                     "[web](https://example.com/x).\n",
        "docs/a.md": "# My Heading\nback to [readme](../README.md)\n",
    })
    rc = check_links.main([str(tmp_path / "README.md"),
                           str(tmp_path / "docs")])
    assert rc == 0
    assert "ok" in capsys.readouterr().out


def test_check_links_broken_target(tmp_path, capsys):
    write_docs(tmp_path, {"README.md": "[gone](docs/missing.md)\n"})
    rc = check_links.main([str(tmp_path / "README.md")])
    assert rc == 1
    assert "broken link -> docs/missing.md" in capsys.readouterr().err


def test_check_links_missing_anchor(tmp_path, capsys):
    write_docs(tmp_path, {
        "README.md": "[s](a.md#no-such-heading)\n",
        "a.md": "# Real Heading\n",
    })
    rc = check_links.main([str(tmp_path / "README.md")])
    assert rc == 1
    assert "missing anchor" in capsys.readouterr().err


def test_check_links_ignores_code_fences_and_slug_rules(tmp_path):
    write_docs(tmp_path, {
        "README.md": "```\n[fake](inside/fence.md)\n```\n"
                     "[ok](a.md#api--usage-notes)\n",
        "a.md": "# API — `usage` *notes*\n",
    })
    rc = check_links.main([str(tmp_path / "README.md")])
    assert rc == 0


def test_check_links_slug():
    assert check_links.slug("My `Code` Heading!") == "my-code-heading"
    assert check_links.slug("A - B") == "a---b"

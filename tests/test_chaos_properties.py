"""Property-based chaos tests (S3) — hypothesis-driven mirrors of the
deterministic seeded checks in tests/test_faults.py.

The whole module skips when ``hypothesis`` is unavailable (the pinned CI
image does not ship it, and the repo policy is to gate — never install —
missing dependencies).  Coverage does not regress on skip: the seeded
random-op storm in tests/test_faults.py exercises the same invariants
with a fixed RandomState, so these tests only *widen* the searched
sequence space when the library happens to be present.

Properties:

* fault scheduling is a pure function of (seed, site, op-index) — two
  schedules built from the same config agree on every draw,
* ``Endpoint.call`` on a must-succeed endpoint is total: whatever the
  injected attempt budget and retry allowance, it never raises and runs
  the wrapped transfer exactly once (donation safety),
* any admit/suspend/resume/discard/step lifecycle sequence keeps every
  controller invariant intact (runtime auditor) with exact host-stash
  byte accounting, and discarding the surviving snapshots always
  returns ``exported_bytes`` to zero.
"""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st              # noqa: E402

import jax                                           # noqa: E402

from repro.analysis import audit_controller          # noqa: E402
from repro.configs import get_config                 # noqa: E402
from repro.models import model as MD                 # noqa: E402
from repro.serving.engine import (PagedContinuousEngine,  # noqa: E402
                                  Request)
from repro.serving.faults import (Endpoint, FaultInjector,  # noqa: E402
                                  FaultPlan, FaultSchedule, RetryPolicy)
from repro.serving.sampling import SamplingParams    # noqa: E402


# ------------------------------------------------- pure-unit properties --

@given(seed=st.integers(0, 2**31 - 1),
       rate=st.floats(0.0, 1.0, allow_nan=False),
       n=st.integers(1, 128))
@settings(max_examples=50, deadline=None)
def test_schedule_is_deterministic_in_seed(seed, rate, n):
    a = FaultSchedule(seed=seed, rates={"pull": rate, "ring": rate})
    b = FaultSchedule(seed=seed, rates={"pull": rate, "ring": rate})
    for site in ("pull", "ring"):
        for i in range(n):
            pa, pb = a.plan(site, i), b.plan(site, i)
            assert (pa is None) == (pb is None)
            if pa is not None:
                assert pa.kind == pb.kind


@given(attempts=st.integers(0, 6), max_retries=st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_must_succeed_endpoint_is_total(attempts, max_retries):
    """No (injected attempts, retry budget) pair may raise out of a
    must-succeed endpoint, and the guarded transfer runs exactly once
    regardless — retries re-draw the fault, not the side effect."""
    inj = FaultInjector(FaultSchedule(
        seed=0, explicit={("pull", 0): FaultPlan(attempts=attempts)}))
    ep = Endpoint("pull", inj,
                  retry=RetryPolicy(max_retries=max_retries, backoff_s=0.0),
                  must_succeed=True)
    calls = []
    out = ep.call(lambda: calls.append(1) or "ok")
    assert out == "ok" and len(calls) == 1
    # every (max_retries + 1)-attempt cycle costs one exhaustion, the
    # remaining injected attempts are plain retries
    assert ep.n_exhausted == attempts // (max_retries + 1)
    assert ep.n_retries == attempts - ep.n_exhausted


# ---------------------------------------------- lifecycle op sequences --

@pytest.fixture(scope="module")
def pressure_cfg():
    """Aggressive freeze pressure, recovery off — mirrors the
    ``pressure_cfg`` fixture in tests/test_faults.py."""
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.6, k_soft=0.7,
                             recovery_enabled=False,
                             entropy_abs_threshold=0.5, rewalk_tokens=6)
    cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@given(ops=st.lists(st.integers(0, 9), min_size=20, max_size=48),
       data_seed=st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
def test_lifecycle_sequences_preserve_invariants(pressure_cfg, ops,
                                                 data_seed):
    """Hypothesis-widened twin of test_faults.py's seeded op storm: ANY
    interleaving of admit/suspend/resume/discard/step keeps the
    controller auditor green and the stash gauge byte-exact against the
    store's actual contents, and discarding every surviving snapshot
    drains ``exported_bytes`` to zero."""
    cfg, params = pressure_cfg
    eng = PagedContinuousEngine(cfg, params, max_seq=256, n_lanes=2,
                                max_active_pages=4, prefill_chunk=16,
                                rewind_cooldown=12, async_pipeline=True,
                                burst_prefill=False)
    rng = np.random.RandomState(data_seed % 2**31)
    snaps, uid = [], 0

    def active(e):
        return [i for i in range(e.n_lanes)
                if e.lanes[i].request is not None or i in e.prefills]

    for op in ops:
        act = active(eng)
        if op <= 1 and len(act) < eng.n_lanes:
            uid += 1
            eng.admit(Request(
                uid,
                np.asarray(rng.randint(0, cfg.vocab_size, size=int(
                    rng.randint(8, 24))), np.int32),
                int(rng.randint(8, 32)), SamplingParams.greedy()))
        elif op == 2 and act:
            snap = eng.suspend_lane(act[0])
            if snap is not None:
                snaps.append(snap)
        elif op == 3 and snaps and len(active(eng)) < eng.n_lanes:
            eng.resume_lane(snaps.pop())
        elif op == 4 and snaps:
            eng.discard_snapshot(snaps.pop())
        else:
            eng.step_once()
        audit_controller(eng.ctl)
        assert eng.ctl.stash_bytes == sum(
            k.nbytes + v.nbytes for k, v in eng.ctl.store.values())
    for snap in snaps:
        eng.discard_snapshot(snap)
    assert eng.ctl.exported_bytes == 0

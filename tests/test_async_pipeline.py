"""Async host<->device DMA pipeline (serving/dma.py + engine integration):

* token-level parity between the async and synchronous pipelines on a
  mixed thaw/rewind trace (the pipeline must be a pure overlap
  optimization — same decisions, same order, different wall-clock),
* the transfer-op regression: non-boundary decode steps issue ZERO
  blocking host transfers (the async pipeline's defining property),
* speculative-thaw staging: staged pages install as metadata-only remaps
  (no K/V push) with a device-side copy, and the reserved staging slots
  leave the in-step freeze dynamics bit-identical to a plain pool,
* kernel contract: a staging slot full of garbage K/V is invisible to
  paged attention while its page table entry is unmapped.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.dma import FetchRing, HostStaging, TransferStats
from repro.serving.engine import ContinuousEngine, PagedContinuousEngine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def tiny_f32():
    cfg = get_config("llama3-8b-tiny")
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             recovery_enabled=False)
    cfg = dataclasses.replace(cfg, freeze=fc, dtype="float32")
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def thaw_rewind_cfg(tiny_f32):
    """Aggressive freeze pressure + low entropy thresholds: pages stash,
    FR thaws fire, and RR rewinds trigger (the mixed trace of the parity
    requirement)."""
    cfg, _ = tiny_f32
    fc = dataclasses.replace(cfg.freeze, page_size=8, window=8,
                             tau_mode="quantile", quantile=0.6, k_soft=0.7,
                             recovery_enabled=True,
                             entropy_abs_threshold=0.5, rewalk_tokens=6)
    cfg = dataclasses.replace(cfg, freeze=fc)
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(eng, cfg, lens, seed=0):
    s = Scheduler(eng)
    rng = np.random.RandomState(seed)
    uids = [s.submit(rng.randint(0, cfg.vocab_size, size=pl), n,
                     SamplingParams.greedy())
            for pl, n in lens]
    s.run()
    return [s.done[u] for u in uids]


class TestParityAsyncVsSync:
    def test_paged_thaw_rewind_trace(self, thaw_rewind_cfg):
        """Sync and async paged engines over a trace that exercises the
        full recovery surface (stash, FR thaw, RR rewind) must emit
        identical tokens AND identical per-request telemetry — and the
        trace must actually thaw and rewind or the test is vacuous."""
        cfg, params = thaw_rewind_cfg
        lens = [(48, 70), (20, 50)]

        def run(async_pipeline):
            eng = PagedContinuousEngine(
                cfg, params, max_seq=256, n_lanes=2, max_active_pages=6,
                prefill_chunk=16, rewind_cooldown=12,
                async_pipeline=async_pipeline, burst_prefill=False)
            return eng, _serve(eng, cfg, lens)

        se, sync_done = run(False)
        ae, async_done = run(True)
        assert se.ctl.n_thaw > 0, "no thaw fired — parity test is vacuous"
        assert sum(r.telemetry.rewinds for r in sync_done) > 0, \
            "no rewind fired — parity test is vacuous"
        assert ae.ctl.n_thaw == se.ctl.n_thaw
        for a, b in zip(sync_done, async_done):
            np.testing.assert_array_equal(a.result, b.result)
            assert a.telemetry.rewinds == b.telemetry.rewinds
            assert a.telemetry.active_kv == b.telemetry.active_kv
            assert a.telemetry.total_kv == b.telemetry.total_kv
            assert a.telemetry.offloaded_tokens == b.telemetry.offloaded_tokens

    def test_contiguous_with_offload(self, tiny_f32):
        """The contiguous engine shares the ring (incl. the folded-in
        offload freeze-mask fetch): async and sync must agree on tokens
        and offload telemetry, and offload must actually engage."""
        cfg, params = tiny_f32
        fc = dataclasses.replace(cfg.freeze, window=4, tau_mode="quantile",
                                 quantile=0.6, k_soft=1.0, page_size=8)
        cfg = dataclasses.replace(cfg, freeze=fc)
        lens = [(16, 40), (16, 24), (12, 30)]

        def run(async_pipeline):
            eng = ContinuousEngine(cfg, params, max_seq=96, n_lanes=2,
                                   async_pipeline=async_pipeline)
            return eng, _serve(eng, cfg, lens)

        se, sync_done = run(False)
        ae, async_done = run(True)
        assert se.offloader.n_offloads > 0, "offload never engaged"
        assert ae.offloader.n_offloads == se.offloader.n_offloads
        for a, b in zip(sync_done, async_done):
            np.testing.assert_array_equal(a.result, b.result)
            assert a.telemetry.offloaded_tokens == b.telemetry.offloaded_tokens


class TestTransferRegression:
    def test_contiguous_steps_never_block(self, tiny_f32):
        """With no offload there is no boundary maintenance at all: the
        async contiguous engine must complete a whole trace without a
        single blocking host transfer."""
        cfg, params = tiny_f32
        eng = ContinuousEngine(cfg, params, max_seq=96, n_lanes=2,
                               offload=False, async_pipeline=True)
        _serve(eng, cfg, [(16, 24), (12, 20), (10, 16)])
        assert eng.stats.steps > 0
        assert eng.stats.blocking_d2h == 0
        assert eng.stats.blocking_h2d == 0
        assert eng.stats.blocked_steps == 0
        assert eng.stats.async_d2h > 0          # the ring did the fetching

    def test_paged_blocks_only_at_boundary_ticks(self, tiny_f32):
        """The transfer-op counter regression: every blocking transfer of
        the async paged engine must belong to a page-boundary tick (the
        batched pool pull) or an admission install — plain decode steps
        issue zero blocking host transfers."""
        cfg, params = tiny_f32
        eng = PagedContinuousEngine(cfg, params, max_seq=160, n_lanes=2,
                                    max_active_pages=8,
                                    prefill_chunk=8, async_pipeline=True)
        _serve(eng, cfg, [(20, 40), (12, 24), (16, 30)])
        assert eng.stats.steps > 0
        assert eng.n_boundary_ticks > 0
        # one batched pull per boundary tick — and nothing else blocks D2H
        assert eng.stats.blocking_d2h == eng.n_boundary_ticks
        # one blocking H2D per push that had to carry K/V (admission
        # installs + dirty boundary pushes) — and nothing else
        assert eng.stats.blocking_h2d == eng.n_kv_pushes
        # a step may block only through boundary maintenance or an
        # install landing on it; plain decode steps never do
        assert eng.stats.blocked_steps <= eng.n_boundary_ticks \
            + eng.n_kv_pushes
        assert eng.stats.blocked_steps < eng.stats.steps

    def test_sync_mode_blocks_every_step(self, tiny_f32):
        """The depth-0 ring is the synchronous baseline: every decode step
        stalls on its fetch (host_blocked_fraction == 1)."""
        cfg, params = tiny_f32
        eng = PagedContinuousEngine(cfg, params, max_seq=96, n_lanes=1,
                                    max_active_pages=8, prefill_chunk=8,
                                    async_pipeline=False)
        _serve(eng, cfg, [(16, 16)])
        assert eng.stats.steps > 0
        assert eng.stats.host_blocked_fraction == 1.0


class TestSpeculativeThawStaging:
    def test_staged_thaw_is_remap_only(self, thaw_rewind_cfg):
        """On the thaw-heavy trace the async engine must serve at least
        one thaw from a staging slot: a metadata-only install (no K/V in
        the push) completed by a device-side copy."""
        cfg, params = thaw_rewind_cfg
        eng = PagedContinuousEngine(
            cfg, params, max_seq=256, n_lanes=2, max_active_pages=6,
            prefill_chunk=16, rewind_cooldown=12, async_pipeline=True,
            burst_prefill=False)
        _serve(eng, cfg, [(48, 70), (20, 50)])
        assert eng.ctl.n_thaw > 0
        assert eng.ctl.n_thaw_remap > 0, \
            "speculative staging never converted a thaw into a remap"
        assert not eng.ctl.pending_remaps      # all executed

    def test_controller_remap_semantics(self, tiny_f32):
        """Unit-level: a staged page installs into the SAME slot the
        upload path would pick, queues a device copy, refreshes the host
        pool copy, and leaves the K/V clean (metadata-only push)."""
        cfg, params = tiny_f32
        from repro.core.paging import PagedController
        L, P, S, page = 2, 4, 1, cfg.freeze.page_size
        kvh, hd = 2, cfg.head_dim
        ctl = PagedController(cfg=cfg, batch=1, max_active_pages=P)
        rng = np.random.RandomState(0)
        P_total = P + S
        pool = {"k": np.zeros((L, 1, P_total, page, kvh, hd), np.float32),
                "v": np.zeros((L, 1, P_total, page, kvh, hd), np.float32),
                "page_table": np.full((L, 1, P_total), -1, np.int32),
                "slot_mask": np.zeros((L, 1, P_total, page), bool)}
        fstate = {f: np.zeros((L, 1, P_total), np.int32)
                  for f in ("c", "d", "frozen_at")}
        fstate["frozen"] = np.zeros((L, 1, P_total), bool)
        kk = rng.randn(page, kvh, hd).astype(np.float32)
        for l in range(L):
            ctl.stash(l, 0, 5, kk, kk, d=50)
            ctl.stage_slots[(l, 0)] = [P]          # last slot reserved
            ctl.staged_keys[(l, 0, 5)] = P
        ctl.begin_tick()
        n = ctl.thaw_lane(pool, fstate, 0, 0, reserve_slots=0)
        assert n == L and ctl.n_thaw_remap == L and ctl.n_thaw_upload == 0
        assert not ctl.kv_dirty, "remap-only install must not dirty K/V"
        assert len(ctl.pending_remaps) == L
        for (l, lane, src, dst) in ctl.pending_remaps:
            assert lane == 0 and src == P and dst == 0, \
                "remap must target the slot the upload path would use"
            assert pool["page_table"][l, 0, dst] == 5
            np.testing.assert_array_equal(pool["k"][l, 0, dst], kk)
        assert not ctl.staged_keys                 # consumed

    def test_reserved_slots_freeze_equivalence(self):
        """The parity-critical math: a P+S pool whose S staging slots are
        unmapped, with reserved_slots=S, must make bit-identical freeze
        decisions to a plain P pool."""
        from repro.configs import get_config
        from repro.core.paging import PageFreezeState, page_freeze_update
        cfg = get_config("llama3-8b-tiny").freeze
        cfg = dataclasses.replace(cfg, page_size=8, window=8,
                                  tau_mode="fixed", tau=0.5, k_soft=0.7)
        B, P, S = 2, 5, 2
        rng = np.random.RandomState(1)
        pt = rng.randint(-1, 6, size=(B, P)).astype(np.int32)
        rel = rng.rand(B, P).astype(np.float32)

        def pad(a, fill):
            return np.concatenate(
                [a, np.full((B, S), fill, a.dtype)], axis=1)

        fz_p = PageFreezeState(
            c=jnp.asarray(rng.randint(0, 3, size=(B, P)), jnp.int32),
            d=jnp.zeros((B, P), jnp.int32),
            frozen=jnp.zeros((B, P), bool),
            frozen_at=jnp.zeros((B, P), jnp.int32))
        fz_t = PageFreezeState(
            c=jnp.asarray(pad(np.asarray(fz_p.c), 0)),
            d=jnp.asarray(pad(np.asarray(fz_p.d), 0)),
            frozen=jnp.asarray(pad(np.asarray(fz_p.frozen), False)),
            frozen_at=jnp.asarray(pad(np.asarray(fz_p.frozen_at), 0)))
        cur = jnp.asarray([5, 5], jnp.int32)
        step = jnp.asarray([9, 9], jnp.int32)
        new_p, info_p = page_freeze_update(
            fz_p, jnp.asarray(rel), jnp.asarray(pt), cur, step, cfg)
        new_t, info_t = page_freeze_update(
            fz_t, jnp.asarray(pad(rel, 0.0)), jnp.asarray(pad(pt, -1)),
            cur, step, cfg, reserved_slots=S)
        for a, b in zip(new_p, new_t):
            np.testing.assert_array_equal(np.asarray(a),
                                          np.asarray(b)[:, :P])
        np.testing.assert_array_equal(np.asarray(info_p["n_frozen"]),
                                      np.asarray(info_t["n_frozen"]))


class TestStagingSlotVisibility:
    def test_garbage_in_unmapped_staging_slot_is_invisible(self):
        """Kernel contract of the staging design: K/V written into a slot
        whose page-table entry is -1 (a staged, not-yet-remapped page)
        must not change attention output or page relevance — in the
        reference and in the Pallas kernel (interpret mode)."""
        from repro.kernels import ops as OPS
        rng = np.random.RandomState(0)
        B, P, page, H, KVH, hd = 2, 4, 8, 4, 2, 16
        q = jnp.asarray(rng.randn(B, H, hd), jnp.float32)
        k = rng.randn(B, P, page, KVH, hd).astype(np.float32)
        v = rng.randn(B, P, page, KVH, hd).astype(np.float32)
        sm = np.ones((B, P, page), bool)
        pt = np.tile(np.arange(P, dtype=np.int32), (B, 1))
        pt[:, -1] = -1                      # last slot = staging, unmapped
        sm[:, -1] = True                    # mask bits may even be set
        zeroed = k.copy(), v.copy()
        zeroed[0][:, -1] = 0
        zeroed[1][:, -1] = 0
        for force in (False, True):
            o_g, r_g = OPS.paged_decode_attention(
                q, jnp.asarray(k), jnp.asarray(v), jnp.asarray(sm),
                jnp.asarray(pt), force_kernel=force)
            o_z, r_z = OPS.paged_decode_attention(
                q, jnp.asarray(zeroed[0]), jnp.asarray(zeroed[1]),
                jnp.asarray(sm), jnp.asarray(pt), force_kernel=force)
            np.testing.assert_array_equal(np.asarray(o_g), np.asarray(o_z))
            np.testing.assert_array_equal(np.asarray(r_g), np.asarray(r_z))


class TestDmaPrimitives:
    def test_ring_depth1_is_async_fifo(self):
        stats = TransferStats()
        ring = FetchRing(stats, depth=1)
        ring.push({"n": 1}, {"x": jnp.asarray([1, 2, 3])})
        ring.push({"n": 2}, {"x": jnp.asarray([4, 5, 6])})
        meta, host = ring.pop()
        assert meta["n"] == 1 and host["x"].tolist() == [1, 2, 3]
        assert stats.async_d2h == 1 and stats.blocking_d2h == 0
        meta, host = ring.pop()
        assert meta["n"] == 2
        assert ring.pop() is None

    def test_ring_depth0_counts_blocking(self):
        stats = TransferStats()
        stats.begin_step()
        ring = FetchRing(stats, depth=0)
        ring.push({}, {"x": jnp.zeros(4)})
        ring.pop()
        stats.end_step()
        assert stats.blocking_d2h == 1
        assert stats.blocked_steps == 1 and stats.steps == 1
        assert stats.host_blocked_fraction == 1.0

    def test_staging_buffers_are_reused(self):
        st = HostStaging()
        a = st.put("x", np.arange(6, dtype=np.float32).reshape(2, 3))
        b = st.put("x", np.zeros((2, 3), np.float32))
        assert a is b                       # same allocation, new contents
        assert b.sum() == 0
        c = st.buf("x", (4, 3), np.float32)  # shape change -> realloc
        assert c is not b

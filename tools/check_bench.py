#!/usr/bin/env python3
"""Assert the serving benchmarks' acceptance criteria on their JSON output.

Replaces the inline heredoc the CI tier-2 job used to carry: every
criterion is a named check with a clear message, all checks run (failures
don't mask each other), and the exit code is the failure count.

    python tools/check_bench.py \\
        experiments/bench/continuous_batching.json \\
        BENCH_continuous_batching.json \\
        --scheduling experiments/bench/scheduling.json

Positional arguments are the continuous-batching benchmark's two outputs:
the full report (experiments/bench/continuous_batching.json) and the
machine-readable repo-root summary (BENCH_continuous_batching.json).
``--scheduling`` adds the mixed-SLO scheduling report
(experiments/bench/scheduling.json, see benchmarks/scheduling.py).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

FAILURES: list = []


def check(name: str, cond: bool, msg: str) -> None:
    tag = "ok  " if cond else "FAIL"
    print(f"  [{tag}] {name}: {msg}")
    if not cond:
        FAILURES.append(name)


def require_keys(name: str, d: dict, keys) -> bool:
    missing = [k for k in keys if k not in d]
    check(f"{name}-keys", not missing,
          f"required keys present ({'missing: ' + ', '.join(missing) if missing else len(keys)})")
    return not missing


def check_report(path: pathlib.Path) -> None:
    print(f"== {path}")
    r = json.loads(path.read_text())
    if not require_keys("report", r, (
            "long_trace_contiguous", "long_trace_paged", "paged_mem_win",
            "needle", "needle_acc_match", "needle_mem_win", "async_vs_sync")):
        return
    check("paged-mem-win", bool(r["paged_mem_win"]),
          "paged engine must use less device KV than contiguous "
          f"(paged={r['long_trace_paged'].get('peak_kv_bytes')} vs "
          f"contiguous={r['long_trace_contiguous'].get('peak_kv_bytes')} bytes)")
    check("needle-acc-match", bool(r["needle_acc_match"]),
          "paged+recovery must match contiguous retrieval accuracy "
          f"(needle={r['needle']})")
    check("needle-mem-win", bool(r["needle_mem_win"]),
          "needle scenario: paged must use less device KV")


def check_bench(path: pathlib.Path, max_retraces=None) -> None:
    print(f"== {path}")
    b = json.loads(path.read_text())
    if not require_keys("bench", b, (
            "step_latency_ms", "host_blocked_fraction",
            "peak_device_kv_bytes", "token_parity", "thaws",
            "thaw_remap_fraction", "n_retraces", "blocking_transfers")):
        return
    check("async-token-parity", bool(b["token_parity"]),
          "async pipeline must be token-identical to the sync path")
    hb = b["host_blocked_fraction"]
    check("async-blocked-win", hb["async"] < hb["sync"],
          "async arm must block the host on strictly fewer steps "
          f"(async={hb['async']} vs sync={hb['sync']})")
    bt = b["blocking_transfers"]
    check("async-blocking-transfers", bt["async"] < bt["sync"],
          "async arm must issue strictly fewer blocking host<->device "
          f"transfers (async={bt['async']} vs sync={bt['sync']})")
    check("thaws-nonzero", b["thaws"] > 0,
          f"the async smoke must produce thaws, else the remap assertion "
          f"is vacuous (thaws={b['thaws']})")
    check("thaw-remap-fraction", b["thaw_remap_fraction"] >= 0.5,
          "speculative staging must turn >= half the thaws into "
          f"remap-only installs (got {b['thaw_remap_fraction']})")
    if max_retraces is not None:
        worst = max(b["n_retraces"].values())
        check("max-retraces", worst <= max_retraces,
              "steady-state jit compile caches must stay flat over the "
              f"timed repeats (worst arm grew {worst} trace(s), allowed "
              f"{max_retraces}; per arm: {b['n_retraces']})")


def check_quant(bench_path: pathlib.Path) -> None:
    """Named criteria bounding the lossy KV-quantization change (the int8
    needle arm written by benchmarks/continuous_batching.py): retrieval
    accuracy must stay at the unquantized arm's level (floor 1.0), and
    BOTH the query-window device-KV gauge and total DMA bytes must drop
    strictly below the unquantized paged+recovery arm."""
    print(f"== {bench_path} [--quant]")
    b = json.loads(bench_path.read_text())
    if not require_keys("quant", b.get("quant", {}), (
            "retrieval_acc", "baseline_retrieval_acc",
            "kv_device_bytes_query_floor", "dma_bytes", "quantized_pages")):
        return
    q = b["quant"]
    check("quant-pages-nonzero", q["quantized_pages"] > 0,
          "the int8 arm must actually quantize pages, else every other "
          f"quant assertion is vacuous (quantized_pages={q['quantized_pages']})")
    check("quant-retrieval-floor", q["retrieval_acc"] >= 1.0,
          "int8 arm must keep needle retrieval accuracy at 1.0 "
          f"(got {q['retrieval_acc']}, unquantized arm "
          f"{q['baseline_retrieval_acc']})")
    kv = q["kv_device_bytes_query_floor"]
    check("quant-device-kv-win",
          kv["paged_recovery_quant"] < kv["paged_recovery"],
          "int8 arm must cut the query-window device-KV gauge floor "
          f"(quant={kv['paged_recovery_quant']} vs "
          f"unquantized={kv['paged_recovery']} bytes)")
    dma = q["dma_bytes"]
    check("quant-dma-win",
          dma["paged_recovery_quant"] < dma["paged_recovery"],
          "int8 arm must cut total host<->device DMA bytes "
          f"(quant={dma['paged_recovery_quant']} vs "
          f"unquantized={dma['paged_recovery']} bytes)")


def check_scheduling(path: pathlib.Path, max_retraces=None) -> None:
    print(f"== {path}")
    s = json.loads(path.read_text())
    if not require_keys("scheduling", s, (
            "fifo", "slo", "hit_rate_win", "fg_p99_win", "throughput_ok",
            "preemptions", "preempt_resume_token_parity", "n_retraces")):
        return
    if max_retraces is not None:
        check("sched-max-retraces", s["n_retraces"] <= max_retraces,
              "steady-state jit compile caches must stay flat over the "
              f"timed scheduling repeats (grew {s['n_retraces']} trace(s), "
              f"allowed {max_retraces}; growth: {s.get('retrace_growth')})")
    check("preemptions-nonzero", s["preemptions"] > 0,
          "the mixed-SLO trace must trigger lane preemption, else every "
          f"other scheduling assertion is vacuous (got {s['preemptions']})")
    check("deadline-hit-rate-win", bool(s["hit_rate_win"]),
          "preemptive scheduler must strictly beat FIFO on foreground "
          "deadline-hit-rate "
          f"(slo={s['slo']['fg_deadline_hit_rate']} vs "
          f"fifo={s['fifo']['fg_deadline_hit_rate']})")
    check("fg-p99-win", bool(s["fg_p99_win"]),
          "preemptive scheduler must strictly beat FIFO on foreground p99 "
          f"latency (slo={s['slo']['fg_latency_p99_s']}s vs "
          f"fifo={s['fifo']['fg_latency_p99_s']}s)")
    check("throughput-ok", bool(s["throughput_ok"]),
          "preemption must not degrade total token throughput — "
          f"steady-state tokens/step within "
          f"{s.get('throughput_tolerance')}x and blocked-transfer "
          f"overhead <= {s.get('blocked_overhead_frac')} of wall "
          f"(slo={s['slo'].get('steady_tokens_per_step')} vs "
          f"fifo={s['fifo'].get('steady_tokens_per_step')} tok/step; "
          f"wall tok/s reported: slo={s['slo']['tokens_per_s']} vs "
          f"fifo={s['fifo']['tokens_per_s']})")
    check("preempt-resume-parity", bool(s["preempt_resume_token_parity"]),
          "every preempt-resumed request must be token-identical to its "
          f"uninterrupted run ({s.get('parity_audited')} audited: "
          f"{s.get('parity_by_uid')})")


def check_chaos(path: pathlib.Path) -> None:
    print(f"== {path}")
    c = json.loads(path.read_text())
    if not require_keys("chaos", c, (
            "unhandled_exceptions", "dma_token_parity", "dma_retries",
            "dma_sites_hit", "dma_breaker_trips", "ladder_token_parity",
            "ladder_peak_within_budget", "ladder_throttles", "ladder_sheds",
            "ladder_shed_resumed", "full_ladder_denied_offloads",
            "full_ladder_denies", "full_ladder_deepens",
            "full_ladder_peak_no_worse", "full_ladder_statuses_clean",
            "nan_single_recovered", "nan_double_quarantined",
            "nan_peer_parity")):
        return
    check("chaos-no-unhandled", c["unhandled_exceptions"] == 0,
          "chaos may degrade serving modes but never crash the server "
          f"(unhandled_exceptions={c['unhandled_exceptions']})")
    check("dma-token-parity", bool(c["dma_token_parity"]),
          "every survivable DMA fault (retried transient, breaker "
          "fallback, staging disable) must be token-invisible")
    check("dma-retries-nonzero", c["dma_retries"] > 0,
          "the fault schedule must actually exercise the retry path, "
          f"else the parity assertion is vacuous (retries={c['dma_retries']})")
    check("dma-sites-covered", c["dma_sites_hit"] >= 3,
          "faults must land on >= 3 distinct injection sites "
          f"(hit {c['dma_sites_hit']})")
    check("dma-breaker-trips", c["dma_breaker_trips"] >= 1,
          "the explicit ring burst must trip the ring breaker — the "
          "depth-0 fallback is the mode under test "
          f"(trips={c['dma_breaker_trips']})")
    check("ladder-token-parity", bool(c["ladder_token_parity"]),
          "throttle and shed rungs must be token-invisible against the "
          "unbounded run (recovery-off parity envelope)")
    check("ladder-peak-within-budget", bool(c["ladder_peak_within_budget"]),
          "parity arm: peak host-stash bytes must stay <= the budget")
    check("ladder-throttles-nonzero", c["ladder_throttles"] > 0,
          "the throttle rung must fire, else its parity claim is vacuous "
          f"(throttles={c['ladder_throttles']})")
    check("ladder-shed-resumed", c["ladder_sheds"] > 0
          and c["ladder_shed_resumed"] > 0,
          "the shed rung must fire and shed requests must resume and "
          f"finish (sheds={c['ladder_sheds']}, "
          f"shed_resumed={c['ladder_shed_resumed']})")
    check("full-ladder-ceiling", c["full_ladder_denied_offloads"] > 0,
          "tight-budget arm: the swap-out hard ceiling must deny at "
          f"least one offload (denied={c['full_ladder_denied_offloads']})")
    check("full-ladder-rungs", c["full_ladder_denies"] > 0
          and c["full_ladder_deepens"] > 0,
          "tight-budget arm: deny-prefetch and deepen-timers rungs must "
          f"both fire (denies={c['full_ladder_denies']}, "
          f"deepens={c['full_ladder_deepens']})")
    check("full-ladder-peak-no-worse", bool(c["full_ladder_peak_no_worse"]),
          "tight-budget arm: peak stash must never exceed the unbounded "
          "run's (the ceiling stops all optimization-path growth)")
    check("full-ladder-statuses", bool(c["full_ladder_statuses_clean"]),
          "tight-budget arm: every request must end completed or "
          "shed-resumed")
    check("nan-single-recovered", bool(c["nan_single_recovered"]),
          "a single poisoned step must be absorbed by one bounded "
          "quarantine rewind with every request completing")
    check("nan-double-quarantined", bool(c["nan_double_quarantined"]),
          "a re-poisoned lane must retire exactly one request "
          "'quarantined' instead of looping")
    check("nan-peer-parity", bool(c["nan_peer_parity"]),
          "the unpoisoned peer lane must be token-identical to a clean "
          "run in both poison scenarios")


def check_failover(path: pathlib.Path) -> None:
    """Named criteria for the replica-kill benchmark
    (benchmarks/failover.py -> BENCH_failover.json): zero lost requests
    across a mid-trace replica crash, token-identical checkpoint
    recovery, append-only journal consistency, exact controller
    accounting on the survivors, and a floor on the foreground
    deadline-hit rate through the failover window."""
    print(f"== {path} [--failover]")
    f = json.loads(path.read_text())
    if not require_keys("failover", f, (
            "lost_requests", "n_failovers", "recovered_with_checkpoint",
            "checkpoint_parity", "checkpoint_audited", "journal_consistent",
            "journal_audited", "invariants_ok", "fg_deadline_hit_window",
            "fg_in_window", "fg_hit_floor")):
        return
    check("failover-fired", f["n_failovers"] >= 1,
          "the trace must actually kill a replica, else every other "
          f"failover assertion is vacuous (n_failovers={f['n_failovers']})")
    check("failover-zero-lost", f["lost_requests"] == 0,
          "a replica crash may repeat decode work but must never lose a "
          f"request (lost_requests={f['lost_requests']})")
    check("failover-checkpoint-recovery", f["recovered_with_checkpoint"] >= 1,
          "at least one in-flight lane must resume from a router-side "
          "checkpoint — the freeze-native migration path under test "
          f"(recovered_with_checkpoint={f['recovered_with_checkpoint']})")
    check("failover-checkpoint-parity", bool(f["checkpoint_parity"]),
          "every checkpoint-recovered request must be token-identical to "
          f"an uninterrupted solo run ({f['checkpoint_audited']} audited)")
    check("failover-journal-consistent", bool(f["journal_consistent"]),
          "each recovered request's final tokens must extend its "
          "journal-at-failure prefix exactly (recovery off -> append-only; "
          f"{f['journal_audited']} audited)")
    check("failover-invariants", bool(f["invariants_ok"]),
          "surviving replicas must pass the exact stash/exported-bytes "
          "controller accounting audit")
    check("failover-fg-window-floor",
          f["fg_deadline_hit_window"] >= f["fg_hit_floor"],
          "foreground requests overlapping the failover window must still "
          f"hit >= {f['fg_hit_floor']:.0%} of deadlines "
          f"(got {f['fg_deadline_hit_window']} over "
          f"{f['fg_in_window']} request(s))")
    check("failover-fg-window-nonempty", f["fg_in_window"] >= 1,
          "the trace must place foreground requests inside the failover "
          f"window, else the floor is vacuous (fg_in_window={f['fg_in_window']})")


def check_serving(path: pathlib.Path) -> None:
    """Named criteria for the multi-tenant streaming-server benchmark
    (benchmarks/serving.py -> BENCH_serving.json): weighted-fair goodput
    per tenant within bounds of its weight share under a hog flood,
    mid-stream disconnects exercised and leak-free, streaming parity
    with the batch path, and zero unhandled server exceptions."""
    print(f"== {path} [--serving]")
    s = json.loads(path.read_text())
    if not require_keys("serving", s, (
            "fairness_ok", "fairness", "streaming_parity_ok",
            "stream_replay_parity_ok", "disconnected_mid_stream",
            "lanes_leaked", "stranded_entries", "audit_clean",
            "unhandled_exceptions", "n_cancelled", "goodput_per_tenant")):
        return
    ratios = {n: f.get("ratio") for n, f in s["fairness"].items()}
    check("serving-fairness", bool(s["fairness_ok"]),
          "every tenant's goodput share must stay within the fairness "
          f"bounds of its weight share (ratios={ratios}, goodput="
          f"{s['goodput_per_tenant']})")
    check("serving-no-unhandled", s["unhandled_exceptions"] == 0,
          "the async serving loop must never swallow a crash "
          f"(unhandled_exceptions={s['unhandled_exceptions']})")
    check("serving-disconnects-nonzero", s["disconnected_mid_stream"] > 0,
          "the trace must exercise mid-stream client disconnects, else "
          "the cancellation criteria are vacuous "
          f"(disconnected={s['disconnected_mid_stream']}, "
          f"cancelled={s['n_cancelled']})")
    check("serving-no-lane-leak",
          s["lanes_leaked"] == 0 and s["stranded_entries"] == 0,
          "disconnected requests must free their lanes and leave no "
          f"stranded scheduler entry (lanes_leaked={s['lanes_leaked']}, "
          f"stranded={s['stranded_entries']})")
    check("serving-audit-clean", bool(s["audit_clean"]),
          "the paged controller's stash/exported-bytes accounting must "
          "audit clean after the disconnect-heavy trace (no KV leak)")
    check("serving-streaming-parity", bool(s["streaming_parity_ok"]),
          "the probe request's streamed token sequence must be identical "
          "to the same request through the batch Scheduler path")
    check("serving-replay-parity", bool(s["stream_replay_parity_ok"]),
          "every stream's token/rewind replay must reconstruct exactly "
          "the request's final committed tokens")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("report", type=pathlib.Path,
                    help="experiments/bench/continuous_batching.json")
    ap.add_argument("bench", type=pathlib.Path,
                    help="BENCH_continuous_batching.json (repo root)")
    ap.add_argument("--scheduling", type=pathlib.Path, default=None,
                    help="experiments/bench/scheduling.json (mixed-SLO "
                         "trace, benchmarks/scheduling.py)")
    ap.add_argument("--chaos", type=pathlib.Path, default=None,
                    help="BENCH_chaos.json (fault-injection / "
                         "degradation-ladder criteria, benchmarks/chaos.py)")
    ap.add_argument("--failover", type=pathlib.Path, default=None,
                    help="BENCH_failover.json (replica-kill criteria, "
                         "benchmarks/failover.py)")
    ap.add_argument("--serving", type=pathlib.Path, default=None,
                    help="BENCH_serving.json (multi-tenant streaming "
                         "server criteria, benchmarks/serving.py)")
    ap.add_argument("--quant", action="store_true",
                    help="assert the quantized-KV guardrail block in the "
                         "bench summary (int8 needle arm: accuracy floor "
                         "1.0, device-KV and DMA-byte cuts vs the "
                         "unquantized arm)")
    ap.add_argument("--max-retraces", type=int, default=None,
                    metavar="N",
                    help="assert the benchmarks' steady-state jit "
                         "compile-cache growth (n_retraces, measured by "
                         "repro.analysis.trace_guard) is <= N per arm")
    args = ap.parse_args(argv)

    FAILURES.clear()            # main() is re-entrant for the unit tests
    check_report(args.report)
    check_bench(args.bench, max_retraces=args.max_retraces)
    if args.quant:
        check_quant(args.bench)
    if args.scheduling is not None:
        check_scheduling(args.scheduling, max_retraces=args.max_retraces)
    if args.chaos is not None:
        check_chaos(args.chaos)
    if args.failover is not None:
        check_failover(args.failover)
    if args.serving is not None:
        check_serving(args.serving)

    if FAILURES:
        print(f"\n{len(FAILURES)} benchmark assertion(s) failed: "
              + ", ".join(FAILURES))
    else:
        print("\nall benchmark assertions passed")
    return len(FAILURES)


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Markdown link checker for the docs CI job (stdlib only).

Usage: python tools/check_links.py README.md docs [more files/dirs...]

Checks every relative link target `[text](path)` / `[text](path#anchor)`
in the given markdown files (directories are scanned for *.md) against
the working tree.  External links (http/https/mailto) are skipped — this
guards against the docs rotting relative to the repo, not the internet.
In-file anchors are validated against the target file's headings using
GitHub's slug rules (lowercase, spaces -> dashes, punctuation dropped).
Exits non-zero listing every broken link.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
CODE_FENCE = re.compile(r"```.*?```", re.S)


def slug(heading: str) -> str:
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set:
    text = CODE_FENCE.sub("", path.read_text())
    return {slug(m.group(1)) for m in HEADING.finditer(text)}


def check_file(md: pathlib.Path, errors: list) -> None:
    text = CODE_FENCE.sub("", md.read_text())
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = md if not path_part else \
            (md.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md" \
                and slug(anchor) not in anchors_of(dest):
            errors.append(f"{md}: missing anchor -> {target}")


def main(argv) -> int:
    files: list = []
    for arg in argv or ["README.md", "docs"]:
        p = pathlib.Path(arg)
        files += sorted(p.rglob("*.md")) if p.is_dir() else [p]
    errors: list = []
    for md in files:
        check_file(md, errors)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""Repo-specific static-analysis suite (hot-path sanitizer).

Three AST passes tuned to this codebase's serving hot path:

* ``hostsync``  — implicit device->host syncs in hot regions
* ``donation``  — use-after-donate on jitted callables' donated args
* ``retrace``   — jit call sites that grow the compile cache

Run with ``python -m tools.analysis src/`` (see ``__main__.py``), or use
the pieces directly::

    from tools.analysis import ALL_PASSES, REPO_CONFIG, run_passes
    diags = run_passes(["src"], ALL_PASSES, REPO_CONFIG)

``docs/analysis.md`` documents suppressions (``# hotpath: ok(<reason>)``),
hot-region declaration, and how to add a pass.
"""
from .config import REPO_CONFIG
from .donation import DonationPass
from .framework import (Config, Context, Diagnostic, Pass, SourceFile,
                        run_passes, walk_paths)
from .hostsync import HostSyncPass
from .retrace import RetracePass

ALL_PASSES = (HostSyncPass(), DonationPass(), RetracePass())

__all__ = [
    "ALL_PASSES", "Config", "Context", "Diagnostic", "DonationPass",
    "HostSyncPass", "Pass", "REPO_CONFIG", "RetracePass", "SourceFile",
    "run_passes", "walk_paths",
]

"""Shared pass framework for the repo's static-analysis suite.

The suite is a set of small AST passes tuned to *this* codebase's failure
modes (hidden device->host syncs, jit retraces, use-after-donate) rather
than a general linter.  This module owns everything the passes share:

* ``SourceFile`` — one parsed file: AST, comment map, function table with
  qualified names, hot-path spans, and suppression bookkeeping.
* Suppressions — ``# hotpath: ok(<reason>)`` on the flagged line (or on
  its own line directly above) silences any diagnostic on that line.  The
  reason is mandatory; a bare ``# hotpath: ok`` or ``# hotpath: ok()`` is
  itself reported and cannot be suppressed.
* Hot-path declaration — a function is *hot* if its qualified name (e.g.
  ``PagedContinuousEngine._boundary_tick``) is listed in the config's
  ``hot_functions``, or if ``# hotpath: hot`` appears on (or directly
  above) its ``def`` line.  Nested functions inherit hotness from any
  enclosing hot function.
* ``Pass`` — the interface: ``run(source, ctx)`` yielding ``Diagnostic``s.
* ``Context`` — cross-file state, notably a table of function signatures
  used by the donation pass to map ``donate_argnames`` to call-site
  positions through ``functools.partial`` wrappers.
* ``run_passes`` — the driver: walk paths, parse once, run every pass,
  apply suppressions, and render ``text`` or ``github`` output.

Passes register here via ``tools.analysis.__init__``; see
``docs/analysis.md`` for the catalogue and how to add one.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(r"#\s*hotpath:\s*ok\s*(?:\((?P<reason>.*)\))?\s*$")
HOT_MARK_RE = re.compile(r"#\s*hotpath:\s*hot\b")


# --------------------------------------------------------------------- #
# diagnostics
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class Diagnostic:
    path: str
    line: int
    col: int
    pass_name: str
    message: str
    suppressed: Optional[str] = None    # suppression reason when silenced

    def render(self, fmt: str = "text") -> str:
        if fmt == "github":
            return (f"::error file={self.path},line={self.line},"
                    f"col={self.col},title={self.pass_name}::{self.message}")
        return f"{self.path}:{self.line}:{self.col}: [{self.pass_name}] " \
               f"{self.message}"


# --------------------------------------------------------------------- #
# configuration
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Config:
    """Per-run pass configuration (see tools/analysis/config.py for the
    repo's instance; tests construct ad-hoc ones)."""
    # qualified names (Class.method / function) that are hot-path regions
    hot_functions: frozenset = frozenset()
    # identifiers that mark an expression as device-resident when they
    # appear anywhere in its attribute chain (self.state..., pp.scratch...)
    device_roots: frozenset = frozenset()
    # functions whose inline shape-constructor args form a declared closed
    # bucket set (warm-up loops compiling each bucket exactly once)
    bucketed_functions: frozenset = frozenset()
    # module aliases
    numpy_aliases: frozenset = frozenset({"np", "numpy"})
    jnp_aliases: frozenset = frozenset({"jnp"})
    jax_aliases: frozenset = frozenset({"jax"})
    # path fragments to skip entirely (sync in test code is fine)
    exclude_parts: tuple = ("tests", "test_", "conftest")


# --------------------------------------------------------------------- #
# source files
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class FuncInfo:
    qualname: str
    name: str
    lineno: int
    end_lineno: int
    node: ast.AST
    hot: bool = False


class SourceFile:
    """A parsed file plus the comment/function/suppression indexes the
    passes need.  Raises SyntaxError upward — the driver reports files it
    cannot parse as (unsuppressable) diagnostics."""

    def __init__(self, path: str, text: Optional[str] = None,
                 config: Config = Config()):
        self.path = path
        self.text = pathlib.Path(path).read_text() if text is None else text
        self.config = config
        self.tree = ast.parse(self.text, filename=path)
        self._scan_comments()
        self._build_functions()
        self._resolve_markers()

    # ---- comments / suppressions ---------------------------------- #
    def _scan_comments(self) -> None:
        self.comments: Dict[int, str] = {}
        code_lines: Set[int] = set()
        try:
            toks = list(tokenize.generate_tokens(
                io.StringIO(self.text).readline))
        except tokenize.TokenError:
            toks = []
        skip = {tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE,
                tokenize.INDENT, tokenize.DEDENT, tokenize.ENDMARKER}
        for tok in toks:
            if tok.type == tokenize.COMMENT:
                self.comments[tok.start[0]] = tok.string
            elif tok.type not in skip:
                code_lines.update(range(tok.start[0], tok.end[0] + 1))
        self._code_lines = code_lines

    def _apply_line(self, comment_line: int) -> Optional[int]:
        """The code line a comment governs: its own line when it trails
        code, else the next code line below it."""
        if comment_line in self._code_lines:
            return comment_line
        later = [ln for ln in self._code_lines if ln > comment_line]
        return min(later) if later else None

    def _resolve_markers(self) -> None:
        self.suppressions: Dict[int, str] = {}
        self.bad_suppressions: List[Tuple[int, str]] = []
        hot_lines: List[int] = []
        for cline, text in self.comments.items():
            m = SUPPRESS_RE.search(text)
            if m:
                reason = (m.group("reason") or "").strip()
                target = self._apply_line(cline)
                if not reason:
                    self.bad_suppressions.append(
                        (cline, "suppression without a reason — write "
                                "'# hotpath: ok(<why this sync is fine>)'"))
                elif target is not None:
                    self.suppressions[target] = reason
                continue
            if HOT_MARK_RE.search(text):
                target = self._apply_line(cline)
                if target is not None:
                    hot_lines.append(target)
        # inline hot markers: the innermost function containing the marked
        # line becomes hot (markers belong on/above the `def` line)
        for ln in hot_lines:
            fn = self.innermost_function(ln)
            if fn is not None:
                fn.hot = True

    # ---- function table ------------------------------------------- #
    def _build_functions(self) -> None:
        self.funcs: List[FuncInfo] = []
        cfg = self.config

        def visit(node: ast.AST, scope: Tuple[str, ...]) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = ".".join(scope + (child.name,))
                    self.funcs.append(FuncInfo(
                        qualname=qual, name=child.name,
                        lineno=child.lineno,
                        end_lineno=child.end_lineno or child.lineno,
                        node=child, hot=qual in cfg.hot_functions))
                    visit(child, scope + (child.name,))
                elif isinstance(child, ast.ClassDef):
                    visit(child, scope + (child.name,))
                else:
                    visit(child, scope)

        visit(self.tree, ())

    def innermost_function(self, line: int) -> Optional[FuncInfo]:
        best = None
        for fn in self.funcs:
            if fn.lineno <= line <= fn.end_lineno:
                if best is None or fn.lineno > best.lineno:
                    best = fn
        return best

    def enclosing_functions(self, line: int) -> List[FuncInfo]:
        return [fn for fn in self.funcs if fn.lineno <= line <= fn.end_lineno]

    def is_hot(self, line: int) -> bool:
        """True when the line sits inside any hot function (nested
        helpers inherit hotness from their enclosing hot region)."""
        return any(fn.hot for fn in self.enclosing_functions(line))


# --------------------------------------------------------------------- #
# expression helpers shared by passes
# --------------------------------------------------------------------- #
def dotted(node: ast.AST) -> Optional[str]:
    """'self.state.freeze' for a Name/Attribute chain, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def chain_idents(node: ast.AST) -> Set[str]:
    """Every identifier appearing in Name/Attribute chains under node."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def contains_nonconstant(node: ast.AST) -> bool:
    """True when the expression depends on any runtime name."""
    return any(isinstance(n, (ast.Name, ast.Attribute))
               for n in ast.walk(node))


# --------------------------------------------------------------------- #
# cross-file context
# --------------------------------------------------------------------- #
class Context:
    """Cross-file state built before the passes run.

    ``signatures`` maps a bare function name to the list of positional
    parameter-name tuples seen across all scanned files (lambdas and
    nested defs included).  The donation pass uses it to turn
    ``donate_argnames`` into call-site positions; when defs with the same
    name disagree on a donated parameter's position, the positional
    mapping for that name is dropped (keyword call sites still match).
    """

    def __init__(self, config: Config):
        self.config = config
        self.signatures: Dict[str, List[Tuple[str, ...]]] = {}

    def add_file(self, sf: SourceFile) -> None:
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                name = getattr(node, "name", None)
                if name is None:
                    continue
                params = tuple(a.arg for a in node.args.args)
                self.signatures.setdefault(name, []).append(params)

    def param_index(self, func_name: str, param: str) -> Optional[int]:
        """Positional index of ``param`` in every known def of
        ``func_name`` — None when unknown or ambiguous."""
        idxs = set()
        for params in self.signatures.get(func_name, []):
            if param in params:
                idxs.add(params.index(param))
        return idxs.pop() if len(idxs) == 1 else None


# --------------------------------------------------------------------- #
# pass interface + driver
# --------------------------------------------------------------------- #
class Pass:
    name = "base"
    description = ""

    def run(self, sf: SourceFile, ctx: Context) -> Iterable[Diagnostic]:
        raise NotImplementedError


def walk_paths(paths: Sequence[str], config: Config) -> List[pathlib.Path]:
    files: List[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        cands = sorted(path.rglob("*.py")) if path.is_dir() else [path]
        for f in cands:
            s = str(f)
            if "__pycache__" in s:
                continue
            if path.is_dir() and any(part in s for part
                                     in config.exclude_parts):
                continue
            files.append(f)
    return files


def run_passes(paths: Sequence[str], passes: Sequence[Pass],
               config: Config) -> List[Diagnostic]:
    """Run every pass over every file; returns ALL diagnostics with
    suppressed ones annotated (callers filter on ``.suppressed``)."""
    files = walk_paths(paths, config)
    sources: List[SourceFile] = []
    diags: List[Diagnostic] = []
    for f in files:
        try:
            sources.append(SourceFile(str(f), config=config))
        except SyntaxError as e:
            diags.append(Diagnostic(str(f), e.lineno or 1, e.offset or 1,
                                    "parse", f"syntax error: {e.msg}"))
    ctx = Context(config)
    for sf in sources:
        ctx.add_file(sf)
    for sf in sources:
        for ln, msg in sf.bad_suppressions:
            diags.append(Diagnostic(sf.path, ln, 1, "suppression", msg))
        for p in passes:
            for d in p.run(sf, ctx):
                if d.line in sf.suppressions:
                    d.suppressed = sf.suppressions[d.line]
                diags.append(d)
    diags.sort(key=lambda d: (d.path, d.line, d.col))
    return diags

"""CLI driver: ``python -m tools.analysis [paths...]``.

Exit code is the number of *unsuppressed* findings.  ``--format=github``
renders each finding as a GitHub Actions workflow command so CI runs
annotate the offending lines in the diff view.
"""
from __future__ import annotations

import argparse
import sys

from . import ALL_PASSES, REPO_CONFIG, run_passes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--format", choices=("text", "github"), default="text",
                    help="diagnostic rendering (github = CI annotations)")
    ap.add_argument("--pass", dest="only", action="append", default=None,
                    metavar="NAME",
                    help="run only the named pass (repeatable; "
                         f"known: {', '.join(p.name for p in ALL_PASSES)})")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings silenced by "
                         "'# hotpath: ok(reason)' comments")
    args = ap.parse_args(argv)

    passes = ALL_PASSES
    if args.only:
        unknown = set(args.only) - {p.name for p in ALL_PASSES}
        if unknown:
            ap.error(f"unknown pass(es): {', '.join(sorted(unknown))}")
        passes = tuple(p for p in ALL_PASSES if p.name in args.only)

    diags = run_passes(args.paths or ["src"], passes, REPO_CONFIG)
    active = [d for d in diags if d.suppressed is None]
    suppressed = [d for d in diags if d.suppressed is not None]

    for d in active:
        print(d.render(args.format))
    if args.show_suppressed:
        for d in suppressed:
            print(f"{d.render('text')}  [suppressed: {d.suppressed}]")
    print(f"{len(active)} finding(s), {len(suppressed)} suppressed "
          f"({', '.join(p.name for p in passes)})", file=sys.stderr)
    return len(active)


if __name__ == "__main__":
    sys.exit(main())

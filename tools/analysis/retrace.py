"""retrace — flag call-site patterns that grow a jit's compile cache.

A jitted callable recompiles whenever an argument's abstract value
changes: a new shape, a new dtype (including the weak-typed dtype a bare
Python scalar gets), or a new static-argument value.  The step loop must
hit a *closed* set of traces — anything data-dependent retraces forever.

Flagged at call sites of collected ``jax.jit`` targets:

* **python-scalar** — a bare numeric/bool literal argument at a
  non-static position.  Python scalars trace as *weak-typed* values: mix
  one call site passing ``0`` with another passing ``jnp.int32(0)`` and
  the jit compiles twice.  Wrap in ``jnp.int32(...)``/``jnp.asarray`` or
  declare the position static.  (Named scalar variables are not flagged —
  their types aren't statically known; the runtime ``trace_guard`` is the
  backstop.)
* **unhashable-static** — a list/dict/set literal passed at a
  ``static_argnums``/``static_argnames`` position (raises at runtime).
* **open-shape** — an inline array constructor (``jnp.zeros`` /
  ``ones`` / ``full`` / ``empty`` / ``arange``) or slice expression with
  a *non-constant* extent passed straight into a jitted call, outside a
  function declared in the config's ``bucketed_functions``.  Bucketed
  functions (``warm_prefill``-style warm-up loops iterating a fixed
  chunk/bucket table) compile each member shape exactly once by design.

This is a lexical heuristic, deliberately conservative; its runtime
companion ``repro.analysis.runtime.trace_guard`` asserts the actual
compile-cache sizes stay flat over the benchmarks' steady state.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Tuple

from .framework import (Context, Diagnostic, Pass, SourceFile,
                        contains_nonconstant, dotted)
from .donation import _const_tuple, _is_jax_jit

_SHAPE_CTORS = ("zeros", "ones", "full", "empty", "arange")


class _Target:
    def __init__(self, static_nums: Tuple[int, ...],
                 static_names: Tuple[str, ...]):
        self.static_nums = static_nums
        self.static_names = static_names


class RetracePass(Pass):
    name = "retrace"
    description = ("jit call sites passing python scalars, open-ended "
                   "shapes, or unhashable static args")

    def _collect(self, sf: SourceFile, ctx: Context) -> Dict[str, _Target]:
        cfg = ctx.config
        targets: Dict[str, _Target] = {}
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            call = node.value
            if not (isinstance(call, ast.Call)
                    and _is_jax_jit(call.func, cfg)):
                continue
            name = dotted(node.targets[0])
            if name is None:
                continue
            nums: Tuple[int, ...] = ()
            names: Tuple[str, ...] = ()
            for kw in call.keywords:
                if kw.arg == "static_argnums":
                    nums = tuple(v for v in _const_tuple(kw.value)
                                 if isinstance(v, int))
                elif kw.arg == "static_argnames":
                    names = tuple(v for v in _const_tuple(kw.value)
                                  if isinstance(v, str))
            targets[name] = _Target(nums, names)
        return targets

    def run(self, sf: SourceFile, ctx: Context) -> Iterable[Diagnostic]:
        cfg = ctx.config
        targets = self._collect(sf, ctx)
        if not targets:
            return []
        out: List[Diagnostic] = []
        np_like = cfg.numpy_aliases | cfg.jnp_aliases

        def emit(node: ast.AST, msg: str) -> None:
            out.append(Diagnostic(sf.path, node.lineno, node.col_offset + 1,
                                  self.name, msg))

        def open_shape(expr: ast.AST) -> bool:
            """Inline constructor/slice whose extent isn't a literal."""
            for n in ast.walk(expr):
                if isinstance(n, ast.Call):
                    head = (dotted(n.func) or "").split(".")
                    if (len(head) == 2 and head[0] in np_like
                            and head[1] in _SHAPE_CTORS and n.args
                            and contains_nonconstant(n.args[0])):
                        return True
                elif isinstance(n, ast.Slice):
                    for bound in (n.lower, n.upper, n.step):
                        if bound is not None \
                                and contains_nonconstant(bound):
                            return True
            return False

        for fn in sf.funcs:
            bucketed = fn.qualname in cfg.bucketed_functions \
                or fn.name in cfg.bucketed_functions
            for node in ast.walk(fn.node):
                if not isinstance(node, ast.Call):
                    continue
                tname = dotted(node.func)
                tgt = targets.get(tname or "")
                if tgt is None:
                    continue
                args: List[Tuple[object, ast.AST, bool]] = \
                    [(i, a, i in tgt.static_nums)
                     for i, a in enumerate(node.args)]
                args += [(kw.arg, kw.value, kw.arg in tgt.static_names)
                         for kw in node.keywords]
                for key, a, is_static in args:
                    if is_static:
                        if isinstance(a, (ast.List, ast.Dict, ast.Set)):
                            emit(a, f"unhashable literal passed at static "
                                    f"position {key!r} of {tname} — jit "
                                    "static args must be hashable")
                        continue
                    if (isinstance(a, ast.Constant)
                            and isinstance(a.value, (bool, int, float))):
                        emit(a, f"bare python scalar {a.value!r} passed to "
                                f"jitted {tname} (arg {key!r}) — weak-typed "
                                "scalars fork the compile cache; wrap in "
                                "jnp.asarray/jnp.int32 or mark the "
                                "position static")
                        continue
                    if not bucketed and open_shape(a):
                        emit(a, f"data-dependent shape built inline in a "
                                f"call to jitted {tname} (arg {key!r}) — "
                                "every new extent retraces; route through "
                                "a declared bucket set (config "
                                "bucketed_functions) or pad to a fixed "
                                "shape")
        return out

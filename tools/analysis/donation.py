"""donation — use-after-donate detection for jitted callables.

``jax.jit(..., donate_argnames=("state",))`` invalidates the argument
buffer the moment the call is dispatched: reading the donated array
afterwards returns garbage (or raises on backends with real donation).
The engines donate their decode state on every step/prefill/lane write,
so the safe idiom is the same-statement rebind::

    self.state = self._write_lane(self.state, lane_state, lane)   # ok
    out = self._write_lane(self.state, lane_state, lane)
    dbg = self.state.freeze.frozen                                # BUG

The pass:

1. collects ``<target> = jax.jit(fn, donate_argnums=... /
   donate_argnames=...)`` assignments (``fn`` may be a ``functools.partial``,
   a lambda, or a name defined in any scanned file);
2. resolves each donated name to a call-site position via the cross-file
   signature table, shifting past leading positional ``partial`` binds
   (keyword binds don't shift; an ambiguous name falls back to matching
   keyword call sites only);
3. at every call of the target, takes donated arguments that are plain
   names / attribute chains and flags the first later *read* of that
   chain in the enclosing function that happens before any *write* to it.

The read/write scan is linear in source order — branches are not modeled
— which is exactly the shape of the engine code this guards (straight-
line step/tick bodies).  Donation is checked everywhere, not just hot
regions: a stale read is a correctness bug, not a perf bug.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Tuple

from .framework import Context, Diagnostic, Pass, SourceFile, dotted


class _JitInfo:
    def __init__(self, donate_nums: Tuple[int, ...],
                 donate_names: Tuple[str, ...], wrapped: Optional[ast.AST],
                 partial_shift: int):
        self.donate_nums = donate_nums
        self.donate_names = donate_names
        self.wrapped = wrapped            # the fn expression inside jax.jit
        self.partial_shift = partial_shift


def _const_tuple(node: ast.AST) -> Tuple:
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant))
    if isinstance(node, ast.Constant):
        return (node.value,)
    return ()


def _is_jax_jit(func: ast.AST, cfg) -> bool:
    head = dotted(func) or ""
    parts = head.split(".")
    return (parts[-1] == "jit"
            and (len(parts) == 1 or parts[0] in cfg.jax_aliases))


class DonationPass(Pass):
    name = "donation"
    description = ("names read after being passed at a donate_argnums/"
                   "donate_argnames position of a jitted callable")

    # ---- collection ------------------------------------------------- #
    def _collect(self, sf: SourceFile, ctx: Context) -> Dict[str, _JitInfo]:
        cfg = ctx.config
        jits: Dict[str, _JitInfo] = {}
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            call = node.value
            if not (isinstance(call, ast.Call)
                    and _is_jax_jit(call.func, cfg)):
                continue
            nums: Tuple[int, ...] = ()
            names: Tuple[str, ...] = ()
            for kw in call.keywords:
                if kw.arg == "donate_argnums":
                    nums = tuple(v for v in _const_tuple(kw.value)
                                 if isinstance(v, int))
                elif kw.arg == "donate_argnames":
                    names = tuple(v for v in _const_tuple(kw.value)
                                  if isinstance(v, str))
            if not nums and not names:
                continue
            target = dotted(node.targets[0])
            if target is None or not call.args:
                continue
            wrapped = call.args[0]
            shift = 0
            if (isinstance(wrapped, ast.Call)
                    and (dotted(wrapped.func) or "").endswith("partial")
                    and wrapped.args):
                shift = len(wrapped.args) - 1   # positional binds shift
                wrapped = wrapped.args[0]       # the real fn expression
            jits[target] = _JitInfo(nums, names, wrapped, shift)
        return jits

    def _positions(self, info: _JitInfo, ctx: Context) -> Dict[int, str]:
        """call-site positional index -> donated-name label."""
        pos: Dict[int, str] = {i: f"argnum {i}" for i in info.donate_nums}
        if not info.donate_names:
            return pos
        params: Optional[Tuple[str, ...]] = None
        if isinstance(info.wrapped, ast.Lambda):
            params = tuple(a.arg for a in info.wrapped.args.args)
        else:
            fname = (dotted(info.wrapped) or "").split(".")[-1]
            for name in info.donate_names:
                idx = ctx.param_index(fname, name) if fname else None
                if idx is not None and idx - info.partial_shift >= 0:
                    pos[idx - info.partial_shift] = name
            return pos
        for name in info.donate_names:
            if name in params:
                pos[params.index(name)] = name
        return pos

    # ---- checking --------------------------------------------------- #
    def run(self, sf: SourceFile, ctx: Context) -> Iterable[Diagnostic]:
        jits = self._collect(sf, ctx)
        if not jits:
            return []
        out: List[Diagnostic] = []
        for fn in sf.funcs:
            body = fn.node
            for call, stmt in self._calls_in(body):
                target = dotted(call.func)
                info = jits.get(target or "")
                if info is None:
                    continue
                donated = self._donated_args(call, info, ctx)
                for expr_name, label in donated:
                    if self._stmt_writes(stmt, expr_name):
                        continue          # same-statement rebind: safe
                    bad = self._first_read_before_write(
                        body, expr_name, stmt)
                    if bad is not None:
                        out.append(Diagnostic(
                            sf.path, bad.lineno, bad.col_offset + 1,
                            self.name,
                            f"'{expr_name}' is read here after being "
                            f"donated ({label}) to {target} on line "
                            f"{call.lineno} — rebind it from the call's "
                            "result first"))
        return out

    def _donated_args(self, call: ast.Call, info: _JitInfo,
                      ctx: Context) -> List[Tuple[str, str]]:
        donated: List[Tuple[str, str]] = []
        positions = self._positions(info, ctx)
        for i, arg in enumerate(call.args):
            if i in positions:
                name = dotted(arg)
                if name:
                    donated.append((name, positions[i]))
        for kw in call.keywords:
            if kw.arg in info.donate_names:
                name = dotted(kw.value)
                if name:
                    donated.append((name, kw.arg))
        return donated

    @staticmethod
    def _calls_in(fn_node: ast.AST) -> List[Tuple[ast.Call, ast.stmt]]:
        """(call, enclosing statement) pairs inside one function body,
        not descending into nested defs (they get their own FuncInfo)."""
        pairs: List[Tuple[ast.Call, ast.stmt]] = []

        def visit(node: ast.AST, stmt: Optional[ast.stmt]) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                    continue
                cstmt = child if isinstance(child, ast.stmt) else stmt
                if isinstance(child, ast.Call) and cstmt is not None:
                    pairs.append((child, cstmt))
                visit(child, cstmt)

        visit(fn_node, None)
        return pairs

    @staticmethod
    def _stmt_writes(stmt: ast.stmt, name: str) -> bool:
        targets: List[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        flat: List[ast.AST] = []
        for t in targets:
            flat += list(t.elts) if isinstance(t, (ast.Tuple, ast.List)) \
                else [t]
        return any(dotted(t) == name for t in flat)

    @staticmethod
    def _first_read_before_write(fn_node: ast.AST, name: str,
                                 stmt: ast.stmt) -> Optional[ast.AST]:
        """First Load of `name` after the donating statement, unless a
        Store to it (or to a prefix of it, e.g. rebinding `self.state`
        kills `self.state.freeze`) comes first.  The cutoff is the END
        of the statement containing the call, so the donated argument
        itself (and siblings in the same statement) never self-flag.
        Source order approximates execution order — good enough for
        straight-line engine bodies."""
        call_pos = (stmt.end_lineno or stmt.lineno,
                    stmt.end_col_offset or 0)
        events: List[Tuple[Tuple[int, int], str, ast.AST]] = []
        prefixes = {name}
        parts = name.split(".")
        for i in range(1, len(parts)):
            prefixes.add(".".join(parts[:i]))
        for n in ast.walk(fn_node):
            if not isinstance(n, (ast.Name, ast.Attribute)):
                continue
            d = dotted(n)
            if d is None:
                continue
            pos = (n.lineno, n.col_offset)
            if pos <= call_pos:
                continue
            if isinstance(n.ctx, ast.Store) and d in prefixes:
                events.append((pos, "w", n))
            elif isinstance(n.ctx, ast.Load) and (
                    d == name or d.startswith(name + ".")):
                # skip the inner chain of a Store attribute (self.state in
                # `self.state.x = ...` is a Load but part of the write)
                events.append((pos, "r", n))
        events.sort(key=lambda e: e[0])
        for pos, kind, n in events:
            if kind == "w":
                return None
            return n
        return None

"""hostsync — flag implicit device->host synchronization in hot regions.

Every one of these forces the host to wait for device compute when the
operand lives on device:

* ``np.asarray(x)`` / ``np.array(x)`` on a device value
* ``int(x)`` / ``float(x)`` / ``bool(x)`` on a device value
* ``x.item()`` / ``x.tolist()`` on a device value
* ``jax.device_get(...)`` and ``x.block_until_ready()`` (explicit syncs —
  always flagged in hot regions so each carries a reasoned suppression)
* iterating a device value (``for t in tokens_dev`` materializes it)

"Device value" is a lexical heuristic: the expression's attribute chain
contains one of the configured ``device_roots`` identifiers (``state``,
``scratch``, ``logits``...) or a ``jnp.*`` / ``jax.*`` call.  Host-side
numpy mirrors (``self.pos``, ring-drained dicts) share none of those
roots, so the boundary-tick commit loops stay clean without annotations.

Only *hot* regions are checked (config ``hot_functions`` or an inline
``# hotpath: hot`` marker): admission/retirement helpers and test code
may sync freely.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .framework import (Context, Diagnostic, Pass, SourceFile, chain_idents,
                        dotted)

_CASTS = ("int", "float", "bool")
_NP_CONVERSIONS = ("asarray", "array")
_SYNC_METHODS = ("item", "tolist")


class HostSyncPass(Pass):
    name = "hostsync"
    description = ("implicit device->host syncs (np.asarray, int(), "
                   ".item(), device_get, iteration) in hot-path regions")

    def run(self, sf: SourceFile, ctx: Context) -> Iterable[Diagnostic]:
        cfg = ctx.config
        out: List[Diagnostic] = []

        def is_device(node: ast.AST) -> bool:
            """Lexical device-value heuristic (see module docstring)."""
            if chain_idents(node) & cfg.device_roots:
                return True
            for n in ast.walk(node):
                if isinstance(n, ast.Call):
                    head = dotted(n.func) or ""
                    root = head.split(".", 1)[0]
                    if root in cfg.jnp_aliases | cfg.jax_aliases:
                        return True
            return False

        def emit(node: ast.AST, msg: str) -> None:
            out.append(Diagnostic(sf.path, node.lineno, node.col_offset + 1,
                                  self.name, msg))

        for node in ast.walk(sf.tree):
            line = getattr(node, "lineno", None)
            if line is None or not sf.is_hot(line):
                continue
            if isinstance(node, ast.Call):
                head = dotted(node.func) or ""
                parts = head.split(".")
                # jax.device_get(...) — explicit blocking pull
                if parts[0] in cfg.jax_aliases and parts[-1] == "device_get":
                    emit(node, "jax.device_get blocks the host on device "
                               "compute inside a hot region")
                    continue
                # np.asarray/np.array on a device value
                if (len(parts) == 2 and parts[0] in cfg.numpy_aliases
                        and parts[1] in _NP_CONVERSIONS and node.args
                        and is_device(node.args[0])):
                    emit(node, f"{head}(...) materializes a device value "
                               "on host (implicit D2H sync) in a hot region")
                    continue
                # int()/float()/bool() on a device value
                if (isinstance(node.func, ast.Name)
                        and node.func.id in _CASTS and node.args
                        and is_device(node.args[0])):
                    emit(node, f"{node.func.id}() on a device value forces "
                               "a scalar D2H sync in a hot region")
                    continue
                # x.item()/x.tolist()/x.block_until_ready()
                if isinstance(node.func, ast.Attribute):
                    meth = node.func.attr
                    if meth == "block_until_ready":
                        emit(node, ".block_until_ready() stalls the host "
                                   "inside a hot region")
                        continue
                    if meth in _SYNC_METHODS and is_device(node.func.value):
                        emit(node, f".{meth}() on a device value forces a "
                                   "D2H sync in a hot region")
                        continue
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                if is_device(node.iter):
                    emit(node, "iterating a device value materializes it "
                               "element-wise (hidden D2H sync per element)")
        # comprehension iterables (ast.comprehension has no lineno; use the
        # iterable expression's own position and hotness)
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                 ast.GeneratorExp)):
                for gen in node.generators:
                    it = gen.iter
                    if sf.is_hot(it.lineno) and is_device(it):
                        emit(it, "comprehension over a device value "
                                 "materializes it element-wise (hidden D2H "
                                 "sync per element)")
        return out

"""The repo's analysis configuration (plain Python — the container's
Python 3.10 has no stdlib TOML parser, and a config that can use
``frozenset`` directly needs no schema layer).

Three knobs matter; see docs/analysis.md for the full story:

* ``hot_functions`` — the serving hot path: everything executed per
  decode step or per boundary tick.  Admission-time helpers that run
  once per request (``_restore_host``, bucket padding) and the static
  ``Engine.generate`` reference loop (per-step sync *by design* — it is
  the paper-protocol baseline the continuous engines are measured
  against) are deliberately not listed.
* ``device_roots`` — identifiers that mark an expression as
  device-resident.  The engines keep host mirrors in distinctly-named
  attributes (``self.pos``, ``self.tok``, ring-drained ``host``/``toks``
  dicts), so the root set cleanly splits the two worlds.
* ``bucketed_functions`` — functions whose inline shape-constructor
  calls iterate a *closed* bucket table (compile-once warm-up loops).
"""
from .framework import Config

REPO_CONFIG = Config(
    hot_functions=frozenset({
        # dense continuous engine: per-step loop + in-serve admission
        "ContinuousEngine.admit",
        "ContinuousEngine.resume_lane",
        "ContinuousEngine.step_once",
        "ContinuousEngine._commit_step",
        # paged engine: step loop, boundary tick, DMA pulls/pushes,
        # chunked prefill, speculative thaw staging, remap installs
        "PagedContinuousEngine.step_once",
        "PagedContinuousEngine._commit_step",
        "PagedContinuousEngine._boundary_tick",
        "PagedContinuousEngine._pull_lanes",
        "PagedContinuousEngine._push_lanes",
        "PagedContinuousEngine._prefill_tick",
        "PagedContinuousEngine._install",
        "PagedContinuousEngine._maybe_prefetch",
        "PagedContinuousEngine._prefetch_lane",
        "PagedContinuousEngine._run_remaps",
        # shared lane machinery (ring drain runs every step)
        "_LaneEngineBase._drain_ring",
        "_LaneEngineBase._push_admit_token",
        "_LaneEngineBase._lane_params",
        # chaos hardening: breaker-gated ring depth + NaN quarantine run
        # every step; Endpoint.call wraps every guarded transfer
        "_LaneEngineBase._ring_guard",
        "_LaneEngineBase._quarantine_scan",
        "_LaneEngineBase._poison_lane",
        "Endpoint.call",
        # host-side paging controller: ticked at every page boundary
        "PagedController.tick",
        "PagedController.thaw_lane",
        "PagedController._kv_transfer",
        "PagedController._install_page",
        "PagedController._evict_coldest",
        "PagedController.ensure_resident",
        # budget-guarded host-stash writer (every stash allocation)
        "PagedController._store_put",
        # per-page quantization: freeze-time in-place pass + swap-out
        # narrowing + thaw installs all run inside the boundary tick
        "PagedController._quantize_frozen_resident",
        "PagedController._store_payload",
        "PagedController._install_kv",
        # core.quant numeric recipe (module-level, hence bare names):
        # called per quantized page on freeze/stash/thaw/rewind
        "quantize_page",
        "dequantize_page",
        "page_scales",
        "narrow_payload",
        # page-batched offload round-trip (dense engine's commit path)
        "HostOffloadController.sync",
        # replica router: the tick loop, the per-tick heartbeat compare
        # and the failover re-place path are all host-side bookkeeping
        # and must stay free of device syncs (checkpoint_lane is NOT
        # listed — it is a deliberate blocking pull, like suspend_lane)
        "ReplicaRouter.step",
        "ReplicaRouter._heartbeat",
        "ReplicaRouter._failover",
        # multi-tenant server front end: the WFQ admission scan runs at
        # every free-lane fill, tenancy accounting runs per lane per
        # step, and the async engine's op/pump pair runs between every
        # scheduler step on the event loop — all pure host bookkeeping
        "Scheduler._pop_admissible",
        "TenancyController.may_admit",
        "TenancyController.note_progress",
        "AsyncServingEngine._apply_ops",
        "AsyncServingEngine._pump_all",
    }),
    device_roots=frozenset({
        "state",        # self.state / lane_state / decode state pytrees
        "lane_state",
        "scratch",      # pp.scratch prefill cache
        "logits",
        "dev",          # _pull_lanes' gathered device tuple
        "cache",        # KVCache pytrees handed to the offloader
        "info",         # decode_step telemetry pytree (pre-ring)
    }),
    bucketed_functions=frozenset({
        # warm-up loops over the closed chunk/bucket tables: each member
        # shape compiles exactly once before serving starts
        "PagedContinuousEngine.warm_prefill",
    }),
)

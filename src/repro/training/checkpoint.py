"""Minimal msgpack checkpointing for params/optimizer pytrees (offline
container: no orbax).  Arrays are stored as (dtype, shape, bytes) triples
keyed by flattened tree paths; restore validates structure."""
from __future__ import annotations

import pathlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _key(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "name", getattr(p, "key", getattr(p, "idx", p)))))
    return "/".join(parts)


def save(path: str, tree: Any) -> None:
    flat = {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        flat[_key(p)] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    pathlib.Path(path).parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(flat))


def restore(path: str, like: Any) -> Any:
    with open(path, "rb") as f:
        flat = msgpack.unpackb(f.read())
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        k = _key(p)
        if k not in flat:
            raise KeyError(f"checkpoint missing {k}")
        rec = flat[k]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {k}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)

"""AdamW in pure JAX (pytree-based, sharding-transparent).

Moments are f32 and inherit the parameter PartitionSpecs, so optimizer state
is FSDP-sharded exactly like the params (no replicated optimizer memory).
Params stay in their storage dtype (bf16); updates are computed in f32 and
cast back — the standard memory/quality trade recorded in DESIGN.md.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def apply(params, grads, state: AdamWState, *, lr: float = 3e-4,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1,
          ) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return params, AdamWState(step=step, m=m, v=v)

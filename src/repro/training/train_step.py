"""Training step: causal-LM cross-entropy (+ MoE aux loss) with AdamW.

The logits keep their vocab dim tensor-sharded (with_sharding_constraint) so
the (B, S, 200k-vocab) tensor never materializes replicated; the label
log-prob is extracted with take_along_axis on the sharded dim.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as MD
from repro.training import optimizer as OPT

AUX_LOSS_WEIGHT = 0.01


class TrainState(NamedTuple):
    params: Any
    opt: OPT.AdamWState


def init_train_state(key, cfg: ModelConfig) -> TrainState:
    params = MD.init_params(key, cfg)
    return TrainState(params=params, opt=OPT.init(params))


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            logits_pspec: Optional[P] = None):
    logits, aux = MD.train_logits(params, cfg, batch)
    if logits_pspec is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_pspec)
    logits = logits.astype(jnp.float32)
    targets = batch["tokens"][:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ll = jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    return loss + AUX_LOSS_WEIGHT * aux, (loss, aux)


def train_step(state: TrainState, batch: Dict[str, jnp.ndarray],
               cfg: ModelConfig, lr: float = 3e-4,
               logits_pspec: Optional[P] = None
               ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
    (total, (xent, aux)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(state.params, cfg, batch, logits_pspec)
    params, opt = OPT.apply(state.params, grads, state.opt, lr=lr)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    metrics = {"loss": xent, "aux_loss": aux, "total_loss": total,
               "grad_norm": gnorm}
    return TrainState(params=params, opt=opt), metrics

"""Synthetic LM data pipeline (offline container: no external corpora).

Two generators:
  * ``synthetic_lm``  — structured pseudo-language (Zipfian unigrams +
    copy/induction patterns) so a small model shows a real, monotonically
    decreasing loss curve — used by the end-to-end training example.
  * ``passkey_corpus`` — the paper's needle-in-a-haystack task (§4.3):
    filler text with an embedded "The pass key is NNNNN" needle.

Deterministic, seedable, batched; the iterator yields device-ready dicts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np



@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    induction_prob: float = 0.5   # fraction of sequences with copy patterns


def synthetic_lm(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Zipfian tokens with planted induction (A B ... A -> B) structure."""
    rng = np.random.RandomState(cfg.seed)
    ranks = np.arange(1, cfg.vocab_size + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(cfg.vocab_size, size=(cfg.batch_size, cfg.seq_len),
                          p=probs).astype(np.int32)
        # plant copy patterns: repeat a random span later in the sequence
        for b in range(cfg.batch_size):
            if rng.rand() < cfg.induction_prob and cfg.seq_len >= 16:
                span = rng.randint(4, min(16, cfg.seq_len // 4))
                src = rng.randint(0, cfg.seq_len // 2 - span)
                dst = rng.randint(cfg.seq_len // 2, cfg.seq_len - span)
                toks[b, dst:dst + span] = toks[b, src:src + span]
        yield {"tokens": toks}


# --------------------------------------------------------------------- #
# Passkey retrieval (paper §4.3) over a tiny synthetic token "language".
# Digit tokens occupy ids [2, 11]; filler is sampled above them.
# --------------------------------------------------------------------- #
PAD, BOS = 0, 1
DIGIT0 = 2          # token id of digit '0'
N_DIGITS = 5


def encode_passkey(passkey: int) -> np.ndarray:
    digits = [int(c) for c in f"{passkey:05d}"]
    return np.array([DIGIT0 + d for d in digits], np.int32)


def passkey_prompt(vocab: int, ctx_len: int, passkey: int,
                   seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (prompt tokens (ctx_len,), needle positions (5,)).
    Layout: [BOS, filler..., needle, filler..., query-marker] with the
    needle placed mid-context (paper: 5-digit number in ~1500 filler)."""
    rng = np.random.RandomState(seed)
    filler = rng.randint(DIGIT0 + 10, vocab, size=ctx_len).astype(np.int32)
    prompt = filler.copy()
    prompt[0] = BOS
    needle = encode_passkey(passkey)
    mid = ctx_len // 2
    prompt[mid: mid + N_DIGITS] = needle
    # query marker: repeat the two tokens preceding the needle right at the
    # end, so induction-capable models retrieve the continuation (the needle)
    prompt[-2:] = prompt[mid - 2: mid]
    return prompt, np.arange(mid, mid + N_DIGITS)

"""``ServingConfig`` — the one construction surface for the serving
engines.

PR after PR grew the engine constructors a keyword at a time
(``async_pipeline``, ``chaos``, ``stash_budget_bytes``, ``ladder``,
``quarantine_window``, ``kv_quant``, the rewind knobs, ...) until every
call site — launcher, router, benchmarks, tests — re-spelled a dozen
kwargs and adding a knob meant touching two engine signatures plus
``from_engine``.  ``ServingConfig`` consolidates all of it into one
dataclass that both engines, the ``ReplicaRouter`` build path and
``launch/serve.py`` construct through:

    sv = ServingConfig(max_seq=256, n_lanes=4, max_active_pages=8,
                       kv_quant="int8")
    eng = PagedContinuousEngine(cfg, params, serving=sv)

The old keyword style still works — the engines funnel legacy kwargs
through :func:`resolve_serving_config`, which builds the equivalent
``ServingConfig`` and emits a single ``DeprecationWarning`` per process
(the shim is a migration ramp, not a second API).

Engine-specific fields: a knob only one engine reads is simply ignored
by the other (``offload`` by the paged engine, ``max_active_pages`` by
the contiguous one) — the config describes a *serving deployment*, and
``launch/serve.py --paged`` flips engines under one config without
re-spelling anything.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

from repro.configs.base import FreezeConfig
from repro.serving.faults import ChaosConfig


@dataclasses.dataclass
class ServingConfig:
    """Everything about how a serving engine is deployed, minus the model
    itself (``ModelConfig`` + params stay positional: they describe *what*
    is served, this describes *how*).

    Fields mirror the historical constructor kwargs one-for-one so the
    legacy shim is a plain ``ServingConfig(**kwargs)``; defaults are the
    engines' historical defaults."""
    # ---- lane geometry (required by both engines) ---- #
    max_seq: int = 512
    n_lanes: int = 4
    # ---- freeze machinery ---- #
    freeze_cfg: Optional[FreezeConfig] = None   # None -> cfg.freeze
    enable_freeze: bool = True
    # ---- admission / sampling plumbing ---- #
    pad_id: int = 0
    seed: int = 0
    min_prompt_bucket: int = 8
    # ---- async DMA + robustness ---- #
    async_pipeline: bool = True
    chaos: Optional[ChaosConfig] = None
    stash_budget_bytes: Optional[int] = None
    ladder: Optional[Any] = None                # engine.LadderConfig
    quarantine_window: int = 64
    # ---- recovery rewind budget ---- #
    max_rewinds: int = 4
    rewind_cooldown: int = 32
    # ---- per-page KV quantization ---- #
    kv_quant: str = "none"
    # ---- contiguous-engine-only ---- #
    offload: bool = True
    offload_every: int = 8
    debug_lane_checks: bool = False
    # ---- paged-engine-only ---- #
    max_active_pages: Optional[int] = None      # required for the paged path
    prefill_chunk: int = 64
    speculative_thaw: Optional[bool] = None
    speculative_slots: int = 3
    burst_prefill: bool = True
    debug_invariants: bool = False

    def replace(self, **kw) -> "ServingConfig":
        return dataclasses.replace(self, **kw)


_LEGACY_WARNED = False


def resolve_serving_config(serving: Optional[ServingConfig],
                           kind: str,
                           max_seq: Optional[int],
                           n_lanes: Optional[int],
                           legacy: dict,
                           max_active_pages: Optional[int] = None,
                           ) -> ServingConfig:
    """Normalize an engine constructor call to one ``ServingConfig``.

    ``serving=`` given: the legacy positional/keyword arguments must be
    absent (mixing the two surfaces silently overriding each other is
    exactly the ambiguity the dataclass exists to kill).  ``serving=``
    absent: rebuild the config from the legacy kwargs and warn ONCE per
    process that the keyword surface is deprecated.  Unknown keywords
    raise ``TypeError`` from the dataclass constructor, preserving the
    old signatures' strictness."""
    global _LEGACY_WARNED
    if serving is not None:
        if max_seq is not None or n_lanes is not None \
                or max_active_pages is not None or legacy:
            extra = [k for k, v in (("max_seq", max_seq),
                                    ("n_lanes", n_lanes),
                                    ("max_active_pages", max_active_pages))
                     if v is not None] + sorted(legacy)
            raise TypeError(
                f"pass every serving knob through serving=ServingConfig(...) "
                f"OR through legacy kwargs, not both (got serving= plus "
                f"{extra})")
        sv = serving
    else:
        if max_seq is None or n_lanes is None:
            raise TypeError(
                f"{kind} engine needs max_seq and n_lanes (or a "
                f"serving=ServingConfig(...))")
        if not _LEGACY_WARNED:
            _LEGACY_WARNED = True
            warnings.warn(
                "constructing serving engines from loose kwargs is "
                "deprecated; pass serving=ServingConfig(...) instead "
                "(repro.serving.config)", DeprecationWarning, stacklevel=3)
        try:
            sv = ServingConfig(max_seq=max_seq, n_lanes=n_lanes,
                               max_active_pages=max_active_pages, **legacy)
        except TypeError as e:
            raise TypeError(f"unknown engine kwarg(s): {e}") from None
    if kind == "paged" and sv.max_active_pages is None:
        raise TypeError("the paged engine requires max_active_pages")
    return sv

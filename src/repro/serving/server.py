"""Multi-tenant streaming server front end over the SLO scheduler.

Two layers, mirroring the ``AsyncAphrodite``-wraps-engine split:

``AsyncServingEngine``
    An asyncio facade over one ``Scheduler`` (and through it one engine).
    The scheduler and the engines are synchronous and single-threaded by
    design, so the facade runs a **strict alternation** serve loop: apply
    every pending operation (submits, cancels, pause/release decisions)
    on the event-loop thread, then run exactly one ``Scheduler.step`` in
    the default executor, then pump freshly committed tokens into the
    per-request streams.  Handlers never touch the scheduler directly —
    they append an op and await a future — so no locks exist anywhere:
    the scheduler is only ever touched either by ``_apply_ops``/pumping
    (loop thread, between steps) or by ``step`` (executor thread), never
    both.

    *Streaming* — each request gets a ``RequestStream``: a **bounded**
    ``asyncio.Queue`` of events (``token`` / ``rewind`` / terminal).
    Entropy-triggered Rewalk rewinds shrink a lane's committed prefix
    mid-decode, so the stream protocol has a ``rewind`` event telling the
    consumer to truncate — streamed output is the *committed* sequence,
    byte-identical to the batch path's final result.

    *Backpressure* — a slow consumer fills its queue; the serve loop then
    parks the request through the freeze-native path
    (``Scheduler.pause``: suspend the lane, hold the snapshot *outside*
    the queue) so the lane immediately serves someone else, and releases
    it back the moment the consumer drains below half capacity.  A slow
    client costs a suspend/resume cycle, never a stalled lane.

    *Cancellation* — client disconnects route into ``Scheduler.cancel``
    (freeze-native suspend + drop): the lane frees, exported stash bytes
    release, no scheduler entry is stranded.

``ServingServer``
    A stdlib-only HTTP/1.1 server (``asyncio.start_server`` + hand-rolled
    request parsing — the no-new-deps constraint is a feature: the whole
    protocol surface stays auditable).  ``POST /v1/generate`` streams
    Server-Sent Events; the tenant comes from the ``X-Tenant`` header (or
    the JSON body), and a mid-stream client disconnect — reader EOF or a
    broken write — cancels the request.  ``GET /v1/health`` and
    ``GET /v1/stats`` expose the engine/ladder/tenancy state machines.

Prompts are token-id lists: the repo serves models, not tokenizers, and
the benches replay integer traces.
"""
from __future__ import annotations

import asyncio
import json
from typing import Any, Dict, List, Optional, Union

import numpy as np

from repro.serving.engine import LaneSnapshot, Request, RequestStatus
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler

DEFAULT_STREAM_CAPACITY = 64


class RequestStream:
    """Async iterator over one request's event stream.

    Events are dicts: ``{"event": "token", "index": i, "token": t}``,
    ``{"event": "rewind", "to": n}`` (truncate to the first ``n``
    tokens), and one terminal ``{"event": "done", "status": ...,
    "tokens": [...]}``.  The queue is bounded — not consuming it
    eventually pauses the request (see module docstring), it never
    grows without limit."""

    def __init__(self, uid: int, capacity: int = DEFAULT_STREAM_CAPACITY,
                 wake: Optional[asyncio.Event] = None):
        self.uid = uid
        self.queue: asyncio.Queue = asyncio.Queue(capacity)
        self.capacity = capacity
        self._wake = wake
        self._terminal = False

    def __aiter__(self) -> "RequestStream":
        return self

    async def __anext__(self) -> Dict[str, Any]:
        if self._terminal:
            raise StopAsyncIteration
        ev = await self.queue.get()
        if self._wake is not None:
            # tell the serve loop a slot freed — it may be sleeping idle
            # with this stream's remaining events still un-pumped
            self._wake.set()
        if ev["event"] == "done":
            self._terminal = True
        return ev

    async def collect(self) -> Dict[str, Any]:
        """Drain to the terminal event, replaying token/rewind events into
        a committed-token list; returns the terminal event with the
        replayed ``streamed`` sequence attached (must equal ``tokens`` —
        the streaming-parity invariant)."""
        toks: List[int] = []
        async for ev in self:
            if ev["event"] == "token":
                assert ev["index"] == len(toks), (ev, len(toks))
                toks.append(ev["token"])
            elif ev["event"] == "rewind":
                del toks[ev["to"]:]
            else:
                ev = dict(ev)
                ev["streamed"] = toks
                return ev
        raise RuntimeError("stream ended without a terminal event")


class _StreamState:
    __slots__ = ("stream", "sent", "paused", "want_pause")

    def __init__(self, stream: RequestStream):
        self.stream = stream
        self.sent = 0                 # tokens already delivered
        self.paused: Optional[Union[Request, LaneSnapshot]] = None
        self.want_pause = False


class AsyncServingEngine:
    """Asyncio facade over a ``Scheduler``.  Construct with a ready
    scheduler (tenancy attached there), ``await start()``, then
    ``submit``/``cancel``/``stats`` from any coroutine.  ``await
    close()`` drains nothing — it stops the loop; cancel requests first
    if you need clean terminal events."""

    def __init__(self, sched: Scheduler,
                 stream_capacity: int = DEFAULT_STREAM_CAPACITY):
        self.sched = sched
        self.stream_capacity = stream_capacity
        self.unhandled_exceptions = 0
        self.n_paused = 0
        self.n_resumed = 0
        self._streams: Dict[int, _StreamState] = {}
        self._ops: List[tuple] = []
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._running = False

    # ---------------- public coroutine API ---------------- #
    async def start(self) -> None:
        assert self._task is None, "already started"
        self._wake = asyncio.Event()
        self._running = True
        self._task = asyncio.get_running_loop().create_task(
            self._serve_loop())

    async def close(self) -> None:
        self._running = False
        if self._wake is not None:
            self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None

    def _op(self, kind: str, payload) -> "asyncio.Future":
        fut = asyncio.get_running_loop().create_future()
        self._ops.append((kind, payload, fut))
        self._wake.set()
        return fut

    async def submit(self, prompt, n_tokens: int,
                     sampling: SamplingParams = SamplingParams.greedy(),
                     priority: int = 0,
                     deadline_ms: Optional[float] = None,
                     slo_tokens_per_s: Optional[float] = None,
                     tenant: Optional[str] = None) -> RequestStream:
        """Enqueue a request; resolves once the scheduler accepted it,
        returning the event stream (``stream.uid`` is the request id)."""
        kw = dict(prompt=np.asarray(prompt, np.int32), n_tokens=n_tokens,
                  sampling=sampling, priority=priority,
                  deadline_ms=deadline_ms,
                  slo_tokens_per_s=slo_tokens_per_s, tenant=tenant)
        return await self._op("submit", kw)

    async def cancel(self, uid: int) -> bool:
        """Client went away: cancel ``uid`` (False = already finished)."""
        return await self._op("cancel", uid)

    async def stats(self) -> Dict[str, Any]:
        return await self._op("stats", None)

    # ---------------- serve loop (event-loop thread) ---------------- #
    async def _serve_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            try:
                self._apply_ops()
                self._pump_all()
            except Exception:
                self.unhandled_exceptions += 1
            if not self._running and not self._ops:
                return
            if self.sched.queue or self.sched.busy:
                try:
                    await loop.run_in_executor(None, self.sched.step)
                except Exception:
                    self.unhandled_exceptions += 1
                # yield so handlers queued behind the step get a slice
                await asyncio.sleep(0)
            else:
                # fully idle (streams may still be draining client-side):
                # sleep until an op arrives
                await self._wake.wait()
                self._wake.clear()

    def _apply_ops(self) -> None:
        ops, self._ops = self._ops, []
        for kind, payload, fut in ops:
            try:
                if kind == "submit":
                    uid = self.sched.submit(**payload)
                    stream = RequestStream(uid, self.stream_capacity,
                                           wake=self._wake)
                    self._streams[uid] = _StreamState(stream)
                    fut.set_result(stream)
                elif kind == "cancel":
                    fut.set_result(self._cancel(payload))
                elif kind == "stats":
                    fut.set_result(self._stats())
                else:                      # pragma: no cover
                    raise AssertionError(kind)
            except Exception as e:
                self.unhandled_exceptions += 1
                if not fut.done():
                    fut.set_exception(e)
        self._apply_backpressure()

    def _cancel(self, uid: int) -> bool:
        st = self._streams.get(uid)
        if st is not None and st.paused is not None:
            # the request is parked in OUR hand, not the scheduler's
            # queue: give it back first so cancel finds it
            self.sched.release(st.paused)
            st.paused = None
        ok = self.sched.cancel(uid)
        # terminal event (cancelled or already-done) flows via _pump_all
        return ok

    def _stats(self) -> Dict[str, Any]:
        s = self.sched
        out: Dict[str, Any] = {
            "active_lanes": s.engine.n_active_lanes,
            "queued": len(s.queue),
            "done": len(s.done),
            "streams": len(self._streams),
            "n_preemptions": s.n_preemptions,
            "n_preempt_skipped_cost": s.n_preempt_skipped_cost,
            "n_cancelled": s.n_cancelled,
            "n_paused": self.n_paused,
            "n_resumed": self.n_resumed,
            "unhandled_exceptions": self.unhandled_exceptions,
            "preempt_cost_s": s.preempt_cost_s(),
            "step_s": s._step_s,
        }
        if s.tenancy is not None:
            out["tenants"] = s.tenancy.snapshot()
        return out

    # ---------------- pumping + backpressure ---------------- #
    def _committed(self, uid: int, st: _StreamState) -> List[int]:
        """The uid's committed token list right now, wherever it lives:
        our paused hand, a running lane, or a queued entry (a suspended
        victim's snapshot; plain queued requests have no tokens yet)."""
        if st.paused is not None:
            item = st.paused
            return list(item.generated) \
                if isinstance(item, LaneSnapshot) else []
        for l in self.sched.engine.lanes:
            if l.request is not None and l.request.uid == uid:
                return list(l.generated)
        for e in self.sched.queue:
            item = e[-1]
            req = item.req if isinstance(item, LaneSnapshot) else item
            if req.uid == uid:
                return list(item.generated) \
                    if isinstance(item, LaneSnapshot) else []
        return []                          # e.g. paged over-prefill

    def _emit(self, st: _StreamState, toks: List[int]) -> bool:
        """Push the un-sent suffix of ``toks`` (after any rewind) into the
        stream without blocking; returns False when the queue filled."""
        q = st.stream.queue
        if len(toks) < st.sent:
            try:
                q.put_nowait({"event": "rewind", "to": len(toks)})
            except asyncio.QueueFull:
                return False
            st.sent = len(toks)
        while st.sent < len(toks):
            try:
                q.put_nowait({"event": "token", "index": st.sent,
                              "token": int(toks[st.sent])})
            except asyncio.QueueFull:
                return False
            st.sent += 1
        return True

    def _pump_all(self) -> None:
        for uid, st in list(self._streams.items()):
            req = self.sched.done.get(uid)
            if req is not None:
                final = [] if req.result is None \
                    else [int(t) for t in req.result]
                if self._emit(st, final) and not st.stream.queue.full():
                    st.stream.queue.put_nowait({
                        "event": "done", "status": str(req.status),
                        "tokens": final})
                    del self._streams[uid]
                continue
            if not self._emit(st, self._committed(uid, st)) \
                    and st.paused is None:
                st.want_pause = True       # consumer is behind: park it

    def _apply_backpressure(self) -> None:
        for uid, st in self._streams.items():
            if st.want_pause and st.paused is None:
                st.want_pause = False
                item = self.sched.pause(uid)
                if item is not None:
                    st.paused = item
                    self.n_paused += 1
            elif st.paused is not None and \
                    st.stream.queue.qsize() <= st.stream.capacity // 2:
                # consumer drained: hand the snapshot back to the queue
                self.sched.release(st.paused)
                st.paused = None
                self.n_resumed += 1
                self._wake.set()


# ===================== HTTP front end ===================== #

_JSON = {"Content-Type": "application/json"}
_SSE = {"Content-Type": "text/event-stream", "Cache-Control": "no-cache"}


def _sse(event: str, data: Dict[str, Any]) -> bytes:
    return (f"event: {event}\ndata: {json.dumps(data)}\n\n").encode()


class ServingServer:
    """stdlib HTTP/1.1 + SSE front end over an ``AsyncServingEngine``.

    Endpoints::

        POST /v1/generate   {"prompt": [ints], "n_tokens": n, ...}
                            -> text/event-stream of token/rewind/done
        GET  /v1/health     -> engine health + robustness snapshot
        GET  /v1/stats      -> scheduler/tenancy/server counters

    Tenant identity: ``X-Tenant`` header, else ``"tenant"`` in the JSON
    body, else untenanted.  Sampling: ``{"greedy": true}`` (default) or
    ``temperature``/``top_k``/``top_p``.  A client that disconnects
    mid-stream cancels its request (freeze-native suspend + drop)."""

    def __init__(self, engine: AsyncServingEngine,
                 host: str = "127.0.0.1", port: int = 8777):
        self.engine = engine
        self.host, self.port = host, port
        self._srv: Optional[asyncio.base_events.Server] = None

    async def start(self) -> None:
        await self.engine.start()
        self._srv = await asyncio.start_server(self._handle, self.host,
                                               self.port)
        # port=0 support: report the bound port back
        self.port = self._srv.sockets[0].getsockname()[1]

    async def close(self) -> None:
        if self._srv is not None:
            self._srv.close()
            await self._srv.wait_closed()
            self._srv = None
        await self.engine.close()

    # ---------------- request plumbing ---------------- #
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            line = await reader.readline()
            if not line:
                return
            try:
                method, path, _ = line.decode("latin-1").split(None, 2)
            except ValueError:
                await self._respond(writer, 400, {"error": "bad request"})
                return
            headers: Dict[str, str] = {}
            while True:
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                k, _, v = h.decode("latin-1").partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            n = int(headers.get("content-length", 0) or 0)
            if n:
                body = await reader.readexactly(n)
            await self._route(method, path, headers, body, reader, writer)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        except Exception:
            self.engine.unhandled_exceptions += 1
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _respond(self, writer, code: int, obj: Dict[str, Any],
                       ) -> None:
        body = json.dumps(obj).encode()
        writer.write(
            f"HTTP/1.1 {code} {'OK' if code == 200 else 'ERR'}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n"
            .encode() + body)
        await writer.drain()

    async def _route(self, method, path, headers, body, reader, writer):
        if method == "GET" and path == "/v1/health":
            eng = self.engine.sched.engine
            await self._respond(writer, 200, _jsonable(eng.health()))
            return
        if method == "GET" and path == "/v1/stats":
            await self._respond(writer, 200,
                                _jsonable(await self.engine.stats()))
            return
        if method == "POST" and path == "/v1/generate":
            await self._generate(headers, body, reader, writer)
            return
        await self._respond(writer, 404, {"error": f"no route {path}"})

    async def _generate(self, headers, body, reader, writer) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
            prompt = np.asarray(spec["prompt"], np.int32)
            n_tokens = int(spec["n_tokens"])
        except (KeyError, ValueError, TypeError) as e:
            await self._respond(writer, 400, {"error": f"bad spec: {e}"})
            return
        if spec.get("greedy", True):
            sampling = SamplingParams.greedy()
        else:
            sampling = SamplingParams(
                temperature=float(spec.get("temperature", 0.7)),
                top_k=int(spec.get("top_k", 40)),
                top_p=float(spec.get("top_p", 0.9)))
        tenant = headers.get("x-tenant") or spec.get("tenant")
        stream = await self.engine.submit(
            prompt, n_tokens, sampling=sampling,
            priority=int(spec.get("priority", 0)),
            deadline_ms=spec.get("deadline_ms"),
            slo_tokens_per_s=spec.get("slo_tokens_per_s"),
            tenant=tenant)
        writer.write(b"HTTP/1.1 200 OK\r\n" + b"".join(
            f"{k}: {v}\r\n".encode() for k, v in _SSE.items())
            + b"Connection: close\r\n\r\n")
        # disconnect watcher: with the body consumed, any further read
        # returns EOF exactly when the client goes away
        eof = asyncio.get_running_loop().create_task(reader.read())
        try:
            async for ev in stream:
                writer.write(_sse(ev.pop("event"), ev))
                await writer.drain()
                if eof.done():
                    raise ConnectionResetError("client disconnected")
        except (ConnectionError, asyncio.IncompleteReadError):
            await self.engine.cancel(stream.uid)
        finally:
            eof.cancel()


def _jsonable(obj):
    """Best-effort JSON coercion for health/stats payloads (numpy
    scalars, enums, nested dicts)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, RequestStatus):
        return obj.value
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj

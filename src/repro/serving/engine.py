"""ASR-KF-EGR serving engines.

Two generation drivers share the jitted prefill / decode-step cores:

* ``Engine`` — static one-shot batched generation: every lane starts
  together and runs for the same number of steps (benchmark arms, examples,
  the paper's Table 1 protocol).

* ``ContinuousEngine`` — the production path: a jitted per-step core with
  **per-lane** ``pos`` / ``step`` vectors plus a host-side lane manager.
  Lanes admit a new request the moment their current one retires —
  mid-generation, without draining the batch — via a per-lane
  prefill-into-slot (``model.write_lane_state``).  Admission overwrites the
  lane's KV / freeze / recovery state wholesale, so no freeze counters or
  entropy baselines leak between requests sharing a lane.

Host-side responsibilities beyond the jitted step (both drivers):
  * page-batched host offload of fully-frozen KV pages (the paper's
    "frozen storage F" — cache.HostOffloadController, bookkeeping keyed
    per (layer, lane, page) so lane reuse can drop exactly its own pages)
  * Rewalk Regeneration (recovery level 4): rewind ``rewalk_tokens``,
    clear freeze state (FR already applied in-step), re-decode — history,
    rewind budget and cooldown are tracked per lane
  * telemetry: active/frozen KV trajectory (paper Fig. 1), compression
    ratio (Table 1), entropy/recovery events — one append per lane-step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FreezeConfig, ModelConfig
from repro.core.cache import HostOffloadController, KVCache
from repro.models import model as MD
from repro.serving.sampling import (SamplingParams, params_arrays, sample,
                                    sample_batched)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray                 # (B, n_generated)
    # per-step telemetry (paper Fig. 1 / Table 1)
    active_kv: List[float]             # mean active slots per layer/seq
    frozen_kv: List[float]
    total_kv: List[int]
    entropy: List[float]
    recovery_events: List[Dict[str, Any]]
    offloaded_tokens: List[int]
    rewinds: int = 0

    @property
    def compression(self) -> float:
        """Paper Table 1: 1 - active/total at the final step."""
        if not self.active_kv:
            return 0.0
        return 1.0 - self.active_kv[-1] / max(self.total_kv[-1], 1)


@dataclasses.dataclass
class Request:
    """One generation request, as seen by the scheduler and lane manager."""
    uid: int
    prompt: np.ndarray            # (S,) int32
    n_tokens: int
    sampling: SamplingParams = SamplingParams()
    result: Optional[np.ndarray] = None
    telemetry: Optional[GenerationResult] = None


class Engine:
    """Static batched generation with ASR-KF-EGR freeze management."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int,
                 freeze_cfg: Optional[FreezeConfig] = None,
                 enable_freeze: bool = True,
                 offload: bool = True,
                 max_rewinds: int = 4,
                 rewind_cooldown: int = 32):
        self.max_rewinds = max_rewinds
        self.rewind_cooldown = rewind_cooldown
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.fcfg = freeze_cfg or cfg.freeze
        self.enable_freeze = enable_freeze
        self.offload = offload and enable_freeze
        self._prefill = jax.jit(
            functools.partial(MD.prefill, cfg=cfg))
        self._step = jax.jit(functools.partial(
            MD.decode_step, cfg=cfg, freeze_cfg=self.fcfg,
            enable_freeze=enable_freeze))

    def generate(self, batch: Dict[str, jnp.ndarray], n_tokens: int,
                 sampling: SamplingParams = SamplingParams(),
                 seed: int = 0) -> GenerationResult:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S0 = tokens.shape
        assert S0 + n_tokens <= self.max_seq
        state = MD.init_decode_state(cfg, B, self.max_seq)
        logits, state = self._prefill(self.params, batch=batch, state=state)
        key = jax.random.PRNGKey(seed)
        res = GenerationResult([], [], [], [], [], [], [])
        offloader = HostOffloadController(self.fcfg.page_size) \
            if self.offload else None

        out_tokens = []
        history: List[jnp.ndarray] = []   # (token, pos) for rewind
        pos, step = S0, 0
        last_rewind_step = -10**9
        key, sub = jax.random.split(key)
        tok = sample(logits, sub, sampling)
        out_tokens.append(np.asarray(tok))
        while len(out_tokens) < n_tokens:
            logits, state, info = self._step(
                self.params, token=tok, pos=jnp.int32(pos),
                step=jnp.int32(step), state=state)
            # ---- telemetry (every list appends exactly once per step) ----
            n_layers_attn = max(state.freeze.frozen.shape[0], 1) \
                if hasattr(state, "freeze") else 1
            if "n_active" in info:
                denom = n_layers_attn * B
                res.active_kv.append(float(jnp.sum(info["n_active"])) / denom)
                res.frozen_kv.append(float(jnp.sum(info["n_frozen"])) / denom)
            else:
                res.active_kv.append(float(pos + 1))
                res.frozen_kv.append(0.0)
            res.total_kv.append(pos + 1)
            if "entropy" in info:
                res.entropy.append(float(jnp.mean(info["entropy"])))
                if bool(jnp.any(info["spike"])):
                    res.recovery_events.append({
                        "step": step,
                        "level": int(jnp.max(info["level"])),
                        "entropy": float(jnp.max(info["entropy"])),
                    })
            # ---- Rewalk Regeneration (recovery level 4) ----
            if "rr_request" in info and bool(jnp.any(info["rr_request"])) \
                    and len(history) >= self.fcfg.rewalk_tokens \
                    and res.rewinds < self.max_rewinds \
                    and step - last_rewind_step >= self.rewind_cooldown:
                nback = self.fcfg.rewalk_tokens
                del history[-nback:]
                del out_tokens[-nback:]
                pos -= nback
                res.rewinds += 1
                last_rewind_step = step
                tok = history[-1][0] if history else tok
                step += 1
                res.offloaded_tokens.append(
                    offloader.offloaded_tokens if offloader else 0)
                continue
            # ---- host offload of fully-frozen pages ----
            if offloader is not None and step % 8 == 7:
                cache = KVCache(k=state.cache_k, v=state.cache_v)
                cache = offloader.sync(cache, np.asarray(state.freeze.frozen))
                state = state._replace(cache_k=cache.k, cache_v=cache.v)
            res.offloaded_tokens.append(
                offloader.offloaded_tokens if offloader else 0)

            key, sub = jax.random.split(key)
            tok = sample(logits, sub, sampling)
            history.append((tok, pos))
            out_tokens.append(np.asarray(tok))
            pos += 1
            step += 1
        res.tokens = np.stack(out_tokens, axis=1)
        return res


# ===================================================================== #
# Continuous batching
# ===================================================================== #
@dataclasses.dataclass
class _Lane:
    """Host-side bookkeeping for one batch slot of the jitted step."""
    request: Optional[Request] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    history: List[Tuple[int, int]] = \
        dataclasses.field(default_factory=list)      # (token, pos) for rewind
    rewinds: int = 0
    last_rewind_step: int = -10**9


class ContinuousEngine:
    """Continuous-batching generation: per-lane admission and retirement.

    The jitted step always runs the full ``n_lanes``-wide batch (fixed
    shapes, one compile); idle lanes decode garbage that the host ignores.
    Prompt lengths are padded to power-of-two buckets so the per-lane
    prefill compiles O(log max_seq) times, not once per prompt length.
    """

    def __init__(self, cfg: ModelConfig, params, max_seq: int, n_lanes: int,
                 freeze_cfg: Optional[FreezeConfig] = None,
                 enable_freeze: bool = True,
                 offload: bool = True,
                 max_rewinds: int = 4,
                 rewind_cooldown: int = 32,
                 pad_id: int = 0,
                 offload_every: int = 8,
                 seed: int = 0,
                 min_prompt_bucket: int = 8,
                 debug_lane_checks: bool = False):
        assert not cfg.is_encoder_decoder, \
            "continuous batching is decoder-only (enc-dec uses Engine)"
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.n_lanes = n_lanes
        self.fcfg = freeze_cfg or cfg.freeze
        self.enable_freeze = enable_freeze
        self.max_rewinds = max_rewinds
        self.rewind_cooldown = rewind_cooldown
        self.pad_id = pad_id
        self.offload_every = offload_every
        self.min_prompt_bucket = min_prompt_bucket
        self.debug_lane_checks = debug_lane_checks
        self._prefill = jax.jit(functools.partial(MD.prefill, cfg=cfg))
        self._step = jax.jit(functools.partial(
            MD.decode_step, cfg=cfg, freeze_cfg=self.fcfg,
            enable_freeze=enable_freeze))
        self._write_lane = jax.jit(functools.partial(MD.write_lane_state, cfg))
        self._sample = jax.jit(sample_batched)
        self.state = MD.init_decode_state(cfg, n_lanes, max_seq)
        self.lanes = [_Lane() for _ in range(n_lanes)]
        self.pos = np.zeros(n_lanes, np.int32)
        self.step = np.zeros(n_lanes, np.int32)
        self.tok = np.full(n_lanes, pad_id, np.int32)
        greedy = SamplingParams.greedy()
        self._temp, self._topk, self._topp = (
            np.array(a) for a in params_arrays([greedy] * n_lanes))
        self._lane_params_dev = None     # device mirror, refreshed on admit
        self.key = jax.random.PRNGKey(seed)
        self.offloader = HostOffloadController(self.fcfg.page_size) \
            if (offload and enable_freeze) else None
        self.wall_step = 0          # number of jitted decode steps issued
        self.events: List[Dict[str, Any]] = []   # admit / finish log

    @classmethod
    def from_engine(cls, engine: Engine, n_lanes: int,
                    **kw) -> "ContinuousEngine":
        """Build a continuous engine sharing a static Engine's model and
        freeze settings (the Scheduler's compatibility path)."""
        return cls(engine.cfg, engine.params, engine.max_seq, n_lanes,
                   freeze_cfg=engine.fcfg,
                   enable_freeze=engine.enable_freeze,
                   offload=engine.offload,
                   max_rewinds=engine.max_rewinds,
                   rewind_cooldown=engine.rewind_cooldown, **kw)

    # ---------------- lane accounting ---------------- #
    @property
    def n_active_lanes(self) -> int:
        return sum(1 for l in self.lanes if l.request is not None)

    @property
    def has_free_lane(self) -> bool:
        return any(l.request is None for l in self.lanes)

    def _free_lane(self) -> int:
        for i, l in enumerate(self.lanes):
            if l.request is None:
                return i
        raise RuntimeError("no free lane")

    def _bucket(self, prompt_len: int, n_tokens: int) -> int:
        """Pad the prompt to a power-of-two bucket (bounded prefill
        recompiles), falling back to the exact length when the bucket
        would not leave room for generation."""
        b = self.min_prompt_bucket
        while b < prompt_len:
            b *= 2
        if b + n_tokens > self.max_seq:
            b = prompt_len
        if b + n_tokens > self.max_seq:
            raise ValueError(
                f"request needs {prompt_len} prompt + {n_tokens} generated "
                f"slots but the engine was built with max_seq={self.max_seq}")
        return b

    # ---------------- admission ---------------- #
    def admit(self, req: Request, lane: Optional[int] = None) -> int:
        """Prefill `req` into a free lane mid-stream.  The single-lane
        prefill state is scattered over the lane's slice of the batched
        decode state, which wholesale-resets its KV cache, freeze masks and
        recovery ladder; host-side page-offload bookkeeping for the lane's
        previous occupant is dropped."""
        if lane is None:
            lane = self._free_lane()
        l = self.lanes[lane]
        assert l.request is None, f"lane {lane} is busy"
        prompt = np.asarray(req.prompt, np.int32)
        sp = self._bucket(len(prompt), req.n_tokens)
        toks = np.full((1, sp), self.pad_id, np.int32)
        toks[0, sp - len(prompt):] = prompt           # left-pad, as in prefill
        event = {"event": "admit", "uid": req.uid, "lane": lane,
                 "wall_step": self.wall_step}
        if self.debug_lane_checks:
            event["frozen_before"] = int(
                np.asarray(self.state.freeze.frozen[:, lane]).sum())
            event["recovery_steps_before"] = int(
                np.asarray(self.state.recovery.steps_seen)[lane])
        lane_state = MD.init_decode_state(self.cfg, 1, self.max_seq)
        logits, lane_state = self._prefill(
            self.params, batch={"tokens": jnp.asarray(toks)}, state=lane_state)
        self.state = self._write_lane(self.state, lane_state, jnp.int32(lane))
        if self.offloader is not None:
            self.offloader.drop_lane(lane)
        if self.debug_lane_checks:
            event["frozen_after"] = int(
                np.asarray(self.state.freeze.frozen[:, lane]).sum())
            event["recovery_steps_after"] = int(
                np.asarray(self.state.recovery.steps_seen)[lane])
        self.pos[lane] = sp
        self.step[lane] = 0
        self.key, sub = jax.random.split(self.key)
        first = int(np.asarray(sample(logits, sub, req.sampling))[0])
        self.tok[lane] = first
        self._temp[lane] = req.sampling.temperature
        self._topk[lane] = req.sampling.top_k
        self._topp[lane] = req.sampling.top_p
        self._lane_params_dev = None
        l.request = req
        l.generated = [first]
        l.history = []
        l.rewinds = 0
        l.last_rewind_step = -10**9
        req.telemetry = GenerationResult([], [], [], [], [], [], [])
        self.events.append(event)
        return lane

    # ---------------- stepping ---------------- #
    def step_once(self) -> List[Request]:
        """Run one jitted decode step over all lanes; returns the requests
        that retired this step (their lanes are immediately free)."""
        active = [i for i, l in enumerate(self.lanes) if l.request is not None]
        if not active:
            return []
        logits, self.state, info = self._step(
            self.params, token=jnp.asarray(self.tok),
            pos=jnp.asarray(self.pos), step=jnp.asarray(self.step),
            state=self.state)
        self.wall_step += 1
        # enqueue per-lane sampling right behind the step, then pull it and
        # the telemetry in ONE device->host transfer (rewound lanes simply
        # discard their draw)
        self.key, sub = jax.random.split(self.key)
        if self._lane_params_dev is None:
            self._lane_params_dev = (jnp.asarray(self._temp),
                                     jnp.asarray(self._topk),
                                     jnp.asarray(self._topp))
        keys = ("n_active", "n_frozen", "entropy", "spike", "level",
                "rr_request")
        host = jax.device_get(dict(
            {k: info[k] for k in keys if k in info},
            toks=self._sample(logits, sub, *self._lane_params_dev)))
        get = host.get
        n_active, n_frozen = get("n_active"), get("n_frozen")
        entropy, spike, level = get("entropy"), get("spike"), get("level")
        rr = get("rr_request")
        toks = host["toks"]
        n_layers_attn = max(self.state.freeze.frozen.shape[0], 1)

        # ---- per-lane telemetry: one append per lane-step ----
        for i in active:
            res = self.lanes[i].request.telemetry
            if n_active is not None:
                res.active_kv.append(float(n_active[i]) / n_layers_attn)
                res.frozen_kv.append(float(n_frozen[i]) / n_layers_attn)
            else:
                res.active_kv.append(float(self.pos[i] + 1))
                res.frozen_kv.append(0.0)
            res.total_kv.append(int(self.pos[i]) + 1)
            if entropy is not None:
                res.entropy.append(float(entropy[i]))
                if spike is not None and bool(spike[i]):
                    res.recovery_events.append({
                        "step": int(self.step[i]),
                        "level": int(level[i]),
                        "entropy": float(entropy[i]),
                    })

        # ---- per-lane Rewalk Regeneration ----
        rewound = set()
        if rr is not None:
            for i in active:
                l = self.lanes[i]
                if bool(rr[i]) and len(l.history) >= self.fcfg.rewalk_tokens \
                        and l.rewinds < self.max_rewinds \
                        and int(self.step[i]) - l.last_rewind_step \
                            >= self.rewind_cooldown:
                    nback = self.fcfg.rewalk_tokens
                    del l.history[-nback:]
                    del l.generated[-nback:]
                    self.pos[i] -= nback
                    l.rewinds += 1
                    l.last_rewind_step = int(self.step[i])
                    l.request.telemetry.rewinds += 1
                    if l.history:
                        self.tok[i] = l.history[-1][0]
                    self.step[i] += 1
                    rewound.add(i)

        # ---- page-batched host offload ----
        if self.offloader is not None \
                and self.wall_step % self.offload_every == 0:
            frozen = np.asarray(self.state.freeze.frozen)
            idle = [i for i, l in enumerate(self.lanes) if l.request is None]
            if idle:   # idle lanes decode garbage; never offload it
                frozen = frozen.copy()
                frozen[:, idle, :] = False
            cache = KVCache(k=self.state.cache_k, v=self.state.cache_v)
            cache = self.offloader.sync(cache, frozen)
            self.state = self.state._replace(cache_k=cache.k, cache_v=cache.v)
        for i in active:
            self.lanes[i].request.telemetry.offloaded_tokens.append(
                self.offloader.offloaded_tokens_lane(i)
                if self.offloader else 0)

        # ---- commit sampled tokens, retire finished lanes ----
        finished = []
        for i in active:
            if i in rewound:
                continue
            l = self.lanes[i]
            t = int(toks[i])
            l.history.append((t, int(self.pos[i])))
            l.generated.append(t)
            self.tok[i] = t
            self.pos[i] += 1
            self.step[i] += 1
            if len(l.generated) >= l.request.n_tokens:
                finished.append(self._retire(i))
        return finished

    def _retire(self, lane: int) -> Request:
        l = self.lanes[lane]
        req = l.request
        req.result = np.asarray(l.generated[: req.n_tokens], np.int32)
        req.telemetry.tokens = req.result[None, :]
        self.events.append({"event": "finish", "uid": req.uid, "lane": lane,
                            "wall_step": self.wall_step})
        l.request = None
        l.generated = []
        l.history = []
        # park the idle lane: greedy sampling, position clamped in-bounds,
        # and the retired request's offloaded pages released right away
        # (offload sync also masks idle lanes, so no churn until re-admit)
        self._temp[lane] = 0.0
        self._lane_params_dev = None
        self.pos[lane] = min(int(self.pos[lane]), self.max_seq - 1)
        if self.offloader is not None:
            self.offloader.drop_lane(lane)
        return req

"""ASR-KF-EGR serving engines.

Three generation drivers share the jitted prefill / decode-step cores:

* ``Engine`` — static one-shot batched generation: every lane starts
  together and runs for the same number of steps (benchmark arms, examples,
  the paper's Table 1 protocol).

* ``ContinuousEngine`` — continuous batching over a dense per-lane cache:
  a jitted per-step core with **per-lane** ``pos`` / ``step`` vectors plus
  a host-side lane manager.  Lanes admit a new request the moment their
  current one retires — mid-generation, without draining the batch — via a
  per-lane prefill-into-slot (``model.write_lane_state``).  Admission
  overwrites the lane's KV / freeze / recovery state wholesale, so no
  freeze counters or entropy baselines leak between requests sharing a
  lane.

* ``PagedContinuousEngine`` — the bounded-HBM production path: decode
  attends only each lane's O(P * page) device page pool, long prompts
  prefill in chunks interleaved with resident decode, frozen/overflow
  pages live in the host store, and entropy-guided recovery runs
  page-granular (stashed-page thaws + page-aware rewinds).

Host-side responsibilities beyond the jitted step (all drivers):
  * host residency of fully-frozen KV (the paper's "frozen storage F"):
    page-batched offload on the dense paths (cache.HostOffloadController)
    and per-page swap/stash/thaw on the paged path
    (core.paging.PagedController) — bookkeeping keyed per (layer, lane,
    page) so lane reuse can drop exactly its own pages
  * Rewalk Regeneration (recovery level 4): rewind ``rewalk_tokens``,
    clear freeze state (FR already applied in-step), re-decode — history,
    rewind budget and cooldown are tracked per lane; the paged path also
    invalidates the rewound KV slots / pages on device
  * telemetry: active/frozen KV trajectory (paper Fig. 1), compression
    ratio (Table 1), entropy/recovery events — one append per lane-step

**Async DMA pipeline** (both continuous engines, ``async_pipeline=True``):
the per-step device->host fetch (sampled tokens + telemetry + recovery
requests) is pushed into a double-buffered ring (``serving.dma.FetchRing``)
right behind the jitted step and *consumed at the start of the next engine
call* — the D2H copy overlaps the device compute and the host's
post-dispatch work instead of stalling right after dispatch.  Host
controller decisions (token commits, telemetry, thaw requests, rewinds,
retirement, offload) therefore run one step behind the device — the same
sliding-window slack the paper's schedule already tolerates — but in
exactly the order the synchronous path applies them, so the two modes
make identical host decisions (``async_pipeline=False`` runs the same
code with a depth-0 ring: push immediately followed by a blocking pop).
Output tokens are bit-identical whenever the prefill chunk split is
deterministic (``burst_prefill=False``): the modes admit on different
wall calls, and the load-adaptive burst split would change
flash-attention summation order — float rounding, not decisions.  The
paged engine additionally batches each boundary tick's pool slices into
ONE device_get / device_put pair across all boundary lanes and layers
(reused host staging buffers), pushes K/V back only when the tick actually
wrote some (metadata-only push otherwise), and speculatively uploads the
top-priority stashed pages into per-lane device *staging slots* so an
entropy-driven thaw becomes a page-table remap instead of a blocking
upload (``core.paging.PagedController.stage_slots`` / ``staged_keys``).
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FreezeConfig, ModelConfig
from repro.core import quant
from repro.serving.config import ServingConfig, resolve_serving_config
from repro.core.cache import HostOffloadController, KVCache
from repro.core.paging import PagedController, PageFreezeState
from repro.core.recovery import RecoveryState
from repro.models import model as MD
from repro.serving.dma import FetchRing, HostStaging, TransferStats
from repro.serving.faults import ChaosConfig, Endpoint
from repro.serving.sampling import (SamplingParams, lane_base_key,
                                    params_arrays, sample,
                                    sample_batched_perlane)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray                 # (B, n_generated)
    # per-step telemetry (paper Fig. 1 / Table 1)
    active_kv: List[float]             # mean active slots per layer/seq
    frozen_kv: List[float]
    total_kv: List[int]
    entropy: List[float]
    recovery_events: List[Dict[str, Any]]
    offloaded_tokens: List[int]
    rewinds: int = 0

    @property
    def compression(self) -> float:
        """Paper Table 1: 1 - active/total at the final step."""
        if not self.active_kv:
            return 0.0
        return 1.0 - self.active_kv[-1] / max(self.total_kv[-1], 1)


class RequestStatus(str, enum.Enum):
    """Request lifecycle status — ONE enum shared by the scheduler, both
    engines, the replica router and the HTTP server (it replaced the
    ad-hoc per-module status strings).

    A ``str`` subclass on purpose: every value equals its historical
    string (``RequestStatus.COMPLETED == "completed"``), so status
    comparisons in older call sites, JSON reports and sorted tallies are
    unchanged.  Lifecycle: requests are ``PENDING`` in flight (``SHED``
    while parked by the degradation ladder's load-shed rung); retirement
    resolves to ``COMPLETED``, ``SHED_RESUMED`` (completed after at least
    one shed/resume round trip) or ``QUARANTINED`` (retired early — the
    lane re-poisoned; the partial result is whatever survived the anomaly
    rewinds).  ``CANCELLED`` is terminal for a client-disconnected
    request whose lane was suspended and dropped."""
    PENDING = "pending"
    SHED = "shed"
    COMPLETED = "completed"
    SHED_RESUMED = "shed-resumed"
    QUARANTINED = "quarantined"
    CANCELLED = "cancelled"

    def __str__(self) -> str:       # "completed", never the member repr
        return self.value

    @property
    def terminal(self) -> bool:
        return self not in (RequestStatus.PENDING, RequestStatus.SHED)


@dataclasses.dataclass
class Request:
    """One generation request, as seen by the scheduler and lane manager.

    ``priority`` is a strict class (0 = most important; the scheduler never
    runs a class while a higher one is runnable and may *preempt* running
    lanes for it).  ``deadline_ms`` (relative to submission) and
    ``slo_tokens_per_s`` (a decode-rate SLO the scheduler converts into a
    completion deadline) order requests within a class — earliest deadline
    first.  All three default to "no SLO", under which the scheduler
    degrades to plain FIFO.  ``tenant`` tags the request for the
    tenancy layer's quota/fair-share accounting (None = untenanted,
    exempt from quotas)."""
    uid: int
    prompt: np.ndarray            # (S,) int32
    n_tokens: int
    sampling: SamplingParams = SamplingParams()
    priority: int = 0
    deadline_ms: Optional[float] = None
    slo_tokens_per_s: Optional[float] = None
    result: Optional[np.ndarray] = None
    telemetry: Optional[GenerationResult] = None
    status: RequestStatus = RequestStatus.PENDING
    tenant: Optional[str] = None


@dataclasses.dataclass
class LadderConfig:
    """Graceful-degradation ladder thresholds, as fractions of the
    host-stash budget (``stash_bytes / stash_budget_bytes``).  Each rung
    engages independently whenever pressure reaches ITS threshold — so a
    run can disable one rung by raising its threshold out of reach
    (e.g. ``deepen_timers=2.0`` for parity-critical serving) while the
    rungs around it keep working.  The defaults are ordered from
    parity-preserving to lossy:

    1. **deny prefetch** — stop speculative thaw staging and free the
       redundant host copies of device-resident pages (paged path) /
       stop offloading newly frozen pages (contiguous path).  Pure
       optimization rollback: token streams are unchanged.
    2. **deepen timers** — offloaded freeze timers decrement every other
       boundary tick, so stashed pages come home ~2x slower.  Changes
       page-visibility timing, so NOT token-parity-preserving; runs that
       must keep parity set this threshold above ``shed``.
    3. **throttle admissions** — the scheduler stops admitting/resuming
       work until pressure clears (queued requests are delayed, their
       tokens unchanged).
    4. **shed** — the scheduler suspends the lowest-priority running
       lane through the freeze-native ``suspend_lane`` snapshot path;
       the work resumes token-identically when pressure clears.
    """
    deny_prefetch: float = 0.60
    deepen_timers: float = 0.75
    throttle_admissions: float = 0.85
    shed: float = 0.95

    def stage(self, pressure: float) -> int:
        """Highest engaged rung (0 = nominal .. 4 = shed) — reporting
        only; rung decisions compare against their own thresholds."""
        if pressure >= self.shed:
            return 4
        if pressure >= self.throttle_admissions:
            return 3
        if pressure >= self.deepen_timers:
            return 2
        if pressure >= self.deny_prefetch:
            return 1
        return 0


@dataclasses.dataclass
class LaneSnapshot:
    """Resumable mid-generation state of a preempted lane.

    Produced by ``suspend_lane`` and consumed by ``resume_lane`` (possibly
    on a *different* lane slot).  The host-side fields (tokens, clocks,
    rewind budget, the snapshot-stable sampling base key) are common to
    both engines; the paged engine additionally carries the lane's entire
    pool slice + freeze state + recovery-ladder scalars and owns the
    lane's host-stashed pages, so resume restores a byte-identical device
    layout and the continuation is token-identical to the uninterrupted
    run.  The contiguous engine carries no KV (a dense lane slice is the
    whole ``max_seq`` cache) — it resumes by re-prefilling prompt +
    generated tokens, an approximate (freeze state restarts) but cheap
    fallback.

    A snapshot with ``generated == []`` marks an admission that was
    cancelled before its first token (e.g. mid-chunked-prefill): resume is
    a plain re-admit."""
    req: Request
    generated: List[int]
    history: List[Tuple[int, int]]
    pos: int
    step: int                      # decode clock (sampling folds it in)
    tok: int                       # next step's input token
    rewinds: int
    last_rewind_step: int
    lane_key: Optional[np.ndarray] = None    # (2,) uint32 sampling base
    # ---- paged-path payload (None on the contiguous fallback) ---- #
    pool: Optional[Dict[str, np.ndarray]] = None     # (L, 1, P_total, ...)
    fstate: Optional[Dict[str, np.ndarray]] = None
    recovery: Optional[Dict[str, Any]] = None        # ladder scalars
    tail_slot: Optional[np.ndarray] = None           # (L,) int32
    stashed: Optional[Dict[Tuple[int, int], Any]] = None  # host-store pages
    pending_thaw: bool = False
    urgency: float = 0.0
    # False for checkpoint snapshots (``checkpoint_lane``): the stashed
    # pages are shared copies still owned by the live controller, so no
    # ``exported_bytes`` accounting moved and none must move back on
    # resume/discard
    exported: bool = True

    @property
    def started(self) -> bool:
        """Whether any decode progress exists (False = resume re-admits)."""
        return bool(self.generated)


class Engine:
    """Static batched generation with ASR-KF-EGR freeze management."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int,
                 freeze_cfg: Optional[FreezeConfig] = None,
                 enable_freeze: bool = True,
                 offload: bool = True,
                 max_rewinds: int = 4,
                 rewind_cooldown: int = 32):
        self.max_rewinds = max_rewinds
        self.rewind_cooldown = rewind_cooldown
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.fcfg = freeze_cfg or cfg.freeze
        self.enable_freeze = enable_freeze
        self.offload = offload and enable_freeze
        # donate the decode state: KV / freeze buffers are updated in place
        # instead of double-buffered in HBM (on backends without donation
        # support, e.g. CPU, JAX falls back to copies with a warning)
        self._prefill = jax.jit(
            functools.partial(MD.prefill, cfg=cfg),
            donate_argnames=("state",))
        self._step = jax.jit(functools.partial(
            MD.decode_step, cfg=cfg, freeze_cfg=self.fcfg,
            enable_freeze=enable_freeze), donate_argnames=("state",))

    def generate(self, batch: Dict[str, jnp.ndarray], n_tokens: int,
                 sampling: SamplingParams = SamplingParams(),
                 seed: int = 0) -> GenerationResult:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S0 = tokens.shape
        assert S0 + n_tokens <= self.max_seq
        state = MD.init_decode_state(cfg, B, self.max_seq)
        logits, state = self._prefill(self.params, batch=batch, state=state)
        key = jax.random.PRNGKey(seed)
        res = GenerationResult([], [], [], [], [], [], [])
        offloader = HostOffloadController(self.fcfg.page_size) \
            if self.offload else None

        out_tokens = []
        history: List[jnp.ndarray] = []   # (token, pos) for rewind
        pos, step = S0, 0
        last_rewind_step = -10**9
        key, sub = jax.random.split(key)
        tok = sample(logits, sub, sampling)
        out_tokens.append(np.asarray(tok))
        while len(out_tokens) < n_tokens:
            logits, state, info = self._step(
                self.params, token=tok, pos=jnp.int32(pos),
                step=jnp.int32(step), state=state)
            # ---- telemetry (every list appends exactly once per step) ----
            n_layers_attn = max(state.freeze.frozen.shape[0], 1) \
                if hasattr(state, "freeze") else 1
            if "n_active" in info:
                denom = n_layers_attn * B
                res.active_kv.append(float(jnp.sum(info["n_active"])) / denom)
                res.frozen_kv.append(float(jnp.sum(info["n_frozen"])) / denom)
            else:
                res.active_kv.append(float(pos + 1))
                res.frozen_kv.append(0.0)
            res.total_kv.append(pos + 1)
            if "entropy" in info:
                res.entropy.append(float(jnp.mean(info["entropy"])))
                if bool(jnp.any(info["spike"])):
                    res.recovery_events.append({
                        "step": step,
                        "level": int(jnp.max(info["level"])),
                        "entropy": float(jnp.max(info["entropy"])),
                    })
            # ---- Rewalk Regeneration (recovery level 4) ----
            if "rr_request" in info and bool(jnp.any(info["rr_request"])) \
                    and len(history) >= self.fcfg.rewalk_tokens \
                    and res.rewinds < self.max_rewinds \
                    and step - last_rewind_step >= self.rewind_cooldown:
                nback = self.fcfg.rewalk_tokens
                del history[-nback:]
                del out_tokens[-nback:]
                pos -= nback
                res.rewinds += 1
                last_rewind_step = step
                # the input at the rewind point: the last surviving history
                # entry, or the prefill-sampled first token when the rewind
                # consumed the whole history (out_tokens[0] survives)
                tok = history[-1][0] if history \
                    else jnp.asarray(out_tokens[-1])
                step += 1
                res.offloaded_tokens.append(
                    offloader.offloaded_tokens if offloader else 0)
                continue
            # ---- host offload of fully-frozen pages ----
            if offloader is not None and step % 8 == 7:
                cache = KVCache(k=state.cache_k, v=state.cache_v)
                cache = offloader.sync(cache, np.asarray(state.freeze.frozen))
                state = state._replace(cache_k=cache.k, cache_v=cache.v)
            res.offloaded_tokens.append(
                offloader.offloaded_tokens if offloader else 0)

            key, sub = jax.random.split(key)
            tok = sample(logits, sub, sampling)
            history.append((tok, pos))
            out_tokens.append(np.asarray(tok))
            pos += 1
            step += 1
        res.tokens = np.stack(out_tokens, axis=1)
        return res


# ===================================================================== #
# Continuous batching
# ===================================================================== #
@dataclasses.dataclass
class _Lane:
    """Host-side bookkeeping for one batch slot of the jitted step."""
    request: Optional[Request] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    history: List[Tuple[int, int]] = \
        dataclasses.field(default_factory=list)      # (token, pos) for rewind
    rewinds: int = 0
    last_rewind_step: int = -10**9


class _LaneEngineBase:
    """Shared lane management for the continuous-batching engines: lane
    accounting, prompt bucketing, per-lane sampling-parameter mirrors and
    the admit/finish event log.  Subclasses own the decode state layout
    (contiguous vs paged) and the step/admission mechanics."""

    def __init__(self, cfg: ModelConfig, params, serving: ServingConfig):
        assert not cfg.is_encoder_decoder, \
            "continuous batching is decoder-only (enc-dec uses Engine)"
        sv = serving
        max_seq, n_lanes = sv.max_seq, sv.n_lanes
        pad_id, seed = sv.pad_id, sv.seed
        async_pipeline, chaos = sv.async_pipeline, sv.chaos
        self.cfg = cfg
        self.params = params
        self.serving = sv
        self.max_seq = max_seq
        self.n_lanes = n_lanes
        self.fcfg = sv.freeze_cfg or cfg.freeze
        self.enable_freeze = sv.enable_freeze
        self.pad_id = pad_id
        self.min_prompt_bucket = sv.min_prompt_bucket
        self._sample = jax.jit(sample_batched_perlane)
        self.lanes = [_Lane() for _ in range(n_lanes)]
        self.pos = np.zeros(n_lanes, np.int32)
        self.step = np.zeros(n_lanes, np.int32)
        self.tok = np.full(n_lanes, pad_id, np.int32)
        greedy = SamplingParams.greedy()
        self._temp, self._topk, self._topp = (
            np.array(a) for a in params_arrays([greedy] * n_lanes))
        self._lane_params_dev = None     # device mirror, refreshed on admit
        self.key = jax.random.PRNGKey(seed)
        # order-invariant sampling randomness: the j-th *admission* gets a
        # base key (fold of the engine seed with the admission counter)
        # and every draw folds it with the lane's own decode clock — a
        # lane's token at logical step k is therefore independent of
        # which global dispatch carried it, which is what keeps the async
        # pipeline (whose admit/step interleaving differs from the sync
        # path's) token-for-token identical
        self._admit_count = 0
        self.lane_keys = np.array(
            jax.random.split(jax.random.PRNGKey(seed), n_lanes), np.uint32)
        self.wall_step = 0          # number of jitted decode steps issued
        self.events: List[Dict[str, Any]] = []   # admit / finish log
        self.peak_kv_bytes = 0      # high-water device KV (incl. prefill
                                    # scratch) — the benchmark memory metric
        # ---- async DMA pipeline (serving/dma.py) ---- #
        # Depth-1 ring: step N's fetch is issued right behind the dispatch
        # and consumed at the start of engine call N+1.  Depth 0 is the
        # synchronous baseline (push + blocking pop in the same call);
        # both modes drain entries in identical FIFO order, so their token
        # streams and telemetry are bit-identical.
        self.async_pipeline = async_pipeline
        self.stats = TransferStats()
        # ---- fault tolerance (serving/faults.py) ---- #
        # One injector (shared per-site op clocks) + one endpoint per
        # guarded transfer class.  pull/push/ring must succeed (the data
        # has to move); stage is best-effort (a failed speculative-thaw
        # staging just falls back to the sync upload path).  All None
        # without a chaos config — the hot path pays one attr check.
        self.chaos = chaos
        self._endpoints: Dict[str, Endpoint] = {}
        if chaos is not None:
            self.injector = chaos.build_injector()
            self.ep_pull = chaos.build_endpoint("pull", self.injector)
            self.ep_push = chaos.build_endpoint("push", self.injector)
            self.ep_ring = chaos.build_endpoint("ring", self.injector)
            self.ep_stage = chaos.build_endpoint("stage", self.injector,
                                                 must_succeed=False)
            self._endpoints = {"pull": self.ep_pull, "push": self.ep_push,
                               "ring": self.ep_ring, "stage": self.ep_stage}
        else:
            self.injector = None
            self.ep_pull = self.ep_push = None
            self.ep_ring = self.ep_stage = None
        # ---- host-stash budget + degradation ladder ---- #
        self.stash_budget_bytes = sv.stash_budget_bytes
        self.ladder_cfg = sv.ladder or LadderConfig()
        self.peak_stash_bytes = 0
        # ---- lane-level anomaly quarantine ---- #
        # A non-finite-entropy step triggers a bounded rewind-and-retry;
        # a lane that re-poisons within `quarantine_window` decode steps
        # of its last quarantine rewind is retired "quarantined" instead
        # of corrupting its batch peers' wall time any further.
        self.quarantine_window = sv.quarantine_window
        self._last_quarantine = np.full(n_lanes, -10**9, np.int64)
        self.robust = {"quarantine_rewinds": 0, "quarantined": 0,
                       "ladder_deny": 0, "ladder_deepen": 0,
                       "ladder_throttle": 0, "ladder_shed": 0}
        self.ring = FetchRing(self.stats, depth=1 if async_pipeline else 0,
                              endpoint=self.ep_ring)
        self.staging = HostStaging()
        self._retired_backlog: List[Request] = []   # retired during admit
                                    # drains; reported by the next step_once
        self._suspended: List[LaneSnapshot] = []    # victims of deferred
                                    # (install-time) preemption, awaiting
                                    # pickup via drain_suspended()

    @property
    def kv_device_bytes(self) -> int:       # subclasses override
        return 0

    def _note_kv_peak(self, scratch_bytes: int = 0) -> None:
        self.peak_kv_bytes = max(self.peak_kv_bytes,
                                 self.kv_device_bytes + scratch_bytes)

    # ---------------- robustness: budget ladder + fault plumbing -------- #
    def _stash_bytes(self) -> int:          # subclasses override
        return 0

    def _exported_bytes(self) -> int:       # subclasses override
        return 0

    @property
    def stash_pressure(self) -> float:
        """Measured host-stash bytes over the configured budget (0.0 when
        unbounded) — the degradation ladder's input."""
        if not self.stash_budget_bytes:
            return 0.0
        return self._stash_bytes() / self.stash_budget_bytes

    @property
    def admission_pressure(self) -> float:
        """Stash pressure as *admission* decisions must see it: measured
        stash bytes PLUS the pages suspended snapshots carried out via
        ``export_lane``.  Exporting a victim drops ``stash_pressure``
        instantly, but resuming the snapshot imports every one of those
        bytes straight back — gating admissions on the measured gauge
        alone lets a shed victim resume the same pass it was shed
        (export -> pressure dips -> resume -> import -> pressure pops ->
        shed again), an export/import ping-pong that makes no progress.
        Counting exported bytes gives the throttle rung hysteresis: a
        shed snapshot stays queued until real work retires and drains
        the stash."""
        if not self.stash_budget_bytes:
            return 0.0
        return (self._stash_bytes() + self._exported_bytes()) \
            / self.stash_budget_bytes

    @property
    def n_pending_retired(self) -> int:
        """Requests that already retired inside an admit/suspend flush,
        parked for re-report by the next ``step_once``.  The scheduler
        must keep stepping while this is non-zero or the retirements
        (and their results) would be stranded unreported."""
        return len(self._retired_backlog)

    @property
    def ladder_stage(self) -> int:
        """Current graceful-degradation stage (0 = nominal .. 4 = shed);
        see ``LadderConfig``.  The engine applies stages 1-2 itself; the
        scheduler reads this property for stages 3-4 (throttle / shed)."""
        return self.ladder_cfg.stage(self.stash_pressure)

    def _note_stash_peak(self) -> None:
        self.peak_stash_bytes = max(self.peak_stash_bytes,
                                    self._stash_bytes())

    def _ring_guard(self) -> None:
        """Degrade the fetch ring to its depth-0 synchronous baseline
        while the ring endpoint's breaker is tripped (and restore depth 1
        once it re-closes).  Depth only changes which engine call drains
        an entry, never the FIFO order, so the fallback is
        token-identical by the ring's design."""
        ep = self.ring.endpoint
        if ep is None or ep.breaker is None:
            return
        if ep.breaker.state == "open":
            ep.allow()          # burn one op of the op-count cooldown
        self.ring.depth = 1 if (self.async_pipeline
                                and ep.breaker.state == "closed") else 0

    def _poison_lane(self, active: List[int]) -> Optional[int]:
        """Consult the fault schedule's ``nan`` site for this dispatch.
        Returns the lane whose host-side entropy the commit will poison
        (None almost always).  Host-side by necessity: entropy is
        computed inside the jitted step from the real logits, so the
        injection happens where the poisoned value would first become
        visible to the host — the ring commit."""
        if self.injector is None or not active:
            return None
        plan = self.injector.next_plan("nan")
        if plan is None or plan.kind != "nan":
            return None
        return plan.lane if plan.lane in active else active[0]

    def discard_snapshot(self, snap: LaneSnapshot) -> None:
        """Release the host-side resources of a snapshot that will never
        resume (a suspended request that was cancelled / abandoned).  The
        contiguous snapshot owns nothing beyond host bookkeeping; the
        paged override returns the exported pages' byte accounting."""

    # ---------------- client-disconnect cancellation ---------------- #
    def cancel_lane(self, lane: int) -> Optional[Request]:
        """Cancel the lane's in-flight request (client disconnect) through
        the freeze-native drop path: ``suspend_lane`` (which flushes the
        ring, stashes/cancels exactly as a preemption would, and frees the
        lane) followed immediately by ``discard_snapshot`` (which returns
        the exported pages' byte accounting so nothing leaks).  The
        request keeps its partial tokens as ``result`` and ends
        ``CANCELLED``.  Returns None when the request retired during the
        suspend flush — the retirement is re-reported by the next
        ``step_once`` and cancellation lost the race to completion."""
        l = self.lanes[lane]
        if l.request is None and lane not in getattr(self, "prefills", {}):
            return None
        snap = self.suspend_lane(lane)
        if snap is None:
            return None
        self.discard_snapshot(snap)
        req = snap.req
        req.status = RequestStatus.CANCELLED
        req.result = np.asarray(snap.generated[: req.n_tokens], np.int32)
        self.events.append({"event": "cancel", "uid": req.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "generated": len(snap.generated)})
        return req

    def cancel_request(self, uid: int) -> Optional[Request]:
        """Find and cancel the lane running ``uid`` (the paged override
        also covers a preemptor still mid-``admit_over`` prefill)."""
        for i, l in enumerate(self.lanes):
            if l.request is not None and l.request.uid == uid:
                return self.cancel_lane(i)
        return None

    def robust_snapshot(self) -> Dict[str, Any]:
        """Fault/ladder/quarantine counters for benchmarks and serving
        reports (chaos-less engines report zeros)."""
        eps = {name: ep.stats() for name, ep in self._endpoints.items()}
        return {
            "endpoints": eps,
            "injected": self.injector.n_injected if self.injector else 0,
            "injected_by_site":
                dict(self.injector.injected) if self.injector else {},
            "retries": sum(e["retries"] for e in eps.values()),
            "breaker_trips": sum(e["breaker_trips"] for e in eps.values()),
            "ladder_stage": self.ladder_stage,
            "stash_bytes": self._stash_bytes(),
            "exported_bytes": self._exported_bytes(),
            "peak_stash_bytes": self.peak_stash_bytes,
            "stash_budget_bytes": self.stash_budget_bytes,
            **self.robust,
        }

    @staticmethod
    def _finalize_status(req: Request) -> None:
        """Map a retiring request's lifecycle status to its terminal
        value (quarantine retirement overwrites it afterwards)."""
        if req.status == RequestStatus.SHED:
            req.status = RequestStatus.SHED_RESUMED
        elif req.status == RequestStatus.PENDING:
            req.status = RequestStatus.COMPLETED

    def _quarantine_rewind(self, lane: int) -> bool:
        """Attempt the engine's page-aware rewind for a quarantined lane;
        True iff the lane state was actually rewound."""
        self._rewind_bookkeeping(lane)
        return True

    def _quarantine_scan(self, active: List[int], entropy,
                         rewound: set) -> List[Request]:
        """Lane-level anomaly quarantine: a lane whose committed entropy
        is non-finite (NaN/Inf logits) gets ONE bounded rewind-and-retry
        through the engine's Rewalk machinery; a lane that re-poisons
        within ``quarantine_window`` steps of its last quarantine rewind
        is beyond retry and is retired with status ``quarantined`` so its
        fault cannot poison telemetry or downstream commits.  Returns the
        retired requests; rewound lanes are added to ``rewound`` so the
        caller's commit loop discards their sampled token."""
        retired: List[Request] = []
        if entropy is None:
            return retired
        for i in active:
            l = self.lanes[i]
            if i in rewound or l.request is None \
                    or bool(np.isfinite(entropy[i])):
                continue
            recent = int(self.step[i]) - int(self._last_quarantine[i]) \
                <= self.quarantine_window
            if not recent and len(l.history) >= self.fcfg.rewalk_tokens \
                    and self._quarantine_rewind(i):
                self._last_quarantine[i] = int(self.step[i])
                self.robust["quarantine_rewinds"] += 1
                rewound.add(i)
            else:
                req = self._retire(i)
                req.status = RequestStatus.QUARANTINED
                self.robust["quarantined"] += 1
                retired.append(req)
        return retired

    # ---------------- lane accounting ---------------- #
    @property
    def n_active_lanes(self) -> int:
        return sum(1 for l in self.lanes if l.request is not None)

    @property
    def has_free_lane(self) -> bool:
        return any(l.request is None for l in self.lanes)

    def health(self) -> Dict[str, Any]:
        """Replica-facing liveness/occupancy facade, read by the router's
        placement scorer and heartbeat monitor.  Host-side gauges only —
        no device sync."""
        return {
            "wall_step": self.wall_step,
            "n_lanes": self.n_lanes,
            "n_active_lanes": self.n_active_lanes,
            "has_free_lane": self.has_free_lane,
            "admission_pressure": self.admission_pressure,
            "ladder_stage": self.ladder_stage,
            "active_uids": sorted(l.request.uid for l in self.lanes
                                  if l.request is not None),
        }

    def _free_lane(self) -> int:
        for i, l in enumerate(self.lanes):
            if l.request is None:
                return i
        raise RuntimeError("no free lane")

    def _bucket(self, prompt_len: int, n_tokens: int) -> int:
        """Pad the prompt to a power-of-two bucket (bounded prefill
        recompiles), falling back to the exact length when the bucket
        would not leave room for generation."""
        b = self.min_prompt_bucket
        while b < prompt_len:
            b *= 2
        if b + n_tokens > self.max_seq:
            b = prompt_len
        if b + n_tokens > self.max_seq:
            raise ValueError(
                f"request needs {prompt_len} prompt + {n_tokens} generated "
                f"slots but the engine was built with max_seq={self.max_seq}")
        return b

    def _set_lane_sampling(self, lane: int, sp: SamplingParams) -> None:
        self._temp[lane] = sp.temperature
        self._topk[lane] = sp.top_k
        self._topp[lane] = sp.top_p
        self._lane_params_dev = None

    def _lane_params(self):
        if self._lane_params_dev is None:
            self._lane_params_dev = (jnp.asarray(self._temp),
                                     jnp.asarray(self._topk),
                                     jnp.asarray(self._topp))
        return self._lane_params_dev

    def _left_padded(self, prompt: np.ndarray, sp: int) -> np.ndarray:
        toks = np.full((1, sp), self.pad_id, np.int32)
        toks[0, sp - len(prompt):] = prompt
        return toks

    def _rewind_bookkeeping(self, lane: int) -> None:
        """Shared RR host bookkeeping: truncate the rolled-back tokens,
        charge the lane's rewind budget/cooldown, and restore the input
        token at the rewind point — the last surviving history entry, or
        the admission-time first token (``generated[0]`` survives the
        truncation) when the rewind consumed the whole history.  The
        contiguous and paged engines must stay semantically identical
        here — the paged-vs-contiguous parity test depends on it."""
        l = self.lanes[lane]
        nback = self.fcfg.rewalk_tokens
        del l.history[-nback:]
        del l.generated[-nback:]
        self.pos[lane] -= nback
        l.rewinds += 1
        l.last_rewind_step = int(self.step[lane])
        l.request.telemetry.rewinds += 1
        self.tok[lane] = l.history[-1][0] if l.history else l.generated[-1]
        self.step[lane] += 1

    # ---------------- fetch-ring drain (shared pipeline) ---------------- #
    def _drain_ring(self) -> List[Request]:
        """Materialize every pending ring entry (FIFO) and apply the host
        bookkeeping it carries: admit-token commits, per-step telemetry,
        recovery servicing, token commits and retirement.  Runs at the
        start of every ``step_once`` (and at the end too when the pipeline
        is synchronous), so host decisions are applied in the same order
        in both modes."""
        finished: List[Request] = []
        for meta, host in self.ring.drain():
            if meta["kind"] == "admit":
                finished.extend(self._commit_admit(meta, host))
            else:
                finished.extend(self._commit_step(meta, host))
        return finished

    def flush(self) -> List[Request]:
        """Public drain: block until every in-flight fetch has landed and
        its bookkeeping is applied.  Call before reading per-lane host
        state (``pos`` / ``generated`` / telemetry) mid-run or before
        mutating engine state from outside ``step_once``.  Requests that
        retire during the flush are returned AND re-reported by the next
        ``step_once`` (via the backlog), so a scheduler driving the
        engine never misses one."""
        out = self._drain_ring()
        self._retired_backlog += out
        return out

    def _commit_admit(self, meta: Dict[str, Any], host: Dict[str, Any]
                      ) -> List[Request]:
        """Commit an admission's deferred first token (sampled from the
        prefill logits on device; the old path blocked the admission on
        ``int(np.asarray(...))`` of it).  The token enters ``generated``
        one drain late, by which point the prefill compute and the D2H
        copy have long overlapped other work."""
        lane = meta["lane"]
        l = self.lanes[lane]
        if l.request is not meta["req"]:        # lane was reset meanwhile
            return []
        first = int(host["tok"][0])
        self.tok[lane] = first
        l.generated = [first]
        if len(l.generated) >= l.request.n_tokens:
            return [self._retire(lane)]
        return []

    def _commit_step(self, meta: Dict[str, Any], host: Dict[str, Any]
                     ) -> List[Request]:
        raise NotImplementedError

    def _next_lane_key(self, lane: int):
        """Assign the lane its admission-ordered sampling base key (the
        admission sequence is identical in the sync and async pipelines,
        so this is order-invariant where a global split-per-dispatch
        stream would not be).  The first token folds in 2**31-1; decode
        steps fold in the lane's own clock (always < 2**31-1).  A
        *resumed* lane restores its snapshot's key instead of consuming a
        fresh admission index (``sampling.lane_base_key``)."""
        self._admit_count += 1
        base = lane_base_key(self.key, self._admit_count)
        self.lane_keys[lane] = np.asarray(base, np.uint32)
        return base

    # ---------------- preemption (suspend / resume) ---------------- #
    def _snap_host(self, lane: int) -> LaneSnapshot:
        """Capture the lane's host-side bookkeeping into a snapshot (the
        fields both engines share); the caller adds any engine-specific
        payload.  Must run after ``flush()`` — pending ring entries carry
        exactly this state."""
        l = self.lanes[lane]
        return LaneSnapshot(
            req=l.request, generated=list(l.generated),
            history=list(l.history), pos=int(self.pos[lane]),
            step=int(self.step[lane]), tok=int(self.tok[lane]),
            rewinds=l.rewinds, last_rewind_step=l.last_rewind_step,
            lane_key=self.lane_keys[lane].copy())

    def _restore_host(self, snap: LaneSnapshot, lane: int) -> None:
        """Inverse of ``_snap_host``: reinstall the shared host-side lane
        bookkeeping (clocks, tokens, rewind budget, the snapshot-stable
        sampling key and per-lane sampling params)."""
        l = self.lanes[lane]
        l.request = snap.req
        l.generated = list(snap.generated)
        l.history = list(snap.history)
        l.rewinds = snap.rewinds
        l.last_rewind_step = snap.last_rewind_step
        self.pos[lane] = snap.pos
        self.step[lane] = snap.step
        self.tok[lane] = snap.tok
        self.lane_keys[lane] = np.asarray(snap.lane_key, np.uint32)
        self._set_lane_sampling(lane, snap.req.sampling)

    def _park_lane(self, lane: int) -> None:
        """Leave a just-vacated lane idle: greedy sampling so the garbage
        it decodes is cheap, position clamped in-bounds."""
        l = self.lanes[lane]
        l.request = None
        l.generated = []
        l.history = []
        self._set_lane_sampling(lane, SamplingParams.greedy())
        self.pos[lane] = min(int(self.pos[lane]), self.max_seq - 1)

    def drain_suspended(self) -> List[LaneSnapshot]:
        """Collect (and clear) the snapshots of lanes the engine suspended
        on its own — currently only the paged engine's install-time
        preemption (``admit_over``).  A scheduler driving the engine must
        call this after every ``step_once`` and requeue the snapshots, or
        the victims' requests are lost."""
        out, self._suspended = self._suspended, []
        return out

    def _push_admit_token(self, lane: int, req: Request, logits) -> None:
        """Shared deferred first-token path: assign the lane's base key,
        sample the admission token on device right behind the prefill
        chain (never materializing it here — the old path blocked on
        ``int(np.asarray(...))``), install the lane's sampling params and
        push the token into the fetch ring for ``_commit_admit``.  Both
        engines MUST use this helper — the 2**31-1 fold sentinel and the
        entry shape are parity-critical with the base-class commit."""
        base = self._next_lane_key(lane)
        first_dev = sample(logits, jax.random.fold_in(base, 2**31 - 1),
                           req.sampling)
        self._set_lane_sampling(lane, req.sampling)
        self.ring.push({"kind": "admit", "lane": lane, "req": req},
                       {"tok": first_dev})


class ContinuousEngine(_LaneEngineBase):
    """Continuous-batching generation: per-lane admission and retirement.

    The jitted step always runs the full ``n_lanes``-wide batch (fixed
    shapes, one compile); idle lanes decode garbage that the host ignores.
    Prompt lengths are padded to power-of-two buckets so the per-lane
    prefill compiles O(log max_seq) times, not once per prompt length.
    """

    def __init__(self, cfg: ModelConfig, params,
                 max_seq: Optional[int] = None,
                 n_lanes: Optional[int] = None,
                 serving: Optional[ServingConfig] = None,
                 **legacy):
        sv = resolve_serving_config(serving, "contiguous", max_seq, n_lanes,
                                    legacy)
        super().__init__(cfg, params, sv)
        quant.resolve_mode(sv.kv_quant)
        self.kv_quant = sv.kv_quant
        self.max_rewinds = sv.max_rewinds
        self.rewind_cooldown = sv.rewind_cooldown
        # legacy knob, no longer a wall-clock cadence: the freeze mask now
        # rides the per-step fetch ring (~KBs) and `needs_sync` triggers
        # the cache round-trip exactly when a page crosses fully-frozen —
        # retained so existing callers keep constructing
        self.offload_every = sv.offload_every
        self.debug_lane_checks = sv.debug_lane_checks
        # donated decode state: the per-step KV/freeze buffers are reused in
        # place rather than double-buffered in HBM (no-op on CPU)
        self._prefill = jax.jit(functools.partial(MD.prefill, cfg=cfg),
                                donate_argnames=("state",))
        self._step = jax.jit(functools.partial(
            MD.decode_step, cfg=cfg, freeze_cfg=self.fcfg,
            enable_freeze=self.enable_freeze), donate_argnames=("state",))
        self._write_lane = jax.jit(functools.partial(MD.write_lane_state, cfg),
                                   donate_argnames=("state", "lane_state"))
        self.state = MD.init_decode_state(cfg, self.n_lanes, self.max_seq)
        self.offloader = HostOffloadController(self.fcfg.page_size) \
            if (sv.offload and self.enable_freeze) else None
        if self.offloader is not None:
            self.offloader.stash_budget_bytes = sv.stash_budget_bytes
            self.offloader.kv_quant = sv.kv_quant

    def _stash_bytes(self) -> int:
        return self.offloader.stash_bytes if self.offloader else 0

    @classmethod
    def from_engine(cls, engine: Engine, n_lanes: int,
                    **kw) -> "ContinuousEngine":
        """Build a continuous engine sharing a static Engine's model and
        freeze settings (the Scheduler's compatibility path)."""
        sv = ServingConfig(max_seq=engine.max_seq, n_lanes=n_lanes,
                           freeze_cfg=engine.fcfg,
                           enable_freeze=engine.enable_freeze,
                           offload=engine.offload,
                           max_rewinds=engine.max_rewinds,
                           rewind_cooldown=engine.rewind_cooldown, **kw)
        return cls(engine.cfg, engine.params, serving=sv)

    @property
    def kv_device_bytes(self) -> int:
        """Live device KV footprint (the benchmark's peak-memory metric)."""
        return self.state.cache_k.nbytes + self.state.cache_v.nbytes

    # ---------------- admission ---------------- #
    def admit(self, req: Request, lane: Optional[int] = None) -> int:
        """Prefill `req` into a free lane mid-stream.  The single-lane
        prefill state is scattered over the lane's slice of the batched
        decode state, which wholesale-resets its KV cache, freeze masks and
        recovery ladder; host-side page-offload bookkeeping for the lane's
        previous occupant is dropped."""
        # drain first: a pending ring entry may reference state buffers
        # (the folded-in offload freeze mask) that the admission scatter
        # donates below — and the sync path processes step N before any
        # later admission anyway, so ordering is unchanged.  This is also
        # what lets _commit_step trust its entry wholesale: no ring entry
        # ever spans an admission, so the lanes and freeze mask it carries
        # always describe the current occupants
        self._retired_backlog += self._drain_ring()
        if lane is None:
            lane = self._free_lane()
        l = self.lanes[lane]
        assert l.request is None, f"lane {lane} is busy"
        prompt = np.asarray(req.prompt, np.int32)
        sp = self._bucket(len(prompt), req.n_tokens)
        toks = self._left_padded(prompt, sp)          # left-pad, as in prefill
        event = {"event": "admit", "uid": req.uid, "lane": lane,
                 "wall_step": self.wall_step}
        if self.debug_lane_checks:
            # ONE batched pull for both debug fields (was two separate
            # blocking np.asarray materializations of full-state columns)
            # hotpath: ok(debug_lane_checks lane audit, default-off in serving)
            fro, seen = jax.device_get(
                (self.state.freeze.frozen[:, lane],
                 self.state.recovery.steps_seen[lane]))
            event["frozen_before"] = int(fro.sum())
            event["recovery_steps_before"] = int(seen)
        lane_state = MD.init_decode_state(self.cfg, 1, self.max_seq)
        self._note_kv_peak(lane_state.cache_k.nbytes + lane_state.cache_v.nbytes)
        logits, lane_state = self._prefill(
            self.params, batch={"tokens": jnp.asarray(toks)}, state=lane_state)
        self.state = self._write_lane(self.state, lane_state, jnp.int32(lane))
        if self.offloader is not None:
            self.offloader.drop_lane(lane)
        if self.debug_lane_checks:
            # hotpath: ok(debug_lane_checks lane audit, default-off in serving)
            fro, seen = jax.device_get(
                (self.state.freeze.frozen[:, lane],
                 self.state.recovery.steps_seen[lane]))
            event["frozen_after"] = int(fro.sum())
            event["recovery_steps_after"] = int(seen)
        self.pos[lane] = sp
        self.step[lane] = 0
        l.request = req
        l.generated = []
        l.history = []
        l.rewinds = 0
        l.last_rewind_step = -10**9
        req.telemetry = GenerationResult([], [], [], [], [], [], [])
        # first token deferred into the fetch ring: committed at the next
        # drain, before the lane's first decode step is dispatched
        self._push_admit_token(lane, req, logits)
        self.events.append(event)
        if self.ring.depth == 0:
            self._retired_backlog += self._drain_ring()
        return lane

    # ---------------- stepping ---------------- #
    def step_once(self) -> List[Request]:
        """One engine call of the async pipeline: drain the previous
        step's fetch-ring entry (applying its host bookkeeping), then
        dispatch one jitted decode step over all lanes and push its fetch.
        Returns the requests that retired during the drain (their lanes
        are immediately free); with ``async_pipeline=False`` the entry is
        drained in the same call, reproducing the synchronous timing."""
        self.stats.begin_step()
        self._ring_guard()
        finished = self._retired_backlog + self._drain_ring()
        self._retired_backlog = []
        active = [i for i, l in enumerate(self.lanes) if l.request is not None]
        if not active:
            self.stats.cancel_step()
            return finished
        self._note_kv_peak()
        logits, self.state, info = self._step(
            self.params, token=jnp.asarray(self.tok),
            pos=jnp.asarray(self.pos), step=jnp.asarray(self.step),
            state=self.state)
        self.wall_step += 1
        # enqueue per-lane sampling right behind the step, then start the
        # async D2H of tokens + telemetry in ONE ring entry, materialized
        # at the next drain (rewound lanes simply discard their draw)
        keys = ("n_active", "n_frozen", "entropy", "spike", "level",
                "rr_request")
        arrays = dict(
            {k: info[k] for k in keys if k in info},
            toks=self._sample(logits, jnp.asarray(self.lane_keys),
                              jnp.asarray(self.step), *self._lane_params()))
        offload = self.offloader is not None
        if offload:
            # fold the offload controller's freeze-mask read into the same
            # async fetch (it used to be a second, blocking device pull of
            # the whole token mask every `offload_every` steps), reduced
            # to page granularity ON DEVICE first — page_size x less D2H,
            # and all `sync` ever consumes.  Riding every step lets
            # `needs_sync` gate the expensive cache round-trip instead of
            # a wall-clock cadence, which also makes offload timing a
            # pure function of each lane's own trajectory (async/sync
            # pipeline parity).  The reduction output is a fresh array,
            # so the ring entry never aliases the donated state buffers.
            fz = self.state.freeze.frozen
            pg = self.offloader.page_size
            n_pages = fz.shape[2] // pg
            arrays["frozen_pages"] = fz[:, :, :n_pages * pg].reshape(
                fz.shape[0], fz.shape[1], n_pages, pg).all(axis=-1)
        self.ring.push({"kind": "step", "active": active,
                        "offload": offload,
                        "poison": self._poison_lane(active)}, arrays)
        if self.ring.depth == 0:
            finished += self._drain_ring()
        self.stats.end_step()
        return finished

    def _commit_step(self, meta: Dict[str, Any], host: Dict[str, Any]
                     ) -> List[Request]:
        """Apply one drained step entry: telemetry, rewinds, host offload,
        token commits and retirement — the exact sequence (and order) the
        synchronous path ran inline after its blocking fetch."""
        active = meta["active"]
        get = host.get
        n_active, n_frozen = get("n_active"), get("n_frozen")
        entropy, spike, level = get("entropy"), get("spike"), get("level")
        rr = get("rr_request")
        toks = host["toks"]
        poison = meta.get("poison")
        if poison is not None and entropy is not None:
            # scheduled logits-anomaly injection: the entropy value is the
            # host's only view of the step's logits health, so the poison
            # lands where the corruption would first become visible
            entropy = np.array(entropy, np.float32)
            entropy[poison] = np.nan
        n_layers_attn = max(self.state.freeze.frozen.shape[0], 1)

        # ---- per-lane telemetry: one append per lane-step ----
        for i in active:
            res = self.lanes[i].request.telemetry
            if n_active is not None:
                res.active_kv.append(float(n_active[i]) / n_layers_attn)
                res.frozen_kv.append(float(n_frozen[i]) / n_layers_attn)
            else:
                res.active_kv.append(float(self.pos[i] + 1))
                res.frozen_kv.append(0.0)
            res.total_kv.append(int(self.pos[i]) + 1)
            if entropy is not None:
                res.entropy.append(float(entropy[i]))
                if spike is not None and bool(spike[i]):
                    res.recovery_events.append({
                        "step": int(self.step[i]),
                        "level": int(level[i]),
                        "entropy": float(entropy[i]),
                    })

        # ---- per-lane Rewalk Regeneration ----
        rewound = set()
        if rr is not None:
            for i in active:
                l = self.lanes[i]
                if bool(rr[i]) and len(l.history) >= self.fcfg.rewalk_tokens \
                        and l.rewinds < self.max_rewinds \
                        and int(self.step[i]) - l.last_rewind_step \
                            >= self.rewind_cooldown:
                    self._rewind_bookkeeping(i)
                    rewound.add(i)

        # ---- lane-level anomaly quarantine (non-finite entropy) ----
        quarantined = self._quarantine_scan(active, entropy, rewound)

        # ---- page-batched host offload ----
        if meta["offload"]:
            # admit() drains the ring before scattering a new occupant, so
            # this (page-reduced) mask always predates at most the
            # retirements applied a few lines below — never a re-admission
            frozen = host["frozen_pages"]
            idle = [i for i, l in enumerate(self.lanes)
                    if l.request is None]
            if idle:   # idle lanes decode garbage; never offload it
                frozen = frozen.copy()
                frozen[:, idle, :] = False
            if self.offloader.needs_sync(frozen, reduced=True):
                t0 = time.perf_counter()
                cache = KVCache(k=self.state.cache_k, v=self.state.cache_v)
                cache = self.offloader.sync(cache, frozen, reduced=True)
                self.state = self.state._replace(cache_k=cache.k,
                                                 cache_v=cache.v)
                self.stats.note_blocking(
                    cache.k.nbytes + cache.v.nbytes, d2h=True,
                    seconds=time.perf_counter() - t0)
        for i in active:
            if self.lanes[i].request is None:       # quarantined above
                continue
            self.lanes[i].request.telemetry.offloaded_tokens.append(
                self.offloader.offloaded_tokens_lane(i)
                if self.offloader is not None else 0)
        self._note_stash_peak()

        # ---- commit sampled tokens, retire finished lanes ----
        finished = list(quarantined)
        for i in active:
            if i in rewound:
                continue
            l = self.lanes[i]
            if l.request is None:                   # quarantined above
                continue
            t = int(toks[i])
            l.history.append((t, int(self.pos[i])))
            l.generated.append(t)
            self.tok[i] = t
            self.pos[i] += 1
            self.step[i] += 1
            if len(l.generated) >= l.request.n_tokens:
                finished.append(self._retire(i))
        return finished

    def _retire(self, lane: int) -> Request:
        l = self.lanes[lane]
        req = l.request
        req.result = np.asarray(l.generated[: req.n_tokens], np.int32)
        req.telemetry.tokens = req.result[None, :]
        self._finalize_status(req)
        self.events.append({"event": "finish", "uid": req.uid, "lane": lane,
                            "wall_step": self.wall_step})
        # park the idle lane; the retired request's offloaded pages are
        # released right away (offload sync also masks idle lanes, so no
        # churn until re-admit)
        self._park_lane(lane)
        if self.offloader is not None:
            self.offloader.drop_lane(lane)
        return req

    # ---------------- preemption (suspend / resume) ---------------- #
    def suspend_lane(self, lane: int) -> Optional[LaneSnapshot]:
        """Preempt the lane's request mid-generation and free the lane.

        The contiguous engine has no page-granular stash, so the snapshot
        carries only host bookkeeping (prompt, generated tokens, clocks,
        sampling key); ``resume_lane`` re-prefills prompt + generated —
        cheaper than regenerating but not byte-identical (the freeze /
        recovery state restarts at the resume point; the paged engine's
        stash/restore path is the exact one).  Returns None when the
        request retired while the in-flight fetch drained (its lane is
        already free and the retirement is re-reported by the next
        ``step_once``)."""
        self.flush()
        l = self.lanes[lane]
        if l.request is None:
            return None
        snap = self._snap_host(lane)
        self.events.append({"event": "suspend", "uid": snap.req.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "generated": len(snap.generated)})
        self._park_lane(lane)
        if self.offloader is not None:
            self.offloader.drop_lane(lane)
        return snap

    def resume_lane(self, snap: LaneSnapshot,
                    lane: Optional[int] = None) -> int:
        """Re-admit a suspended request from its snapshot.

        Re-prefills the left-padded prompt plus the already-generated
        tokens (all but the uncommitted input token, whose KV the original
        run had not written yet) into a free lane, then restores the
        host bookkeeping — decode clock, rewind budget and the
        snapshot-stable sampling key — so the continuation draws the same
        sampling stream the uninterrupted run would have.  The re-prefill
        length is re-bucketed to a power of two (extra left-padding,
        exactly like admission's prompt bucketing) so resumes compile
        O(log max_seq) prefill shapes, not one per suspension point; the
        lane's ``pos`` shifts right by the padding, which this approximate
        path tolerates (the paged engine's restore is the exact one)."""
        if not snap.started:
            return self.admit(snap.req, lane)
        self._retired_backlog += self._drain_ring()   # mirror admit's drain
        if lane is None:
            lane = self._free_lane()
        l = self.lanes[lane]
        assert l.request is None, f"lane {lane} is busy"
        prompt = np.asarray(snap.req.prompt, np.int32)
        sp = self._bucket(len(prompt), snap.req.n_tokens)
        assert snap.pos == sp + len(snap.generated) - 1, \
            "snapshot clocks are inconsistent with its token count"
        remaining = snap.req.n_tokens - len(snap.generated) + 1
        sb = self._bucket(snap.pos, remaining)
        toks = np.full((1, sb), self.pad_id, np.int32)
        off = sb - snap.pos                  # re-bucketing pad shift
        toks[0, off + sp - len(prompt):off + sp] = prompt
        toks[0, off + sp:] = snap.generated[:-1]
        lane_state = MD.init_decode_state(self.cfg, 1, self.max_seq)
        self._note_kv_peak(lane_state.cache_k.nbytes
                           + lane_state.cache_v.nbytes)
        _, lane_state = self._prefill(
            self.params, batch={"tokens": jnp.asarray(toks)},
            state=lane_state)
        self.state = self._write_lane(self.state, lane_state,
                                      jnp.int32(lane))
        if self.offloader is not None:
            self.offloader.drop_lane(lane)
        self._restore_host(snap, lane)
        self.pos[lane] = sb                  # snap.pos plus the pad shift
        self.events.append({"event": "resume", "uid": snap.req.uid,
                            "lane": lane, "wall_step": self.wall_step})
        return lane


# ===================================================================== #
# Paged continuous batching (bounded-HBM decode + chunked prefill)
# ===================================================================== #
@dataclasses.dataclass
class _PendingPrefill:
    """An admission in flight: the prompt is prefilled chunk-by-chunk into a
    contiguous single-lane scratch cache, interleaved with decode steps of
    the resident lanes; on completion the scratch is repacked into pages
    and installed into the lane.

    ``over=True`` is the preemption variant (``admit_over``): the lane's
    current occupant — the preemption victim — KEEPS DECODING while this
    prefill runs in its scratch, because the scratch never touches the
    lane's page pool.  The victim is suspended only at install time, so a
    preemption costs the victim zero decode opportunity during the
    preemptor's prefill."""
    req: Request
    toks: np.ndarray          # (1, sp) left-padded prompt
    scratch: Any              # contiguous DecodeState (B=1, S=sp)
    sp: int                   # padded prompt length
    done: int = 0             # tokens prefilled so far
    logits: Any = None        # chunk-final logits (valid once done == sp)
    over: bool = False        # preempting the lane's current occupant


class PagedContinuousEngine(_LaneEngineBase):
    """Continuous batching whose decode attends only each lane's bounded
    active page pool: device KV is O(P * page) per lane instead of
    O(max_seq), with frozen / overflow pages living in the host store
    (`core.paging.PagedController`).

    Two serving properties beyond `ContinuousEngine`:

    * **Bounded-HBM decode** — the jitted step (`model.decode_step_paged`,
      Pallas paged-attention kernel on TPU) runs per-lane (B,) pos/step
      clocks and a per-layer, per-lane tail-slot table; page-granular
      freeze plus the forced-freeze bound keep every lane inside its P
      physical slots, and the host controller swaps frozen pages out / due
      pages in at each lane's own page-allocation cadence.

    * **Chunked prefill** — admission prefills the prompt in fixed-size
      chunks (`prefill_chunk` tokens per engine step) into a scratch cache
      while resident lanes keep decoding; the finished prompt is repacked
      into pages (overflow beyond the pool is stashed to the host store)
      and installed with a wholesale per-lane reset
      (`PagedController.write_lane`).  A long prompt therefore never
      head-of-line-blocks the batch.

    * **Async DMA pipeline** (``async_pipeline=True``, the default) — the
      per-step fetch rides the double-buffered ring (module docstring),
      every boundary tick is ONE batched device_get/device_put pair with
      metadata-only pushes when no K/V moved, and ``speculative_slots``
      staging slots per (layer, lane) hold prefetched likely-thaw pages
      (``thaw_urgency`` trend + ``thaw_priority`` ranking) so an FR thaw
      installs as a page-table remap plus a device-side copy instead of a
      blocking upload.  ``async_pipeline=False`` is the same code with a
      depth-0 ring: identical host decisions, and bit-identical tokens
      under a deterministic chunk split (``burst_prefill=False`` — see
      the module docstring; the staging slots are subtracted from the
      jitted step's headroom math, so a P+S pool with S reserved behaves
      exactly like a plain P pool).

    Restricted to attention-only decoder stacks (chunked prefill would
    need cross-chunk recurrent-state threading for mamba/rwkv hybrids).

    **Entropy-guided recovery** (when ``freeze_cfg.recovery_enabled``) runs
    page-granular: the jitted step's ladder (``core.recovery.
    page_recovery_update``) un-freezes *resident* pages in place — they
    re-enter attention through the kernel's per-page visibility mask — and
    raises two host requests the step itself cannot service:

    * ``thaw_request`` (FR level): the lane's stashed host pages are due
      back early.  The engine marks the lane and the ``PagedController``
      thaws at its next page-boundary tick — stashed pages are ranked by
      ``recovery.thaw_priority`` and remapped into free slots, evicting
      the coldest resident page (stashed in turn) once the pool is full.
    * ``rr_request`` (RR level): page-aware Rewalk rewind.  The host
      rewinds ``rewalk_tokens``, invalidates the rewound KV slots on
      device (``model.rewind_paged_lane`` — wholly-rewound pages unmap;
      a rewind landing exactly on a page boundary leaves tail allocation
      to the next boundary tick), makes sure the surviving tail page is
      resident/un-frozen (``PagedController.ensure_resident``), and
      replays from the rewind point.  Budget and cooldown are per lane,
      mirroring ``ContinuousEngine``.
    """

    def __init__(self, cfg: ModelConfig, params,
                 max_seq: Optional[int] = None,
                 n_lanes: Optional[int] = None,
                 max_active_pages: Optional[int] = None,
                 serving: Optional[ServingConfig] = None,
                 **legacy):
        sv = resolve_serving_config(serving, "paged", max_seq, n_lanes,
                                    legacy, max_active_pages=max_active_pages)
        super().__init__(cfg, params, sv)
        quant.resolve_mode(sv.kv_quant)       # fail fast on bad/unsupported
        self.kv_quant = sv.kv_quant
        self.debug_invariants = sv.debug_invariants
        assert sv.max_active_pages >= 3, "pool needs tail + swap headroom"
        assert sv.prefill_chunk >= 1
        max_active_pages = sv.max_active_pages
        self.P = max_active_pages          # usable (allocator-visible) pool
        self.page = self.fcfg.page_size
        self.prefill_chunk = sv.prefill_chunk
        # load-adaptive burst chunks make the chunk split (and with it the
        # flash-attention summation order) depend on engine busyness;
        # disable for runs that must be bit-reproducible across pipelines
        self.burst_prefill = sv.burst_prefill
        self.max_rewinds = sv.max_rewinds
        self.rewind_cooldown = sv.rewind_cooldown
        self.pending_thaws: set = set()   # lanes owed a host thaw (FR level)
        # speculative-thaw staging: S extra physical slots per (layer, lane)
        # hold prefetched stashed pages so a thaw is a page-table remap.
        # The jitted step subtracts them from its headroom math
        # (reserved_slots), so a P+S pool with S reserved is step-for-step
        # identical to a plain P pool — async and sync arms stay
        # token-parity even though only the async arm stages.
        speculative_thaw = sv.speculative_thaw
        if speculative_thaw is None:
            speculative_thaw = sv.async_pipeline
        self.S_stage = sv.speculative_slots if (speculative_thaw
                                                and self.enable_freeze) else 0
        self.P_total = self.P + self.S_stage
        self._step = jax.jit(functools.partial(
            MD.decode_step_paged, cfg=cfg, freeze_cfg=self.fcfg,
            enable_freeze=self.enable_freeze, reserved_slots=self.S_stage),
            donate_argnames=("state",))
        self._rewind = jax.jit(
            functools.partial(MD.rewind_paged_lane, cfg, page=self.page),
            donate_argnames=("state",))
        self._chunk = jax.jit(functools.partial(MD.prefill_chunk, cfg=cfg),
                              donate_argnames=("state",))
        self._reset_lane = jax.jit(functools.partial(MD.reset_paged_lane, cfg),
                                   donate_argnames=("state",))
        # batched boundary-tick DMA: ONE gather + device_get pulls every
        # boundary lane's pool slice (all layers stacked), ONE scatter +
        # device_put pushes them back.  The lane-index vector is padded to
        # n_lanes (repeating the first lane) so each tuple shape compiles
        # exactly once; duplicate scatter indices write identical columns.
        self._gather_lanes = jax.jit(
            lambda arrs, idx: tuple(jnp.take(a, idx, axis=1) for a in arrs))
        self._scatter_lanes = jax.jit(
            lambda arrs, idx, vals: tuple(
                a.at[:, idx].set(v.astype(a.dtype))
                for a, v in zip(arrs, vals)),
            donate_argnums=(0,))
        # speculative staging write: scatter one page of K/V per layer into
        # the lane's staging slots (valid=False layers are a no-op)
        def _stage_write_fn(state, lane, slots, new_k, new_v, valid):
            li = jnp.arange(state.k.shape[0])
            slots = jnp.maximum(slots, 0)
            sel = valid[:, None, None, None]
            cur_k = state.k[li, lane, slots]
            cur_v = state.v[li, lane, slots]
            k = state.k.at[li, lane, slots].set(
                jnp.where(sel, new_k.astype(state.k.dtype), cur_k))
            v = state.v.at[li, lane, slots].set(
                jnp.where(sel, new_v.astype(state.v.dtype), cur_v))
            return state._replace(k=k, v=v)
        self._stage_write = jax.jit(_stage_write_fn,
                                    donate_argnames=("state",))
        # staged installs: ONE device-side batched copy staging slots ->
        # target slots per tick (padded to a fixed width so it compiles
        # once; padding rows copy slot 0 onto itself — a no-op)
        def _remap_copy_fn(state, layers, lanes, srcs, dsts):
            k = state.k.at[layers, lanes, dsts].set(
                state.k[layers, lanes, srcs])
            v = state.v.at[layers, lanes, dsts].set(
                state.v[layers, lanes, srcs])
            return state._replace(k=k, v=v)
        self._remap_copy = jax.jit(_remap_copy_fn,
                                   donate_argnames=("state",))
        self._remap_width = 8
        # preemption resume: the pool slice rides _push_lanes, but the
        # recovery ladder is per-lane (B,) state outside the pool fields —
        # restore one lane's scalars with a tiny donated scatter
        def _set_rec_fn(state, lane, ema, level, calm, seen):
            r = state.recovery
            return state._replace(recovery=RecoveryState(
                ema_entropy=r.ema_entropy.at[lane].set(ema),
                level=r.level.at[lane].set(level),
                calm_steps=r.calm_steps.at[lane].set(calm),
                steps_seen=r.steps_seen.at[lane].set(seen)))
        self._set_recovery = jax.jit(_set_rec_fn,
                                     donate_argnames=("state",))
        self.state = MD.init_paged_decode_state(
            cfg, self.n_lanes, max_active_pages, staging_slots=self.S_stage)
        self.L_attn = max(self.state.page_table.shape[0], 1)
        assert self.state.page_table.shape[0] == cfg.num_layers, \
            "paged continuous batching requires an attention-only stack"
        self.ctl = PagedController(cfg=cfg, batch=self.n_lanes,
                                   max_active_pages=max_active_pages)
        self.ctl.kv_quant = sv.kv_quant
        self.ctl.stash_budget_bytes = sv.stash_budget_bytes
        if self.injector is not None:
            self.ep_stash = sv.chaos.build_endpoint(
                "stash", self.injector, must_succeed=False)
            self.ctl.stash_endpoint = self.ep_stash
            self._endpoints["stash"] = self.ep_stash
        else:
            self.ep_stash = None
        self.tail_slot = np.zeros((self.L_attn, self.n_lanes), np.int32)
        self.prefills: Dict[int, _PendingPrefill] = {}
        self._urgency = np.zeros(self.n_lanes, np.float32)  # thaw trend/lane
        self.n_boundary_ticks = 0   # boundary maintenance passes (each one
                                    # batched pull + one push)
        self.n_kv_pushes = 0        # pushes that had to carry pool K/V

    @property
    def kv_device_bytes(self) -> int:
        """Live device KV footprint — O(n_lanes * P * page), independent of
        context length (the benchmark's peak-memory metric).  Quantized
        resident pages count at their packed width (1 byte/elem): the CPU
        pool stores the integer-valued payload widened into the pool dtype
        (the kernel dequantizes in place), but on a real TPU the frozen
        region is physically int8/fp8 — the gauge models that layout, so
        the quantized arm's measured reduction is the deployable one."""
        return (self.state.k.nbytes + self.state.v.nbytes
                - self.ctl.device_savings_bytes)

    def _offloaded_tokens_lane(self, lane: int) -> int:
        n = sum(1 for key in self.ctl.frozen_meta if key[1] == lane)
        return n * self.page // self.L_attn

    def _stash_bytes(self) -> int:
        return self.ctl.stash_bytes

    def _exported_bytes(self) -> int:
        return self.ctl.exported_bytes

    def _scratch_bytes(self) -> int:
        return sum(pp.scratch.cache_k.nbytes + pp.scratch.cache_v.nbytes
                   for pp in self.prefills.values())

    # ---------------- device <-> host pool transfer ---------------- #
    # Only the affected lanes' pool slices cross the host<->device boundary
    # — and they cross it BATCHED: a boundary tick with any number of lanes
    # issues exactly one device_get (a jitted gather over the padded
    # lane-index vector stacks all lanes and layers) and one device_put
    # (a donated scatter).  Pulled data lands in reused host staging
    # buffers (pinned memory on a real TPU); the push carries K/V only
    # when the controller actually wrote some (kv_dirty) — a tick that
    # only flipped metadata (page-table remaps, freeze counters) moves a
    # few KB, not the pool.
    # page_quant / kv_scales travel with BOTH field sets: a metadata-only
    # push (staged-remap tick) must still land the target slots' quant
    # flags + scales — the remap copies the quantized payload device-side,
    # so only the metadata crosses the bus
    _POOL_FIELDS = ("k", "v", "page_table", "slot_mask",
                    "page_quant", "kv_scales")
    _FZ_FIELDS = ("c", "d", "frozen", "frozen_at")
    _META_FIELDS = ("page_table", "slot_mask",
                    "page_quant", "kv_scales") + _FZ_FIELDS

    def _state_arrs(self, fields=None):
        st = self.state
        fields = fields or (self._POOL_FIELDS + self._FZ_FIELDS)
        return tuple(getattr(st, f) if hasattr(st, f)
                     else getattr(st.freeze, f) for f in fields)

    def _padded_idx(self, lanes: List[int]) -> np.ndarray:
        idx = np.full(self.n_lanes, lanes[0], np.int32)
        idx[:len(lanes)] = lanes
        return idx

    @staticmethod
    def _quant_packing_savings(pool: dict) -> int:
        """Bytes a real TPU transfer would NOT move for this pool slice:
        quantized mapped pages cross the bus at 1 byte/elem (K and V), not
        at the pool dtype's width.  The CPU reference path moves the
        widened payload, so the gauges subtract the packing delta to model
        the deployable transfer size (docs/quantization.md)."""
        pq = pool.get("page_quant")
        if pq is None:
            return 0
        n = int(((np.asarray(pq) != 0)
                 & (np.asarray(pool["page_table"]) >= 0)).sum())
        k = pool["k"]
        page_elems = int(np.prod(k.shape[3:]))
        return n * page_elems * (k.dtype.itemsize - 1) * 2

    def _pull_lanes(self, lanes: List[int]) -> Tuple[dict, dict]:
        m = len(lanes)
        dev = self._gather_lanes(self._state_arrs(),
                                 jnp.asarray(self._padded_idx(lanes)))
        t0 = time.perf_counter()
        # the ONE batched D2H for all boundary lanes + layers, recorded in
        # TransferStats below — the pull every per-lane slice rides on.
        # Under chaos the endpoint fronts it: injected failures burn
        # retries BEFORE device_get runs (must-succeed — the tick cannot
        # proceed without the pool bytes), so the real pull runs once
        if self.ep_pull is not None:
            # hotpath: ok(single batched boundary-tick pull, counted via note_blocking)
            host = self.ep_pull.call(jax.device_get, dev)
        else:
            # hotpath: ok(single batched boundary-tick pull, counted via note_blocking)
            host = jax.device_get(dev)
        dt = time.perf_counter() - t0
        names = self._POOL_FIELDS + self._FZ_FIELDS
        out = {}
        for name, arr in zip(names, host):
            out[name] = self.staging.put(f"pull_{name}_{m}", arr[:, :m])
        self.stats.note_blocking(sum(a.nbytes for a in out.values())
                                 - self._quant_packing_savings(out),
                                 d2h=True, seconds=dt)
        return ({f: out[f] for f in self._POOL_FIELDS},
                {f: out[f] for f in self._FZ_FIELDS})

    def _push_lanes(self, pool: dict, fstate: dict, lanes: List[int],
                    kv: bool = True) -> None:
        m = len(lanes)
        idx = self._padded_idx(lanes)
        if kv:
            self.n_kv_pushes += 1
        fields = (self._POOL_FIELDS + self._FZ_FIELDS) if kv \
            else self._META_FIELDS
        vals = []
        nbytes = 0
        for f in fields:
            src = pool[f] if f in pool else fstate[f]
            buf = self.staging.buf(f"push_{f}", (src.shape[0], self.n_lanes)
                                   + src.shape[2:], src.dtype)
            buf[:, :m] = src
            if m < self.n_lanes:        # duplicate scatter columns must
                buf[:, m:] = src[:, :1]  # carry identical data
            vals.append(buf)
            nbytes += src.nbytes
        # the dispatch closure runs exactly once per endpoint call —
        # injected failures are simulated before it, never around a
        # half-donated scatter (re-running it would read freed buffers)
        def _dispatch():
            return self._scatter_lanes(self._state_arrs(fields),
                                       jnp.asarray(idx),
                                       tuple(jnp.asarray(v) for v in vals))
        arrs = self.ep_push.call(_dispatch) if self.ep_push is not None \
            else _dispatch()
        upd = dict(zip(fields, arrs))
        fz = PageFreezeState(*(upd.get(f, getattr(self.state.freeze, f))
                               for f in self._FZ_FIELDS))
        self.state = self.state._replace(
            freeze=fz, **{f: upd[f] for f in self._POOL_FIELDS
                          if f in upd})
        # the K/V of a metadata-only push never crossed the bus: remapped
        # staging slots already hold their page data on device
        if kv:
            nbytes -= self._quant_packing_savings(pool)
            self.stats.note_blocking(nbytes, d2h=False)
        else:
            self.stats.note_async(nbytes, d2h=False)

    # ---------------- admission (chunked) ---------------- #
    @property
    def has_free_lane(self) -> bool:
        # a lane mid-over-prefill whose victim already retired holds no
        # request, but its slot is spoken for — never hand it out twice
        return any(l.request is None and i not in self.prefills
                   for i, l in enumerate(self.lanes))

    def _free_lane(self) -> int:
        for i, l in enumerate(self.lanes):
            if l.request is None and i not in self.prefills:
                return i
        raise RuntimeError("no free lane")

    def _queue_prefill(self, req: Request, lane: int,
                       over: bool = False) -> None:
        prompt = np.asarray(req.prompt, np.int32)
        sp = self._bucket(len(prompt), req.n_tokens)
        if not self.enable_freeze:
            # without freezing nothing ever swaps out, so the whole request
            # must fit in the pool (plus the tail-allocation headroom slot)
            need = -(-(sp + req.n_tokens) // self.page) + 1
            if need > self.P:
                raise ValueError(
                    f"request needs ~{need} pages ({sp} prompt + "
                    f"{req.n_tokens} generated tokens) but the pool holds "
                    f"{self.P} and freezing is disabled (no page ever swaps "
                    f"out); enable freezing or raise max_active_pages")
        self.prefills[lane] = _PendingPrefill(
            req=req, toks=self._left_padded(prompt, sp),
            scratch=MD.init_decode_state(self.cfg, 1, sp), sp=sp, over=over)
        self.events.append({"event": "admit_start", "uid": req.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "prompt_len": len(prompt), "bucket": sp,
                            **({"over": True} if over else {})})

    def _assign_lane(self, req: Request, lane: int) -> None:
        l = self.lanes[lane]
        l.request = req
        l.generated = []
        l.history = []
        l.rewinds = 0
        l.last_rewind_step = -10**9
        req.telemetry = GenerationResult([], [], [], [], [], [], [])

    def admit(self, req: Request, lane: Optional[int] = None) -> int:
        """Begin a chunked admission: reserves a lane and queues the prompt
        for chunk-by-chunk prefill.  Returns immediately — resident lanes
        keep decoding while `step_once` advances the prefill."""
        if lane is None:
            lane = self._free_lane()
        l = self.lanes[lane]
        assert l.request is None, f"lane {lane} is busy"
        assert lane not in self.prefills, f"lane {lane} has a prefill queued"
        self._queue_prefill(req, lane)
        self._assign_lane(req, lane)
        return lane

    def admit_over(self, req: Request, lane: int) -> int:
        """Preempting admission: queue `req`'s chunked prefill against a
        lane whose current occupant keeps decoding.  The prefill runs in a
        scratch cache that never touches the lane's page pool, so the
        victim loses nothing while the preemptor's prompt is processed; at
        install time the victim is suspended (``suspend_lane`` semantics —
        full stash/restore snapshot, surfaced via ``drain_suspended``) and
        the preemptor takes the lane.  This is what makes preemption
        throughput-neutral: the only lane-time the victim ever gives up is
        time the preemptor is actually decoding.  If the victim retires
        before the prefill completes, the install degenerates to a normal
        admission and no snapshot is produced."""
        l = self.lanes[lane]
        assert l.request is not None, \
            f"lane {lane} is free — use admit(), not admit_over()"
        assert lane not in self.prefills, \
            f"lane {lane} already has a prefill queued"
        self._queue_prefill(req, lane, over=True)
        return lane

    def _chunk_sizes(self, sp: int) -> List[int]:
        """Every chunk length a prompt bucket `sp` can hit, over all
        interleaved/burst schedules (small closed set: the schedule only
        ever picks min(prefill_chunk, rem) or the largest power-of-two
        multiple of it that fits rem)."""
        sizes, seen, frontier = set(), set(), {sp}
        while frontier:
            rem = frontier.pop()
            if rem <= 0 or rem in seen:
                continue
            seen.add(rem)
            ci = min(self.prefill_chunk, rem)
            cb = self.prefill_chunk
            while cb * 2 <= rem:
                cb *= 2
            cb = min(cb, rem)
            sizes.update((ci, cb))
            frontier.update((rem - ci, rem - cb))
        return sorted(sizes)

    def warm_prefill(self, prompt_len: int, n_tokens: int) -> None:
        """Pre-compile every prefill-chunk shape a prompt of this length
        can encounter (the burst schedule makes the shape sequence depend
        on engine load, so production warmup must cover the closed set,
        not one observed trace)."""
        sp = self._bucket(prompt_len, n_tokens)
        state = MD.init_decode_state(self.cfg, 1, sp)
        for c in self._chunk_sizes(sp):
            _, state = self._chunk(self.params,
                                   tokens=jnp.zeros((1, c), jnp.int32),
                                   state=state, pos0=jnp.int32(0))

    def _prefill_tick(self, lane: int, busy: bool = True) -> None:
        """Advance one admission by one prompt chunk.

        `busy=False` (no resident lane is decoding) grows the chunk to the
        largest power of two that fits the remainder: fine-grained chunks
        only buy anything when there is decode work to interleave, so an
        empty engine admits at near-whole-prefill speed while a busy one
        keeps the configured interleave granularity.  Chunk lengths stay
        powers of two, so compiles remain O(log max_seq)."""
        pp = self.prefills[lane]
        self._note_kv_peak(self._scratch_bytes())
        rem = pp.sp - pp.done
        c = self.prefill_chunk
        if not busy and self.burst_prefill:
            while c * 2 <= rem:
                c *= 2
        c = min(c, rem)
        chunk = jnp.asarray(pp.toks[:, pp.done:pp.done + c])
        pp.logits, pp.scratch = self._chunk(
            self.params, tokens=chunk, state=pp.scratch,
            pos0=jnp.int32(pp.done))
        pp.done += c
        self.events.append({"event": "prefill_chunk", "uid": pp.req.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "done": pp.done, "total": pp.sp})
        if pp.done >= pp.sp:
            self._install(lane)

    def _install(self, lane: int) -> None:
        """Repack the finished scratch prefill into pages and install them
        into the lane: the newest pages fill the device pool, older pages
        are stashed in the host store (returning as slots free up), and
        `PagedController.write_lane` wholesale-resets exactly this lane."""
        pp = self.prefills.pop(lane)
        if pp.over:
            # install-time preemption: the victim decoded right through the
            # preemptor's prefill; suspend it now (full stash/restore
            # snapshot, picked up via drain_suspended) — unless it already
            # retired, in which case this is a normal install
            if self.lanes[lane].request is not None:
                snap = self._suspend_decode(lane)
                if snap is not None:
                    self._suspended.append(snap)
            self._assign_lane(pp.req, lane)
        sp, page, P, L = pp.sp, self.page, self.P, self.L_attn
        P_total = self.P_total
        # wholesale lane reset first: beyond the pool fields the push below
        # overwrites, this clears the lane's recovery ladder — the decode
        # steps that ran while this admission was in flight advanced the
        # lane's entropy baseline on garbage logits, which must not leak
        # into the new occupant
        self.state = self._reset_lane(state=self.state, lane=jnp.int32(lane))
        # (L, sp, KVH, hd) host repack: one pull per finished prefill (not
        # per step) to slice the scratch cache into pool pages
        # hotpath: ok(once-per-admission install repack, amortized over the request)
        ck = np.array(pp.scratch.cache_k[:, 0])
        # hotpath: ok(once-per-admission install repack, amortized over the request)
        cv = np.array(pp.scratch.cache_v[:, 0])
        n_pages = -(-sp // page)
        pad = n_pages * page - sp
        if pad:
            ck = np.pad(ck, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cv = np.pad(cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ck = ck.reshape(L, n_pages, page, *ck.shape[2:])
        cv = cv.reshape(L, n_pages, page, *cv.shape[2:])
        masks = (np.arange(n_pages * page) < sp).reshape(n_pages, page)
        # newest pages resident (leave one slot free for the next tail);
        # older prompt pages overflow to the host store and cycle back in
        # as the freeze schedule frees slots
        r = min(n_pages, P - 1)
        # write_lane overwrites every byte of the lane slice, so build it
        # host-side instead of pulling the stale device copy first
        kvh, hd = ck.shape[-2:]
        dt = np.dtype(self.state.k.dtype)
        pool = {"k": np.zeros((L, 1, P_total, page, kvh, hd), dt),
                "v": np.zeros((L, 1, P_total, page, kvh, hd), dt),
                "page_table": np.full((L, 1, P_total), -1, np.int32),
                "slot_mask": np.zeros((L, 1, P_total, page), bool),
                "page_quant": np.zeros((L, 1, P_total), np.int32),
                "kv_scales": np.ones((L, 1, P_total, 2, kvh), np.float32)}
        fstate = {"c": np.zeros((L, 1, P_total), np.int32),
                  "d": np.zeros((L, 1, P_total), np.int32),
                  "frozen": np.zeros((L, 1, P_total), bool),
                  "frozen_at": np.zeros((L, 1, P_total), np.int32)}
        # write_lane drops the lane's host store, so overflow pages must be
        # stashed AFTER it or they'd be deleted before decode ever starts
        self.ctl.write_lane(pool, fstate, 0,
                            ck[:, n_pages - r:], cv[:, n_pages - r:],
                            np.arange(n_pages - r, n_pages, dtype=np.int32),
                            masks[n_pages - r:], store_lane=lane)
        # overflow pages are not low-relevance, just oldest-out: timer 1
        # returns each the moment the freeze schedule frees a slot
        for gp in range(n_pages - r):
            for layer in range(L):
                self.ctl.stash(layer, lane, gp, ck[layer, gp], cv[layer, gp],
                               d=1)
        # the last S_stage physical slots start out as the lane's staging
        # slots (write_lane only ever fills slots 0..P-1); drop_lane inside
        # write_lane already forgot any staged keys of the lane's previous
        # occupant
        for layer in range(L):
            self.ctl.stage_slots[(layer, lane)] = \
                list(range(self.P, P_total))
        self._push_lanes(pool, fstate, [lane])
        if sp % page:                       # partial tail page is resident
            self.tail_slot[:, lane] = r - 1
        self.pos[lane] = sp                 # sp % page == 0 -> the boundary
        self.step[lane] = 0                 # alloc runs before the next step
        # first token deferred into the fetch ring: sampling stays on
        # device behind the last prefill chunk; the host commits the
        # value at the next drain, before the first decode dispatch
        self._push_admit_token(lane, pp.req, pp.logits)
        self.events.append({"event": "admit", "uid": pp.req.uid,
                            "lane": lane, "wall_step": self.wall_step})

    # ---------------- stepping ---------------- #
    def _keep_gids(self, lane: int) -> Tuple[int, ...]:
        """Global page ids the host must never evict for this lane: the
        tail page plus the freeze window (the jitted step would just
        re-write / re-attend them)."""
        cp = int(self.pos[lane]) // self.page
        window_pages = max(1, -(-self.fcfg.window // self.page))
        return tuple(range(max(0, cp - window_pages), cp + 1))

    def step_once(self) -> List[Request]:
        """One engine call of the async pipeline: drain the previous
        step's fetch-ring entry (telemetry, thaw requests, page rewinds,
        token commits, retirement), then per-lane page-boundary
        maintenance (ONE batched pull, host swap tick, pending thaws, tail
        allocation, ONE batched push — metadata-only if no K/V moved), a
        jitted paged decode step over the resident lanes with its fetch
        pushed asynchronously behind it, speculative thaw staging, and one
        prefill chunk for every admission in flight.  Returns retired
        requests (from the drain; same-call with ``async_pipeline=False``)."""
        self.stats.begin_step()
        self._ring_guard()
        finished = self._retired_backlog + self._drain_ring()
        self._retired_backlog = []
        decode_lanes = [i for i, l in enumerate(self.lanes)
                        if l.request is not None
                        and (i not in self.prefills or self.prefills[i].over)]
        if decode_lanes:
            boundary = [i for i in decode_lanes if self.pos[i] % self.page == 0]
            if boundary:
                self._boundary_tick(boundary)
            live = np.zeros(self.n_lanes, bool)
            live[decode_lanes] = True
            self._note_kv_peak(self._scratch_bytes())
            logits, self.state, info = self._step(
                self.params, token=jnp.asarray(self.tok),
                pos=jnp.asarray(self.pos), step=jnp.asarray(self.step),
                tail_slot=jnp.asarray(self.tail_slot), state=self.state,
                live=jnp.asarray(live))
            self.wall_step += 1
            keys = ("n_active_slots_lane", "n_frozen_pages_lane", "entropy",
                    "spike", "level", "ema_entropy", "rr_request",
                    "thaw_request")
            arrays = dict(
                {k: info[k] for k in keys if k in info},
                toks=self._sample(logits, jnp.asarray(self.lane_keys),
                                  jnp.asarray(self.step),
                                  *self._lane_params()))
            self.ring.push({"kind": "step", "active": list(decode_lanes),
                            "poison": self._poison_lane(decode_lanes)},
                           arrays)
            # start copying likely-thaw pages into the staging slots while
            # the step computes — by the time an FR thaw fires at a
            # boundary tick, its pages install as a page-table remap
            self._maybe_prefetch(decode_lanes)

        # ---- chunked prefill: one chunk per admission in flight ---- #
        for lane in list(self.prefills):
            self._prefill_tick(lane, busy=bool(decode_lanes))
        if self.ring.depth == 0:
            finished += self._drain_ring()
        if decode_lanes:
            self.stats.end_step()
        else:
            self.stats.cancel_step()
        return finished

    def _boundary_tick(self, boundary: List[int]) -> None:
        """Page-boundary maintenance for `boundary` lanes: one batched
        pull, the host controller pass (timer swaps, pending thaws, tail
        allocation with the force-free backstop), one batched push, then
        the queued device-side staging remaps."""
        self.n_boundary_ticks += 1
        # graceful-degradation ladder, engine-applied rungs: under stash
        # pressure first reclaim redundant host copies of resident pages
        # (stage 1+, parity-free), then deepen the forced-freeze timers so
        # stashed pages return to the device half as fast (stage 2+) —
        # stages 3/4 (admission throttle, lane shed) belong to the
        # scheduler, which reads ``stash_pressure``
        pressure = self.stash_pressure
        if pressure >= self.ladder_cfg.deny_prefetch:
            self.ctl.trim_resident_copies()
        self.ctl.deepen_timers = pressure >= self.ladder_cfg.deepen_timers
        if self.ctl.deepen_timers:
            self.robust["ladder_deepen"] += 1
        self.ctl.begin_tick()
        self._prune_staged()
        pool, fstate = self._pull_lanes(boundary)
        keep = {bi: self._keep_gids(i) for bi, i in enumerate(boundary)}
        thaw = tuple(bi for bi, i in enumerate(boundary)
                     if i in self.pending_thaws)
        self.ctl.tick(pool, fstate, step=self.wall_step,
                      lane_ids=tuple(boundary),
                      thaw_lanes=thaw, keep_gids=keep)
        self.pending_thaws -= set(boundary)
        for bi, i in enumerate(boundary):
            slots = self.ctl.alloc_tail_lane(
                pool, bi, int(self.pos[i]) // self.page, lane_id=i)
            if slots is None and self.enable_freeze:
                # recovery may have un-frozen every page the timer
                # pass would have swapped out; the host is the
                # bound's enforcer of last resort — stash the
                # coldest page and retry
                self.ctl.force_free_slot(pool, fstate, bi, i,
                                         keep_gids=keep[bi])
                slots = self.ctl.alloc_tail_lane(
                    pool, bi, int(self.pos[i]) // self.page, lane_id=i)
            if slots is None:
                raise RuntimeError(
                    f"lane {i}: page pool exhausted"
                    + (" (forced freeze should have kept headroom)"
                       if self.enable_freeze else
                       " — freezing is disabled, so nothing swaps "
                       "out; admission should have rejected this"))
            self.tail_slot[:, i] = slots
        if self.debug_invariants:
            # the one moment the host holds a coherent cross-structure
            # view: post-controller-pass, pre-push
            from repro.analysis import audit_boundary
            audit_boundary(self.ctl, pool, fstate, range(len(boundary)),
                           lane_ids={bi: i for bi, i in enumerate(boundary)})
        self._note_stash_peak()
        self._push_lanes(pool, fstate, boundary, kv=self.ctl.kv_dirty)
        self._run_remaps()

    def _commit_step(self, meta: Dict[str, Any], host: Dict[str, Any]
                     ) -> List[Request]:
        """Apply one drained paged-step entry — the exact sequence (and
        order) the synchronous path ran inline after its blocking fetch:
        telemetry, thaw requests, page-aware rewinds, token commits,
        retirement."""
        decode_lanes = meta["active"]
        get = host.get
        toks = host["toks"]
        act, fro = get("n_active_slots_lane"), get("n_frozen_pages_lane")
        entropy, spike, level = get("entropy"), get("spike"), get("level")
        rr, thaw_req = get("rr_request"), get("thaw_request")
        poison = meta.get("poison")
        if poison is not None and entropy is not None:
            # scheduled logits-anomaly injection (host-side: entropy is
            # computed inside the jitted step, so the commit is where the
            # corrupt value first becomes visible to the host)
            entropy = np.array(entropy, np.float32)
            entropy[poison] = np.nan

        for i in decode_lanes:
            res = self.lanes[i].request.telemetry
            if act is not None:
                res.active_kv.append(float(act[i]) / self.L_attn)
                res.frozen_kv.append(
                    float(fro[i]) * self.page / self.L_attn)
            else:
                res.active_kv.append(float(self.pos[i] + 1))
                res.frozen_kv.append(0.0)
            res.total_kv.append(int(self.pos[i]) + 1)
            res.offloaded_tokens.append(self._offloaded_tokens_lane(i))
            if entropy is not None:
                res.entropy.append(float(entropy[i]))
                if spike is not None and bool(spike[i]):
                    res.recovery_events.append({
                        "step": int(self.step[i]),
                        "level": int(level[i]),
                        "entropy": float(entropy[i]),
                    })
        # thaw-urgency trend for the speculative prefetcher (only the
        # escalation level and the entropy-vs-baseline ratio matter, both
        # of which ride the same ring entry)
        if entropy is not None and get("ema_entropy") is not None:
            from repro.core.recovery import thaw_urgency
            urg = thaw_urgency(level, entropy, get("ema_entropy"))
            for i in decode_lanes:
                self._urgency[i] = urg[i]

        # ---- recovery servicing: host thaws + page-aware rewinds ----
        if thaw_req is not None:
            for i in decode_lanes:
                if bool(thaw_req[i]):
                    # serviced by PagedController.thaw_lane at the
                    # lane's next page-boundary tick
                    self.pending_thaws.add(i)
        rewound = set()
        if rr is not None:
            for i in decode_lanes:
                l = self.lanes[i]
                if bool(rr[i]) and len(l.history) >= self.fcfg.rewalk_tokens \
                        and l.rewinds < self.max_rewinds \
                        and int(self.step[i]) - l.last_rewind_step \
                            >= self.rewind_cooldown \
                        and self._rewind_lane(i):
                    rewound.add(i)

        # ---- lane-level anomaly quarantine (non-finite entropy) ----
        quarantined = self._quarantine_scan(decode_lanes, entropy, rewound)

        finished = list(quarantined)
        for i in decode_lanes:
            if i in rewound:
                continue
            l = self.lanes[i]
            if l.request is None:               # quarantined above
                continue
            t = int(toks[i])
            l.history.append((t, int(self.pos[i])))
            l.generated.append(t)
            self.tok[i] = t
            self.pos[i] += 1
            self.step[i] += 1
            if len(l.generated) >= l.request.n_tokens:
                finished.append(self._retire(i))
        return finished

    # ---------------- speculative thaw staging ---------------- #
    def _prune_staged(self) -> None:
        """Forget staged copies whose host page vanished (rewind drop,
        lane reset) — their staging slots become available again."""
        stale = [k for k in self.ctl.staged_keys
                 if k not in self.ctl.frozen_meta]
        for k in stale:
            del self.ctl.staged_keys[k]

    def _run_remaps(self) -> None:
        """Execute the controller's queued staging-slot remaps as ONE
        batched device-side page copy (staging slot -> the install's
        target slot).  Nothing crosses the host<->device boundary — this
        is what makes a staged thaw "remap-only" — and the consumed
        staging slots are immediately reusable for the next prefetch."""
        remaps = self.ctl.pending_remaps
        self.ctl.pending_remaps = []
        W = self._remap_width
        for i in range(0, len(remaps), W):
            chunk = remaps[i:i + W]
            ls, lanes = np.zeros(W, np.int32), np.zeros(W, np.int32)
            # padding rows self-copy a staging slot — never a real remap's
            # destination, so the batched scatter stays conflict-free
            srcs = np.full(W, self.P, np.int32)
            dsts = np.full(W, self.P, np.int32)
            for j, (l, lane, src, dst) in enumerate(chunk):
                ls[j], lanes[j], srcs[j], dsts[j] = l, lane, src, dst
            self.state = self._remap_copy(
                self.state, jnp.asarray(ls), jnp.asarray(lanes),
                jnp.asarray(srcs), jnp.asarray(dsts))

    def _maybe_prefetch(self, decode_lanes: List[int]) -> None:
        """Dispatch speculative staging uploads for lanes trending toward
        an FR thaw: the highest-urgency lane's top thaw candidates (by
        ``recovery.thaw_priority`` — the exact ranking ``thaw_lane`` will
        use) are copied into its staging slots.  Budget: at most
        ``S_stage`` staged *pages* (gids) per step; each is ONE batched
        dispatch carrying that page's K/V for every attention layer that
        has it stashed, i.e. up to ``S_stage * L_attn`` page-sized
        uploads per step on a deep stack.  The H2D copies are dispatched
        asynchronously behind the decode step; they never change page
        tables, so a misprediction costs bandwidth, not correctness."""
        if not self.S_stage:
            return
        if self.stash_pressure >= self.ladder_cfg.deny_prefetch:
            # ladder stage 1: deny speculative prefetch under stash
            # pressure (staging is pure optimization — thaws fall back to
            # the sync upload path, token-identically)
            self.robust["ladder_deny"] += 1
            return
        if self.ep_stage is not None and not self.ep_stage.allow():
            # tripped stage breaker: speculative staging stays disabled
            # until the breaker's op-count cooldown re-closes it (same
            # token-identical sync-upload fallback)
            return
        # stage for lanes that WILL thaw (request pending, boundary tick
        # not yet reached) and for lanes trending within one spike of FR
        # (urgency >= WR) — looser gating buys little and costs a state
        # dispatch per staged page
        from repro.core.recovery import WR
        cands = [i for i in decode_lanes
                 if i in self.pending_thaws or self._urgency[i] >= WR]
        cands.sort(key=lambda i: (i not in self.pending_thaws,
                                  -self._urgency[i]))
        budget = self.S_stage
        for lane in cands:
            while budget and self._prefetch_lane(lane):
                budget -= 1
            if not budget:
                return

    def _prefetch_lane(self, lane: int) -> bool:
        from repro.core.recovery import thaw_priority
        metas = [(key, m) for key, m in self.ctl.frozen_meta.items()
                 if key[1] == lane]
        if not metas:
            return False
        gid_score: Dict[int, float] = {}
        for (l, _, gid), m in metas:
            s = thaw_priority(m["c"], m["frozen_at"])
            gid_score[gid] = max(gid_score.get(gid, -np.inf), s)
        staged_gids = {k[2] for k in self.ctl.staged_keys if k[1] == lane}
        occupied = {}
        for k, slot in self.ctl.staged_keys.items():
            if k[1] == lane:
                occupied.setdefault(k[0], set()).add(slot)
        # canonical tie-break (gid) mirrors thaw_lane's: the staging
        # schedule must be invariant to frozen_meta insertion order, which
        # a suspend/resume migration rebuilds
        want = sorted(gid_score,
                      key=lambda g: (-gid_score[g], g))[:self.S_stage]
        page, kvh, hd = self.state.k.shape[3:]
        for gid in want:
            if gid in staged_gids:
                continue
            slots = np.full(self.L_attn, -1, np.int32)
            valid = np.zeros(self.L_attn, bool)
            k_buf = self.staging.buf("stage_k",
                                     (self.L_attn, page, kvh, hd),
                                     np.dtype(self.state.k.dtype))
            v_buf = self.staging.buf("stage_v",
                                     (self.L_attn, page, kvh, hd),
                                     np.dtype(self.state.v.dtype))
            sent = 0
            for l in range(self.L_attn):
                key = (l, lane, gid)
                if key not in self.ctl.frozen_meta:
                    continue
                avail = [s for s in self.ctl.stage_slots.get((l, lane), [])
                         if s not in occupied.get(l, ())]
                if not avail:
                    continue
                # a quantized store entry is a 1-byte payload; assigning it
                # into the pool-dtype buffer widens the integer values
                # exactly (the kernel dequantizes once the page is mapped,
                # scales riding the metadata push)
                kk, vv = self.ctl.store[key]
                k_buf[l] = kk
                v_buf[l] = vv
                sent += kk.nbytes + vv.nbytes
                slots[l] = avail[0]
                valid[l] = True
            if not valid.any():
                continue
            # the dispatch closure runs exactly once per endpoint call
            # (injection precedes it); a best-effort failure returns
            # FAILED with the state untouched — the thaw just won't be
            # staged, and installs fall back to the sync upload path
            def _dispatch():
                return self._stage_write(
                    self.state, jnp.int32(lane), jnp.asarray(slots),
                    jnp.asarray(k_buf), jnp.asarray(v_buf),
                    jnp.asarray(valid))
            if self.ep_stage is not None:
                out = self.ep_stage.call(_dispatch)
                if out is Endpoint.FAILED:
                    return False
                self.state = out
            else:
                self.state = _dispatch()
            for l in range(self.L_attn):
                if valid[l]:
                    self.ctl.staged_keys[(l, lane, gid)] = int(slots[l])
            # count what the host store actually holds — a quantized page
            # crosses the bus packed (1 byte/elem), not pool-width
            self.stats.note_async(sent, d2h=False)
            return True
        return False

    def _rewind_lane(self, lane: int) -> bool:
        """Rewalk Regeneration on the paged path: rewind ``rewalk_tokens``,
        invalidate the rewound KV slots on device, and make the surviving
        tail page attendable again.  Pages wholly past the rewind point
        unmap (a boundary-landing rewind leaves tail re-allocation to the
        next page-boundary tick) and their stale host copies are dropped —
        the replayed pages must never collide with a stashed copy of the
        rewound generation.  Returns False (rewind skipped, nothing
        mutated) if the tail page cannot be made resident.

        The in-flight fetch (async pipeline) is consumed first: its commit
        carries a token for the PRE-rewind position, and applying it after
        the surgery below would clobber the rewound clocks and replay
        token.  Draining makes the host bookkeeping current at the
        injection point in both pipeline modes (re-entrant calls from
        ``_commit_step``'s RR path see an already-empty ring — no-op)."""
        self._retired_backlog += self._drain_ring()
        l = self.lanes[lane]
        if l.request is None:        # the drained commit retired this lane
            return False
        nback = self.fcfg.rewalk_tokens
        new_pos = int(self.pos[lane]) - nback
        if new_pos <= 0:
            return False
        gid_t = new_pos // self.page
        window_pages = max(1, -(-self.fcfg.window // self.page))
        keep = tuple(range(max(0, gid_t - window_pages), gid_t + 1))
        if new_pos % self.page:
            # mid-page landing: the tail page must be resident + un-frozen
            # in every layer before decode resumes (it may have been
            # frozen or even stashed if the freeze window is one page)
            self.ctl.begin_tick()
            self._prune_staged()
            pool, fstate = self._pull_lanes([lane])
            ok = self.ctl.ensure_resident(pool, fstate, 0, lane, gid_t,
                                          keep_gids=keep)
            # push back even on failure: a partial layer's thaw/eviction
            # mutated both the pulled copies and the controller's host
            # bookkeeping, and dropping the copies would desynchronize
            # them (duplicate swap-ins / unreachable host pages)
            self._push_lanes(pool, fstate, [lane], kv=self.ctl.kv_dirty)
            self._run_remaps()
            if not ok:
                return False
            for lyr in range(self.L_attn):
                slot = np.nonzero(pool["page_table"][lyr, 0] == gid_t)[0]
                self.tail_slot[lyr, lane] = int(slot[0])
        self.state = self._rewind(state=self.state, lane=jnp.int32(lane),
                                  new_pos=jnp.int32(new_pos))
        self.ctl.drop_pages_from(lane, -(-new_pos // self.page))
        self._rewind_bookkeeping(lane)
        self.events.append({"event": "rewind", "uid": l.request.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "new_pos": new_pos})
        return True

    def _quarantine_rewind(self, lane: int) -> bool:
        return self._rewind_lane(lane)

    # ---------------- preemption (suspend / resume) ---------------- #
    def suspend_lane(self, lane: int) -> Optional[LaneSnapshot]:
        """Freeze-native preemption: force-stash the lane's entire device
        residency and free the lane without losing any decode progress.

        The snapshot owns (1) the lane's full pool slice — K/V pages,
        page table, slot masks and page-freeze counters, pulled in the
        same ONE batched transfer a boundary tick uses — (2) the lane's
        recovery-ladder scalars, and (3) every host-stashed page, *moved
        out of* the ``PagedController`` store (``export_lane``) so
        reassigning the lane cannot ``drop_lane`` them.  ``resume_lane``
        pushes the slice back verbatim (possibly into a different lane),
        so the continuation is **token-identical** to the uninterrupted
        run — preemption costs two pool-slice transfers, never a
        re-prefill.

        An admission still mid-chunked-prefill is cancelled instead (no
        decode progress exists yet): the snapshot re-admits from scratch.
        On a lane mid-``admit_over`` this suspends the decoding VICTIM and
        leaves the preemptor's prefill queued (it then installs into the
        freed lane as a normal admission).  Returns None when the request
        retired while the in-flight fetch drained (the retirement is
        re-reported by the next ``step_once``)."""
        self.flush()
        l = self.lanes[lane]
        pp = self.prefills.get(lane)
        if pp is not None and not pp.over:
            if l.request is None:
                return None
            self.prefills.pop(lane)
            snap = LaneSnapshot(req=pp.req, generated=[], history=[],
                                pos=0, step=0, tok=self.pad_id,
                                rewinds=0, last_rewind_step=-10**9)
            self.events.append({"event": "suspend", "uid": pp.req.uid,
                                "lane": lane, "wall_step": self.wall_step,
                                "generated": 0})
            self.ctl.drop_lane(lane)
            self._park_lane(lane)
            return snap
        return self._suspend_decode(lane)

    def _suspend_decode(self, lane: int) -> Optional[LaneSnapshot]:
        """The decode-lane suspension core shared by ``suspend_lane`` and
        the install-time preemption of ``admit_over``: flush, snapshot,
        stash, free."""
        self.flush()
        l = self.lanes[lane]
        if l.request is None:
            return None
        snap = self._snap_host(lane)
        # speculative staged copies survive the lane changing hands: the
        # pulled pool slice spans all P_total slots (staging included) and
        # every lane reserves the same [P, P_total) staging range, so the
        # slice push restores the bytes verbatim on any destination lane.
        # The slot bookkeeping rides the export (4th tuple element) —
        # dropping it here is what used to break ≥4-cycle parity under
        # recovery: a forgotten staged page de-scheduled the resumed
        # lane's thaw remap, and the timing shift fed an
        # entropy-triggered Rewalk a different path
        # (docs/robustness.md parity envelope)
        pool, fstate = self._pull_lanes([lane])
        # deep-copy out of the reused staging buffers — the next pull
        # overwrites them, the snapshot may outlive many ticks
        snap.pool = {f: a.copy() for f, a in pool.items()}
        snap.fstate = {f: a.copy() for f, a in fstate.items()}
        rec = jax.device_get(self.state.recovery)
        snap.recovery = {f: np.asarray(a)[lane].item()
                         for f, a in zip(RecoveryState._fields, rec)}
        snap.tail_slot = self.tail_slot[:, lane].copy()
        snap.stashed = self.ctl.export_lane(lane)
        snap.pending_thaw = lane in self.pending_thaws
        snap.urgency = float(self._urgency[lane])
        self.events.append({"event": "suspend", "uid": snap.req.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "generated": len(snap.generated),
                            "stashed_pages": len(snap.stashed)})
        # free the lane: unmap on device, clear host bookkeeping
        self.state = self._reset_lane(state=self.state, lane=jnp.int32(lane))
        self.ctl.drop_lane(lane)
        self.pending_thaws.discard(lane)
        self._urgency[lane] = 0.0
        self._park_lane(lane)
        return snap

    def resume_lane(self, snap: LaneSnapshot,
                    lane: Optional[int] = None) -> int:
        """Re-admit a suspended request via the stash/restore path — no
        re-prefill.  The snapshot's host-store pages are rekeyed to the
        destination lane (``import_lane``), its pool slice is pushed back
        byte-identical (same physical slot layout → same float summation
        order downstream → token parity with the uninterrupted run), and
        the recovery-ladder scalars, tail slots, clocks and the
        snapshot-stable sampling key are restored."""
        if not snap.started:
            return self.admit(snap.req, lane)
        self._retired_backlog += self._drain_ring()
        if lane is None:
            lane = self._free_lane()
        l = self.lanes[lane]
        assert l.request is None, f"lane {lane} is busy"
        assert lane not in self.prefills, f"lane {lane} has a prefill queued"
        # host store first: thaw/swap bookkeeping must see the pages the
        # pushed page table expects to find stashed.  A checkpoint
        # snapshot's bytes were never moved out of the controller's
        # accounting, so nothing moves back (counted=False)
        self.ctl.import_lane(lane, snap.stashed, counted=snap.exported)
        self._push_lanes(snap.pool, snap.fstate, [lane])
        # the snapshot's pool slice may carry quantized resident pages —
        # rebuild the destination lane's packed-residency ledger
        self.ctl.refresh_resident_quant(snap.pool, 0, lane)
        for lyr in range(self.L_attn):
            self.ctl.stage_slots[(lyr, lane)] = \
                list(range(self.P, self.P_total))
        r = snap.recovery
        self.state = self._set_recovery(
            self.state, jnp.int32(lane),
            jnp.float32(r["ema_entropy"]), jnp.int32(r["level"]),
            jnp.int32(r["calm_steps"]), jnp.int32(r["steps_seen"]))
        self.tail_slot[:, lane] = snap.tail_slot
        self._restore_host(snap, lane)
        if snap.pending_thaw:
            self.pending_thaws.add(lane)
        self._urgency[lane] = snap.urgency
        self.events.append({"event": "resume", "uid": snap.req.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "stashed_pages": len(snap.stashed)})
        return lane

    def cancel_request(self, uid: int) -> Optional[Request]:
        """Paged cancellation also reaches a preemptor still running its
        ``admit_over`` chunked prefill: the prefill's scratch cache never
        touched the lane's page pool, so dropping the pending prefill is
        the whole cancellation — the victim keeps decoding, undisturbed."""
        for lane, pp in list(self.prefills.items()):
            if pp.req.uid == uid and pp.over:
                self.prefills.pop(lane)
                req = pp.req
                req.status = RequestStatus.CANCELLED
                req.result = np.zeros(0, np.int32)
                self.events.append({"event": "cancel", "uid": uid,
                                    "lane": lane,
                                    "wall_step": self.wall_step,
                                    "generated": 0})
                return req
        return super().cancel_request(uid)

    def discard_snapshot(self, snap: LaneSnapshot) -> None:
        """A suspended paged request that will never resume still owns
        its exported host-stash pages (``export_lane`` moved them OUT of
        the controller store precisely so lane reuse could not drop
        them).  Dropping the snapshot without this call leaks both the
        page bytes and the ``exported_bytes`` gauge they are counted
        under — the budget ladder would see phantom pressure forever.
        Checkpoint snapshots (``exported=False``) never moved accounting
        out of the controller, so dropping them is free."""
        if snap.stashed and snap.exported:
            self.ctl.release_exported(snap.stashed)
        snap.stashed = None

    def checkpoint_lane(self, lane: int) -> Optional[LaneSnapshot]:
        """Non-destructive ``_suspend_decode``: capture a resume-exact
        snapshot of a decoding lane WITHOUT freeing it — the replica
        router's periodic checkpoint, mirrored off-engine so a crashed
        replica's lanes can be re-placed on a survivor token-identically
        from the last checkpoint.

        The lane keeps running; the controller keeps owning its host
        store (``copy_lane`` shares the immutable page payloads and
        copies the mutable freeze metas), so ``exported_bytes`` does not
        move — the snapshot is marked ``exported=False`` and both
        ``resume_lane`` and ``discard_snapshot`` skip the accounting they
        would move back for a real export.  Returns None for an idle lane
        or one still mid-chunked-prefill (no decode progress to
        checkpoint — failover re-prefills those)."""
        self.flush()
        l = self.lanes[lane]
        pp = self.prefills.get(lane)
        if l.request is None or (pp is not None and not pp.over):
            return None
        snap = self._snap_host(lane)
        pool, fstate = self._pull_lanes([lane])
        snap.pool = {f: a.copy() for f, a in pool.items()}
        snap.fstate = {f: a.copy() for f, a in fstate.items()}
        rec = jax.device_get(self.state.recovery)
        snap.recovery = {f: np.asarray(a)[lane].item()
                         for f, a in zip(RecoveryState._fields, rec)}
        snap.tail_slot = self.tail_slot[:, lane].copy()
        snap.stashed = self.ctl.copy_lane(lane)
        snap.pending_thaw = lane in self.pending_thaws
        snap.urgency = float(self._urgency[lane])
        snap.exported = False
        self.events.append({"event": "checkpoint", "uid": snap.req.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "generated": len(snap.generated),
                            "stashed_pages": len(snap.stashed)})
        return snap

    def _retire(self, lane: int) -> Request:
        l = self.lanes[lane]
        req = l.request
        req.result = np.asarray(l.generated[: req.n_tokens], np.int32)
        req.telemetry.tokens = req.result[None, :]
        self._finalize_status(req)
        self.events.append({"event": "finish", "uid": req.uid, "lane": lane,
                            "wall_step": self.wall_step})
        l.request = None
        l.generated = []
        l.history = []
        # unmap the lane's pages on device (attention skips them), drop its
        # host store, staged prefetches and any pending thaw so nothing
        # leaks into the lane's next occupant
        self.state = self._reset_lane(state=self.state, lane=jnp.int32(lane))
        self.ctl.drop_lane(lane)
        self.pending_thaws.discard(lane)
        self._urgency[lane] = 0.0
        self._set_lane_sampling(lane, SamplingParams.greedy())
        return req

"""ASR-KF-EGR serving engine: the host-side generation loop wrapping the
jitted prefill / decode steps.

Responsibilities beyond the jitted step:
  * page-batched host offload of fully-frozen KV pages (the paper's
    "frozen storage F" — cache.HostOffloadController)
  * Rewalk Regeneration (recovery level 4): rewind `rewalk_tokens`, clear
    freeze state (FR already applied in-step), re-decode
  * telemetry: active/frozen KV trajectory (paper Fig. 1), compression
    ratio (Table 1), entropy/recovery events
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FreezeConfig, ModelConfig
from repro.core.cache import HostOffloadController
from repro.models import model as MD
from repro.serving.sampling import SamplingParams, sample


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray                 # (B, n_generated)
    # per-step telemetry (paper Fig. 1 / Table 1)
    active_kv: List[float]             # mean active slots per layer/seq
    frozen_kv: List[float]
    total_kv: List[int]
    entropy: List[float]
    recovery_events: List[Dict[str, Any]]
    offloaded_tokens: List[int]
    rewinds: int = 0

    @property
    def compression(self) -> float:
        """Paper Table 1: 1 - active/total at the final step."""
        if not self.active_kv:
            return 0.0
        return 1.0 - self.active_kv[-1] / max(self.total_kv[-1], 1)


class Engine:
    """Batched generation with ASR-KF-EGR freeze management."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int,
                 freeze_cfg: Optional[FreezeConfig] = None,
                 enable_freeze: bool = True,
                 offload: bool = True,
                 max_rewinds: int = 4,
                 rewind_cooldown: int = 32):
        self.max_rewinds = max_rewinds
        self.rewind_cooldown = rewind_cooldown
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.fcfg = freeze_cfg or cfg.freeze
        self.enable_freeze = enable_freeze
        self.offload = offload and enable_freeze
        self._prefill = jax.jit(
            functools.partial(MD.prefill, cfg=cfg))
        self._step = jax.jit(functools.partial(
            MD.decode_step, cfg=cfg, freeze_cfg=self.fcfg,
            enable_freeze=enable_freeze))

    def generate(self, batch: Dict[str, jnp.ndarray], n_tokens: int,
                 sampling: SamplingParams = SamplingParams(),
                 seed: int = 0) -> GenerationResult:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S0 = tokens.shape
        assert S0 + n_tokens <= self.max_seq
        state = MD.init_decode_state(cfg, B, self.max_seq)
        logits, state = self._prefill(self.params, batch=batch, state=state)
        key = jax.random.PRNGKey(seed)
        res = GenerationResult([], [], [], [], [], [], [])
        offloader = HostOffloadController(self.fcfg.page_size) \
            if self.offload else None

        out_tokens = []
        history: List[jnp.ndarray] = []   # (token, pos) for rewind
        pos, step = S0, 0
        last_rewind_step = -10**9
        key, sub = jax.random.split(key)
        tok = sample(logits, sub, sampling)
        out_tokens.append(np.asarray(tok))
        while len(out_tokens) < n_tokens:
            logits, state, info = self._step(
                self.params, token=tok, pos=jnp.int32(pos),
                step=jnp.int32(step), state=state)
            # ---- telemetry ----
            n_layers_attn = max(state.freeze.frozen.shape[0], 1) \
                if hasattr(state, "freeze") else 1
            if "n_active" in info:
                denom = n_layers_attn * B
                res.active_kv.append(float(jnp.sum(info["n_active"])) / denom)
                res.frozen_kv.append(float(jnp.sum(info["n_frozen"])) / denom)
            else:
                res.active_kv.append(float(pos + 1))
                res.frozen_kv.append(0.0)
            res.total_kv.append(pos + 1)
            if "entropy" in info:
                res.entropy.append(float(jnp.mean(info["entropy"])))
                if bool(jnp.any(info["spike"])):
                    res.recovery_events.append({
                        "step": step,
                        "level": int(jnp.max(info["level"])),
                        "entropy": float(jnp.max(info["entropy"])),
                    })
            # ---- Rewalk Regeneration (recovery level 4) ----
            if "rr_request" in info and bool(jnp.any(info["rr_request"])) \
                    and len(history) >= self.fcfg.rewalk_tokens \
                    and res.rewinds < self.max_rewinds \
                    and step - last_rewind_step >= self.rewind_cooldown:
                nback = self.fcfg.rewalk_tokens
                del history[-nback:]
                del out_tokens[-nback:]
                pos -= nback
                res.rewinds += 1
                last_rewind_step = step
                tok = history[-1][0] if history else tok
                step += 1
                continue
            # ---- host offload of fully-frozen pages ----
            if offloader is not None and step % 8 == 7:
                from repro.core.cache import KVCache
                cache = KVCache(k=state.cache_k, v=state.cache_v)
                cache = offloader.sync(cache, np.asarray(state.freeze.frozen))
                state = state._replace(cache_k=cache.k, cache_v=cache.v)
            res.offloaded_tokens.append(
                offloader.offloaded_tokens if offloader else 0)

            key, sub = jax.random.split(key)
            tok = sample(logits, sub, sampling)
            history.append((tok, pos))
            out_tokens.append(np.asarray(tok))
            pos += 1
            step += 1
        res.tokens = np.stack(out_tokens, axis=1)
        return res

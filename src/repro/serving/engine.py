"""ASR-KF-EGR serving engines.

Three generation drivers share the jitted prefill / decode-step cores:

* ``Engine`` — static one-shot batched generation: every lane starts
  together and runs for the same number of steps (benchmark arms, examples,
  the paper's Table 1 protocol).

* ``ContinuousEngine`` — continuous batching over a dense per-lane cache:
  a jitted per-step core with **per-lane** ``pos`` / ``step`` vectors plus
  a host-side lane manager.  Lanes admit a new request the moment their
  current one retires — mid-generation, without draining the batch — via a
  per-lane prefill-into-slot (``model.write_lane_state``).  Admission
  overwrites the lane's KV / freeze / recovery state wholesale, so no
  freeze counters or entropy baselines leak between requests sharing a
  lane.

* ``PagedContinuousEngine`` — the bounded-HBM production path: decode
  attends only each lane's O(P * page) device page pool, long prompts
  prefill in chunks interleaved with resident decode, frozen/overflow
  pages live in the host store, and entropy-guided recovery runs
  page-granular (stashed-page thaws + page-aware rewinds).

Host-side responsibilities beyond the jitted step (all drivers):
  * host residency of fully-frozen KV (the paper's "frozen storage F"):
    page-batched offload on the dense paths (cache.HostOffloadController)
    and per-page swap/stash/thaw on the paged path
    (core.paging.PagedController) — bookkeeping keyed per (layer, lane,
    page) so lane reuse can drop exactly its own pages
  * Rewalk Regeneration (recovery level 4): rewind ``rewalk_tokens``,
    clear freeze state (FR already applied in-step), re-decode — history,
    rewind budget and cooldown are tracked per lane; the paged path also
    invalidates the rewound KV slots / pages on device
  * telemetry: active/frozen KV trajectory (paper Fig. 1), compression
    ratio (Table 1), entropy/recovery events — one append per lane-step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FreezeConfig, ModelConfig
from repro.core.cache import HostOffloadController, KVCache
from repro.core.paging import PagedController, PageFreezeState
from repro.models import model as MD
from repro.serving.sampling import (SamplingParams, params_arrays, sample,
                                    sample_batched)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray                 # (B, n_generated)
    # per-step telemetry (paper Fig. 1 / Table 1)
    active_kv: List[float]             # mean active slots per layer/seq
    frozen_kv: List[float]
    total_kv: List[int]
    entropy: List[float]
    recovery_events: List[Dict[str, Any]]
    offloaded_tokens: List[int]
    rewinds: int = 0

    @property
    def compression(self) -> float:
        """Paper Table 1: 1 - active/total at the final step."""
        if not self.active_kv:
            return 0.0
        return 1.0 - self.active_kv[-1] / max(self.total_kv[-1], 1)


@dataclasses.dataclass
class Request:
    """One generation request, as seen by the scheduler and lane manager."""
    uid: int
    prompt: np.ndarray            # (S,) int32
    n_tokens: int
    sampling: SamplingParams = SamplingParams()
    result: Optional[np.ndarray] = None
    telemetry: Optional[GenerationResult] = None


class Engine:
    """Static batched generation with ASR-KF-EGR freeze management."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int,
                 freeze_cfg: Optional[FreezeConfig] = None,
                 enable_freeze: bool = True,
                 offload: bool = True,
                 max_rewinds: int = 4,
                 rewind_cooldown: int = 32):
        self.max_rewinds = max_rewinds
        self.rewind_cooldown = rewind_cooldown
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.fcfg = freeze_cfg or cfg.freeze
        self.enable_freeze = enable_freeze
        self.offload = offload and enable_freeze
        # donate the decode state: KV / freeze buffers are updated in place
        # instead of double-buffered in HBM (on backends without donation
        # support, e.g. CPU, JAX falls back to copies with a warning)
        self._prefill = jax.jit(
            functools.partial(MD.prefill, cfg=cfg),
            donate_argnames=("state",))
        self._step = jax.jit(functools.partial(
            MD.decode_step, cfg=cfg, freeze_cfg=self.fcfg,
            enable_freeze=enable_freeze), donate_argnames=("state",))

    def generate(self, batch: Dict[str, jnp.ndarray], n_tokens: int,
                 sampling: SamplingParams = SamplingParams(),
                 seed: int = 0) -> GenerationResult:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S0 = tokens.shape
        assert S0 + n_tokens <= self.max_seq
        state = MD.init_decode_state(cfg, B, self.max_seq)
        logits, state = self._prefill(self.params, batch=batch, state=state)
        key = jax.random.PRNGKey(seed)
        res = GenerationResult([], [], [], [], [], [], [])
        offloader = HostOffloadController(self.fcfg.page_size) \
            if self.offload else None

        out_tokens = []
        history: List[jnp.ndarray] = []   # (token, pos) for rewind
        pos, step = S0, 0
        last_rewind_step = -10**9
        key, sub = jax.random.split(key)
        tok = sample(logits, sub, sampling)
        out_tokens.append(np.asarray(tok))
        while len(out_tokens) < n_tokens:
            logits, state, info = self._step(
                self.params, token=tok, pos=jnp.int32(pos),
                step=jnp.int32(step), state=state)
            # ---- telemetry (every list appends exactly once per step) ----
            n_layers_attn = max(state.freeze.frozen.shape[0], 1) \
                if hasattr(state, "freeze") else 1
            if "n_active" in info:
                denom = n_layers_attn * B
                res.active_kv.append(float(jnp.sum(info["n_active"])) / denom)
                res.frozen_kv.append(float(jnp.sum(info["n_frozen"])) / denom)
            else:
                res.active_kv.append(float(pos + 1))
                res.frozen_kv.append(0.0)
            res.total_kv.append(pos + 1)
            if "entropy" in info:
                res.entropy.append(float(jnp.mean(info["entropy"])))
                if bool(jnp.any(info["spike"])):
                    res.recovery_events.append({
                        "step": step,
                        "level": int(jnp.max(info["level"])),
                        "entropy": float(jnp.max(info["entropy"])),
                    })
            # ---- Rewalk Regeneration (recovery level 4) ----
            if "rr_request" in info and bool(jnp.any(info["rr_request"])) \
                    and len(history) >= self.fcfg.rewalk_tokens \
                    and res.rewinds < self.max_rewinds \
                    and step - last_rewind_step >= self.rewind_cooldown:
                nback = self.fcfg.rewalk_tokens
                del history[-nback:]
                del out_tokens[-nback:]
                pos -= nback
                res.rewinds += 1
                last_rewind_step = step
                # the input at the rewind point: the last surviving history
                # entry, or the prefill-sampled first token when the rewind
                # consumed the whole history (out_tokens[0] survives)
                tok = history[-1][0] if history \
                    else jnp.asarray(out_tokens[-1])
                step += 1
                res.offloaded_tokens.append(
                    offloader.offloaded_tokens if offloader else 0)
                continue
            # ---- host offload of fully-frozen pages ----
            if offloader is not None and step % 8 == 7:
                cache = KVCache(k=state.cache_k, v=state.cache_v)
                cache = offloader.sync(cache, np.asarray(state.freeze.frozen))
                state = state._replace(cache_k=cache.k, cache_v=cache.v)
            res.offloaded_tokens.append(
                offloader.offloaded_tokens if offloader else 0)

            key, sub = jax.random.split(key)
            tok = sample(logits, sub, sampling)
            history.append((tok, pos))
            out_tokens.append(np.asarray(tok))
            pos += 1
            step += 1
        res.tokens = np.stack(out_tokens, axis=1)
        return res


# ===================================================================== #
# Continuous batching
# ===================================================================== #
@dataclasses.dataclass
class _Lane:
    """Host-side bookkeeping for one batch slot of the jitted step."""
    request: Optional[Request] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    history: List[Tuple[int, int]] = \
        dataclasses.field(default_factory=list)      # (token, pos) for rewind
    rewinds: int = 0
    last_rewind_step: int = -10**9


class _LaneEngineBase:
    """Shared lane management for the continuous-batching engines: lane
    accounting, prompt bucketing, per-lane sampling-parameter mirrors and
    the admit/finish event log.  Subclasses own the decode state layout
    (contiguous vs paged) and the step/admission mechanics."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int, n_lanes: int,
                 freeze_cfg: Optional[FreezeConfig] = None,
                 enable_freeze: bool = True,
                 pad_id: int = 0,
                 seed: int = 0,
                 min_prompt_bucket: int = 8):
        assert not cfg.is_encoder_decoder, \
            "continuous batching is decoder-only (enc-dec uses Engine)"
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.n_lanes = n_lanes
        self.fcfg = freeze_cfg or cfg.freeze
        self.enable_freeze = enable_freeze
        self.pad_id = pad_id
        self.min_prompt_bucket = min_prompt_bucket
        self._sample = jax.jit(sample_batched)
        self.lanes = [_Lane() for _ in range(n_lanes)]
        self.pos = np.zeros(n_lanes, np.int32)
        self.step = np.zeros(n_lanes, np.int32)
        self.tok = np.full(n_lanes, pad_id, np.int32)
        greedy = SamplingParams.greedy()
        self._temp, self._topk, self._topp = (
            np.array(a) for a in params_arrays([greedy] * n_lanes))
        self._lane_params_dev = None     # device mirror, refreshed on admit
        self.key = jax.random.PRNGKey(seed)
        self.wall_step = 0          # number of jitted decode steps issued
        self.events: List[Dict[str, Any]] = []   # admit / finish log
        self.peak_kv_bytes = 0      # high-water device KV (incl. prefill
                                    # scratch) — the benchmark memory metric

    @property
    def kv_device_bytes(self) -> int:       # subclasses override
        return 0

    def _note_kv_peak(self, scratch_bytes: int = 0) -> None:
        self.peak_kv_bytes = max(self.peak_kv_bytes,
                                 self.kv_device_bytes + scratch_bytes)

    # ---------------- lane accounting ---------------- #
    @property
    def n_active_lanes(self) -> int:
        return sum(1 for l in self.lanes if l.request is not None)

    @property
    def has_free_lane(self) -> bool:
        return any(l.request is None for l in self.lanes)

    def _free_lane(self) -> int:
        for i, l in enumerate(self.lanes):
            if l.request is None:
                return i
        raise RuntimeError("no free lane")

    def _bucket(self, prompt_len: int, n_tokens: int) -> int:
        """Pad the prompt to a power-of-two bucket (bounded prefill
        recompiles), falling back to the exact length when the bucket
        would not leave room for generation."""
        b = self.min_prompt_bucket
        while b < prompt_len:
            b *= 2
        if b + n_tokens > self.max_seq:
            b = prompt_len
        if b + n_tokens > self.max_seq:
            raise ValueError(
                f"request needs {prompt_len} prompt + {n_tokens} generated "
                f"slots but the engine was built with max_seq={self.max_seq}")
        return b

    def _set_lane_sampling(self, lane: int, sp: SamplingParams) -> None:
        self._temp[lane] = sp.temperature
        self._topk[lane] = sp.top_k
        self._topp[lane] = sp.top_p
        self._lane_params_dev = None

    def _lane_params(self):
        if self._lane_params_dev is None:
            self._lane_params_dev = (jnp.asarray(self._temp),
                                     jnp.asarray(self._topk),
                                     jnp.asarray(self._topp))
        return self._lane_params_dev

    def _left_padded(self, prompt: np.ndarray, sp: int) -> np.ndarray:
        toks = np.full((1, sp), self.pad_id, np.int32)
        toks[0, sp - len(prompt):] = prompt
        return toks

    def _rewind_bookkeeping(self, lane: int) -> None:
        """Shared RR host bookkeeping: truncate the rolled-back tokens,
        charge the lane's rewind budget/cooldown, and restore the input
        token at the rewind point — the last surviving history entry, or
        the admission-time first token (``generated[0]`` survives the
        truncation) when the rewind consumed the whole history.  The
        contiguous and paged engines must stay semantically identical
        here — the paged-vs-contiguous parity test depends on it."""
        l = self.lanes[lane]
        nback = self.fcfg.rewalk_tokens
        del l.history[-nback:]
        del l.generated[-nback:]
        self.pos[lane] -= nback
        l.rewinds += 1
        l.last_rewind_step = int(self.step[lane])
        l.request.telemetry.rewinds += 1
        self.tok[lane] = l.history[-1][0] if l.history else l.generated[-1]
        self.step[lane] += 1


class ContinuousEngine(_LaneEngineBase):
    """Continuous-batching generation: per-lane admission and retirement.

    The jitted step always runs the full ``n_lanes``-wide batch (fixed
    shapes, one compile); idle lanes decode garbage that the host ignores.
    Prompt lengths are padded to power-of-two buckets so the per-lane
    prefill compiles O(log max_seq) times, not once per prompt length.
    """

    def __init__(self, cfg: ModelConfig, params, max_seq: int, n_lanes: int,
                 freeze_cfg: Optional[FreezeConfig] = None,
                 enable_freeze: bool = True,
                 offload: bool = True,
                 max_rewinds: int = 4,
                 rewind_cooldown: int = 32,
                 pad_id: int = 0,
                 offload_every: int = 8,
                 seed: int = 0,
                 min_prompt_bucket: int = 8,
                 debug_lane_checks: bool = False):
        super().__init__(cfg, params, max_seq, n_lanes,
                         freeze_cfg=freeze_cfg, enable_freeze=enable_freeze,
                         pad_id=pad_id, seed=seed,
                         min_prompt_bucket=min_prompt_bucket)
        self.max_rewinds = max_rewinds
        self.rewind_cooldown = rewind_cooldown
        self.offload_every = offload_every
        self.debug_lane_checks = debug_lane_checks
        # donated decode state: the per-step KV/freeze buffers are reused in
        # place rather than double-buffered in HBM (no-op on CPU)
        self._prefill = jax.jit(functools.partial(MD.prefill, cfg=cfg),
                                donate_argnames=("state",))
        self._step = jax.jit(functools.partial(
            MD.decode_step, cfg=cfg, freeze_cfg=self.fcfg,
            enable_freeze=enable_freeze), donate_argnames=("state",))
        self._write_lane = jax.jit(functools.partial(MD.write_lane_state, cfg),
                                   donate_argnames=("state", "lane_state"))
        self.state = MD.init_decode_state(cfg, n_lanes, max_seq)
        self.offloader = HostOffloadController(self.fcfg.page_size) \
            if (offload and enable_freeze) else None

    @classmethod
    def from_engine(cls, engine: Engine, n_lanes: int,
                    **kw) -> "ContinuousEngine":
        """Build a continuous engine sharing a static Engine's model and
        freeze settings (the Scheduler's compatibility path)."""
        return cls(engine.cfg, engine.params, engine.max_seq, n_lanes,
                   freeze_cfg=engine.fcfg,
                   enable_freeze=engine.enable_freeze,
                   offload=engine.offload,
                   max_rewinds=engine.max_rewinds,
                   rewind_cooldown=engine.rewind_cooldown, **kw)

    @property
    def kv_device_bytes(self) -> int:
        """Live device KV footprint (the benchmark's peak-memory metric)."""
        return self.state.cache_k.nbytes + self.state.cache_v.nbytes

    # ---------------- admission ---------------- #
    def admit(self, req: Request, lane: Optional[int] = None) -> int:
        """Prefill `req` into a free lane mid-stream.  The single-lane
        prefill state is scattered over the lane's slice of the batched
        decode state, which wholesale-resets its KV cache, freeze masks and
        recovery ladder; host-side page-offload bookkeeping for the lane's
        previous occupant is dropped."""
        if lane is None:
            lane = self._free_lane()
        l = self.lanes[lane]
        assert l.request is None, f"lane {lane} is busy"
        prompt = np.asarray(req.prompt, np.int32)
        sp = self._bucket(len(prompt), req.n_tokens)
        toks = self._left_padded(prompt, sp)          # left-pad, as in prefill
        event = {"event": "admit", "uid": req.uid, "lane": lane,
                 "wall_step": self.wall_step}
        if self.debug_lane_checks:
            event["frozen_before"] = int(
                np.asarray(self.state.freeze.frozen[:, lane]).sum())
            event["recovery_steps_before"] = int(
                np.asarray(self.state.recovery.steps_seen)[lane])
        lane_state = MD.init_decode_state(self.cfg, 1, self.max_seq)
        self._note_kv_peak(lane_state.cache_k.nbytes + lane_state.cache_v.nbytes)
        logits, lane_state = self._prefill(
            self.params, batch={"tokens": jnp.asarray(toks)}, state=lane_state)
        self.state = self._write_lane(self.state, lane_state, jnp.int32(lane))
        if self.offloader is not None:
            self.offloader.drop_lane(lane)
        if self.debug_lane_checks:
            event["frozen_after"] = int(
                np.asarray(self.state.freeze.frozen[:, lane]).sum())
            event["recovery_steps_after"] = int(
                np.asarray(self.state.recovery.steps_seen)[lane])
        self.pos[lane] = sp
        self.step[lane] = 0
        self.key, sub = jax.random.split(self.key)
        first = int(np.asarray(sample(logits, sub, req.sampling))[0])
        self.tok[lane] = first
        self._set_lane_sampling(lane, req.sampling)
        l.request = req
        l.generated = [first]
        l.history = []
        l.rewinds = 0
        l.last_rewind_step = -10**9
        req.telemetry = GenerationResult([], [], [], [], [], [], [])
        self.events.append(event)
        return lane

    # ---------------- stepping ---------------- #
    def step_once(self) -> List[Request]:
        """Run one jitted decode step over all lanes; returns the requests
        that retired this step (their lanes are immediately free)."""
        active = [i for i, l in enumerate(self.lanes) if l.request is not None]
        if not active:
            return []
        self._note_kv_peak()
        logits, self.state, info = self._step(
            self.params, token=jnp.asarray(self.tok),
            pos=jnp.asarray(self.pos), step=jnp.asarray(self.step),
            state=self.state)
        self.wall_step += 1
        # enqueue per-lane sampling right behind the step, then pull it and
        # the telemetry in ONE device->host transfer (rewound lanes simply
        # discard their draw)
        self.key, sub = jax.random.split(self.key)
        keys = ("n_active", "n_frozen", "entropy", "spike", "level",
                "rr_request")
        host = jax.device_get(dict(
            {k: info[k] for k in keys if k in info},
            toks=self._sample(logits, sub, *self._lane_params())))
        get = host.get
        n_active, n_frozen = get("n_active"), get("n_frozen")
        entropy, spike, level = get("entropy"), get("spike"), get("level")
        rr = get("rr_request")
        toks = host["toks"]
        n_layers_attn = max(self.state.freeze.frozen.shape[0], 1)

        # ---- per-lane telemetry: one append per lane-step ----
        for i in active:
            res = self.lanes[i].request.telemetry
            if n_active is not None:
                res.active_kv.append(float(n_active[i]) / n_layers_attn)
                res.frozen_kv.append(float(n_frozen[i]) / n_layers_attn)
            else:
                res.active_kv.append(float(self.pos[i] + 1))
                res.frozen_kv.append(0.0)
            res.total_kv.append(int(self.pos[i]) + 1)
            if entropy is not None:
                res.entropy.append(float(entropy[i]))
                if spike is not None and bool(spike[i]):
                    res.recovery_events.append({
                        "step": int(self.step[i]),
                        "level": int(level[i]),
                        "entropy": float(entropy[i]),
                    })

        # ---- per-lane Rewalk Regeneration ----
        rewound = set()
        if rr is not None:
            for i in active:
                l = self.lanes[i]
                if bool(rr[i]) and len(l.history) >= self.fcfg.rewalk_tokens \
                        and l.rewinds < self.max_rewinds \
                        and int(self.step[i]) - l.last_rewind_step \
                            >= self.rewind_cooldown:
                    self._rewind_bookkeeping(i)
                    rewound.add(i)

        # ---- page-batched host offload ----
        if self.offloader is not None \
                and self.wall_step % self.offload_every == 0:
            frozen = np.asarray(self.state.freeze.frozen)
            idle = [i for i, l in enumerate(self.lanes) if l.request is None]
            if idle:   # idle lanes decode garbage; never offload it
                frozen = frozen.copy()
                frozen[:, idle, :] = False
            cache = KVCache(k=self.state.cache_k, v=self.state.cache_v)
            cache = self.offloader.sync(cache, frozen)
            self.state = self.state._replace(cache_k=cache.k, cache_v=cache.v)
        for i in active:
            self.lanes[i].request.telemetry.offloaded_tokens.append(
                self.offloader.offloaded_tokens_lane(i)
                if self.offloader else 0)

        # ---- commit sampled tokens, retire finished lanes ----
        finished = []
        for i in active:
            if i in rewound:
                continue
            l = self.lanes[i]
            t = int(toks[i])
            l.history.append((t, int(self.pos[i])))
            l.generated.append(t)
            self.tok[i] = t
            self.pos[i] += 1
            self.step[i] += 1
            if len(l.generated) >= l.request.n_tokens:
                finished.append(self._retire(i))
        return finished

    def _retire(self, lane: int) -> Request:
        l = self.lanes[lane]
        req = l.request
        req.result = np.asarray(l.generated[: req.n_tokens], np.int32)
        req.telemetry.tokens = req.result[None, :]
        self.events.append({"event": "finish", "uid": req.uid, "lane": lane,
                            "wall_step": self.wall_step})
        l.request = None
        l.generated = []
        l.history = []
        # park the idle lane: greedy sampling, position clamped in-bounds,
        # and the retired request's offloaded pages released right away
        # (offload sync also masks idle lanes, so no churn until re-admit)
        self._set_lane_sampling(lane, SamplingParams.greedy())
        self.pos[lane] = min(int(self.pos[lane]), self.max_seq - 1)
        if self.offloader is not None:
            self.offloader.drop_lane(lane)
        return req


# ===================================================================== #
# Paged continuous batching (bounded-HBM decode + chunked prefill)
# ===================================================================== #
@dataclasses.dataclass
class _PendingPrefill:
    """An admission in flight: the prompt is prefilled chunk-by-chunk into a
    contiguous single-lane scratch cache, interleaved with decode steps of
    the resident lanes; on completion the scratch is repacked into pages
    and installed into the lane."""
    req: Request
    toks: np.ndarray          # (1, sp) left-padded prompt
    scratch: Any              # contiguous DecodeState (B=1, S=sp)
    sp: int                   # padded prompt length
    done: int = 0             # tokens prefilled so far
    logits: Any = None        # chunk-final logits (valid once done == sp)


class PagedContinuousEngine(_LaneEngineBase):
    """Continuous batching whose decode attends only each lane's bounded
    active page pool: device KV is O(P * page) per lane instead of
    O(max_seq), with frozen / overflow pages living in the host store
    (`core.paging.PagedController`).

    Two serving properties beyond `ContinuousEngine`:

    * **Bounded-HBM decode** — the jitted step (`model.decode_step_paged`,
      Pallas paged-attention kernel on TPU) runs per-lane (B,) pos/step
      clocks and a per-layer, per-lane tail-slot table; page-granular
      freeze plus the forced-freeze bound keep every lane inside its P
      physical slots, and the host controller swaps frozen pages out / due
      pages in at each lane's own page-allocation cadence.

    * **Chunked prefill** — admission prefills the prompt in fixed-size
      chunks (`prefill_chunk` tokens per engine step) into a scratch cache
      while resident lanes keep decoding; the finished prompt is repacked
      into pages (overflow beyond the pool is stashed to the host store)
      and installed with a wholesale per-lane reset
      (`PagedController.write_lane`).  A long prompt therefore never
      head-of-line-blocks the batch.

    Restricted to attention-only decoder stacks (chunked prefill would
    need cross-chunk recurrent-state threading for mamba/rwkv hybrids).

    **Entropy-guided recovery** (when ``freeze_cfg.recovery_enabled``) runs
    page-granular: the jitted step's ladder (``core.recovery.
    page_recovery_update``) un-freezes *resident* pages in place — they
    re-enter attention through the kernel's per-page visibility mask — and
    raises two host requests the step itself cannot service:

    * ``thaw_request`` (FR level): the lane's stashed host pages are due
      back early.  The engine marks the lane and the ``PagedController``
      thaws at its next page-boundary tick — stashed pages are ranked by
      ``recovery.thaw_priority`` and remapped into free slots, evicting
      the coldest resident page (stashed in turn) once the pool is full.
    * ``rr_request`` (RR level): page-aware Rewalk rewind.  The host
      rewinds ``rewalk_tokens``, invalidates the rewound KV slots on
      device (``model.rewind_paged_lane`` — wholly-rewound pages unmap;
      a rewind landing exactly on a page boundary leaves tail allocation
      to the next boundary tick), makes sure the surviving tail page is
      resident/un-frozen (``PagedController.ensure_resident``), and
      replays from the rewind point.  Budget and cooldown are per lane,
      mirroring ``ContinuousEngine``.
    """

    def __init__(self, cfg: ModelConfig, params, max_seq: int, n_lanes: int,
                 max_active_pages: int,
                 freeze_cfg: Optional[FreezeConfig] = None,
                 enable_freeze: bool = True,
                 prefill_chunk: int = 64,
                 max_rewinds: int = 4,
                 rewind_cooldown: int = 32,
                 pad_id: int = 0,
                 seed: int = 0,
                 min_prompt_bucket: int = 8):
        super().__init__(cfg, params, max_seq, n_lanes,
                         freeze_cfg=freeze_cfg, enable_freeze=enable_freeze,
                         pad_id=pad_id, seed=seed,
                         min_prompt_bucket=min_prompt_bucket)
        assert max_active_pages >= 3, "pool needs tail + swap headroom"
        assert prefill_chunk >= 1
        self.P = max_active_pages
        self.page = self.fcfg.page_size
        self.prefill_chunk = prefill_chunk
        self.max_rewinds = max_rewinds
        self.rewind_cooldown = rewind_cooldown
        self.pending_thaws: set = set()   # lanes owed a host thaw (FR level)
        self._step = jax.jit(functools.partial(
            MD.decode_step_paged, cfg=cfg, freeze_cfg=self.fcfg,
            enable_freeze=enable_freeze), donate_argnames=("state",))
        self._rewind = jax.jit(
            functools.partial(MD.rewind_paged_lane, cfg, page=self.page),
            donate_argnames=("state",))
        self._chunk = jax.jit(functools.partial(MD.prefill_chunk, cfg=cfg),
                              donate_argnames=("state",))
        self._reset_lane = jax.jit(functools.partial(MD.reset_paged_lane, cfg),
                                   donate_argnames=("state",))
        self._lane_read = jax.jit(
            lambda arrs, lane: tuple(
                jax.lax.dynamic_slice_in_dim(a, lane, 1, axis=1)
                for a in arrs))
        self._lane_write = jax.jit(
            lambda arrs, lane, lane_arrs: tuple(
                jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), lane, axis=1)
                for big, small in zip(arrs, lane_arrs)),
            donate_argnums=(0,))
        self.state = MD.init_paged_decode_state(cfg, n_lanes, max_active_pages)
        self.L_attn = max(self.state.page_table.shape[0], 1)
        assert self.state.page_table.shape[0] == cfg.num_layers, \
            "paged continuous batching requires an attention-only stack"
        self.ctl = PagedController(cfg=cfg, batch=n_lanes,
                                   max_active_pages=max_active_pages)
        self.tail_slot = np.zeros((self.L_attn, n_lanes), np.int32)
        self.prefills: Dict[int, _PendingPrefill] = {}

    @property
    def kv_device_bytes(self) -> int:
        """Live device KV footprint — O(n_lanes * P * page), independent of
        context length (the benchmark's peak-memory metric)."""
        return self.state.k.nbytes + self.state.v.nbytes

    def _offloaded_tokens_lane(self, lane: int) -> int:
        n = sum(1 for key in self.ctl.frozen_meta if key[1] == lane)
        return n * self.page // self.L_attn

    def _scratch_bytes(self) -> int:
        return sum(pp.scratch.cache_k.nbytes + pp.scratch.cache_v.nbytes
                   for pp in self.prefills.values())

    # ---------------- device <-> host pool transfer ---------------- #
    # Only the affected lanes' pool slices cross the host<->device boundary:
    # page maintenance is per-lane, so a 1-lane page boundary moves
    # (L, 1, P, page) arrays, not the whole (L, n_lanes, ...) pool.  The
    # write path is a donated dynamic_update_slice — in place on backends
    # with donation, a contiguous copy elsewhere.
    _POOL_FIELDS = ("k", "v", "page_table", "slot_mask")
    _FZ_FIELDS = ("c", "d", "frozen", "frozen_at")

    def _state_arrs(self):
        st = self.state
        return tuple(getattr(st, f) for f in self._POOL_FIELDS) + \
            tuple(st.freeze)

    def _pull_lanes(self, lanes: List[int]) -> Tuple[dict, dict]:
        cols = [jax.device_get(self._lane_read(self._state_arrs(),
                                               jnp.int32(lane)))
                for lane in lanes]
        cat = lambda i: np.concatenate([c[i] for c in cols], axis=1)
        pool = {f: cat(i) for i, f in enumerate(self._POOL_FIELDS)}
        fstate = {f: cat(len(self._POOL_FIELDS) + i)
                  for i, f in enumerate(self._FZ_FIELDS)}
        return pool, fstate

    def _push_lanes(self, pool: dict, fstate: dict, lanes: List[int]) -> None:
        arrs = self._state_arrs()
        for j, lane in enumerate(lanes):
            sl = [pool[f][:, j:j + 1] for f in self._POOL_FIELDS] + \
                 [fstate[f][:, j:j + 1] for f in self._FZ_FIELDS]
            arrs = self._lane_write(arrs, jnp.int32(lane),
                                    tuple(jnp.asarray(s) for s in sl))
        self.state = self.state._replace(
            **dict(zip(self._POOL_FIELDS, arrs[:4])),
            freeze=PageFreezeState(*arrs[4:]))

    # ---------------- admission (chunked) ---------------- #
    def admit(self, req: Request, lane: Optional[int] = None) -> int:
        """Begin a chunked admission: reserves a lane and queues the prompt
        for chunk-by-chunk prefill.  Returns immediately — resident lanes
        keep decoding while `step_once` advances the prefill."""
        if lane is None:
            lane = self._free_lane()
        l = self.lanes[lane]
        assert l.request is None, f"lane {lane} is busy"
        prompt = np.asarray(req.prompt, np.int32)
        sp = self._bucket(len(prompt), req.n_tokens)
        if not self.enable_freeze:
            # without freezing nothing ever swaps out, so the whole request
            # must fit in the pool (plus the tail-allocation headroom slot)
            need = -(-(sp + req.n_tokens) // self.page) + 1
            if need > self.P:
                raise ValueError(
                    f"request needs ~{need} pages ({sp} prompt + "
                    f"{req.n_tokens} generated tokens) but the pool holds "
                    f"{self.P} and freezing is disabled (no page ever swaps "
                    f"out); enable freezing or raise max_active_pages")
        self.prefills[lane] = _PendingPrefill(
            req=req, toks=self._left_padded(prompt, sp),
            scratch=MD.init_decode_state(self.cfg, 1, sp), sp=sp)
        l.request = req
        l.generated = []
        l.history = []
        l.rewinds = 0
        l.last_rewind_step = -10**9
        req.telemetry = GenerationResult([], [], [], [], [], [], [])
        self.events.append({"event": "admit_start", "uid": req.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "prompt_len": len(prompt), "bucket": sp})
        return lane

    def _chunk_sizes(self, sp: int) -> List[int]:
        """Every chunk length a prompt bucket `sp` can hit, over all
        interleaved/burst schedules (small closed set: the schedule only
        ever picks min(prefill_chunk, rem) or the largest power-of-two
        multiple of it that fits rem)."""
        sizes, seen, frontier = set(), set(), {sp}
        while frontier:
            rem = frontier.pop()
            if rem <= 0 or rem in seen:
                continue
            seen.add(rem)
            ci = min(self.prefill_chunk, rem)
            cb = self.prefill_chunk
            while cb * 2 <= rem:
                cb *= 2
            cb = min(cb, rem)
            sizes.update((ci, cb))
            frontier.update((rem - ci, rem - cb))
        return sorted(sizes)

    def warm_prefill(self, prompt_len: int, n_tokens: int) -> None:
        """Pre-compile every prefill-chunk shape a prompt of this length
        can encounter (the burst schedule makes the shape sequence depend
        on engine load, so production warmup must cover the closed set,
        not one observed trace)."""
        sp = self._bucket(prompt_len, n_tokens)
        state = MD.init_decode_state(self.cfg, 1, sp)
        for c in self._chunk_sizes(sp):
            _, state = self._chunk(self.params,
                                   tokens=jnp.zeros((1, c), jnp.int32),
                                   state=state, pos0=jnp.int32(0))

    def _prefill_tick(self, lane: int, busy: bool = True) -> None:
        """Advance one admission by one prompt chunk.

        `busy=False` (no resident lane is decoding) grows the chunk to the
        largest power of two that fits the remainder: fine-grained chunks
        only buy anything when there is decode work to interleave, so an
        empty engine admits at near-whole-prefill speed while a busy one
        keeps the configured interleave granularity.  Chunk lengths stay
        powers of two, so compiles remain O(log max_seq)."""
        pp = self.prefills[lane]
        self._note_kv_peak(self._scratch_bytes())
        rem = pp.sp - pp.done
        c = self.prefill_chunk
        if not busy:
            while c * 2 <= rem:
                c *= 2
        c = min(c, rem)
        chunk = jnp.asarray(pp.toks[:, pp.done:pp.done + c])
        pp.logits, pp.scratch = self._chunk(
            self.params, tokens=chunk, state=pp.scratch,
            pos0=jnp.int32(pp.done))
        pp.done += c
        self.events.append({"event": "prefill_chunk", "uid": pp.req.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "done": pp.done, "total": pp.sp})
        if pp.done >= pp.sp:
            self._install(lane)

    def _install(self, lane: int) -> None:
        """Repack the finished scratch prefill into pages and install them
        into the lane: the newest pages fill the device pool, older pages
        are stashed in the host store (returning as slots free up), and
        `PagedController.write_lane` wholesale-resets exactly this lane."""
        pp = self.prefills.pop(lane)
        sp, page, P, L = pp.sp, self.page, self.P, self.L_attn
        # wholesale lane reset first: beyond the pool fields the push below
        # overwrites, this clears the lane's recovery ladder — the decode
        # steps that ran while this admission was in flight advanced the
        # lane's entropy baseline on garbage logits, which must not leak
        # into the new occupant
        self.state = self._reset_lane(state=self.state, lane=jnp.int32(lane))
        ck = np.array(pp.scratch.cache_k[:, 0])      # (L, sp, KVH, hd)
        cv = np.array(pp.scratch.cache_v[:, 0])
        n_pages = -(-sp // page)
        pad = n_pages * page - sp
        if pad:
            ck = np.pad(ck, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cv = np.pad(cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ck = ck.reshape(L, n_pages, page, *ck.shape[2:])
        cv = cv.reshape(L, n_pages, page, *cv.shape[2:])
        masks = (np.arange(n_pages * page) < sp).reshape(n_pages, page)
        # newest pages resident (leave one slot free for the next tail);
        # older prompt pages overflow to the host store and cycle back in
        # as the freeze schedule frees slots
        r = min(n_pages, P - 1)
        # write_lane overwrites every byte of the lane slice, so build it
        # host-side instead of pulling the stale device copy first
        kvh, hd = ck.shape[-2:]
        dt = np.dtype(self.state.k.dtype)
        pool = {"k": np.zeros((L, 1, P, page, kvh, hd), dt),
                "v": np.zeros((L, 1, P, page, kvh, hd), dt),
                "page_table": np.full((L, 1, P), -1, np.int32),
                "slot_mask": np.zeros((L, 1, P, page), bool)}
        fstate = {"c": np.zeros((L, 1, P), np.int32),
                  "d": np.zeros((L, 1, P), np.int32),
                  "frozen": np.zeros((L, 1, P), bool),
                  "frozen_at": np.zeros((L, 1, P), np.int32)}
        # write_lane drops the lane's host store, so overflow pages must be
        # stashed AFTER it or they'd be deleted before decode ever starts
        self.ctl.write_lane(pool, fstate, 0,
                            ck[:, n_pages - r:], cv[:, n_pages - r:],
                            np.arange(n_pages - r, n_pages, dtype=np.int32),
                            masks[n_pages - r:], store_lane=lane)
        # overflow pages are not low-relevance, just oldest-out: timer 1
        # returns each the moment the freeze schedule frees a slot
        for gp in range(n_pages - r):
            for layer in range(L):
                self.ctl.stash(layer, lane, gp, ck[layer, gp], cv[layer, gp],
                               d=1)
        self._push_lanes(pool, fstate, [lane])
        if sp % page:                       # partial tail page is resident
            self.tail_slot[:, lane] = r - 1
        self.pos[lane] = sp                 # sp % page == 0 -> the boundary
        self.step[lane] = 0                 # alloc runs before the next step
        self.key, sub = jax.random.split(self.key)
        first = int(np.asarray(sample(pp.logits, sub, pp.req.sampling))[0])
        self.tok[lane] = first
        self._set_lane_sampling(lane, pp.req.sampling)
        self.lanes[lane].generated = [first]
        self.events.append({"event": "admit", "uid": pp.req.uid,
                            "lane": lane, "wall_step": self.wall_step})

    # ---------------- stepping ---------------- #
    def _keep_gids(self, lane: int) -> Tuple[int, ...]:
        """Global page ids the host must never evict for this lane: the
        tail page plus the freeze window (the jitted step would just
        re-write / re-attend them)."""
        cp = int(self.pos[lane]) // self.page
        window_pages = max(1, -(-self.fcfg.window // self.page))
        return tuple(range(max(0, cp - window_pages), cp + 1))

    def step_once(self) -> List[Request]:
        """One engine step: per-lane page-boundary maintenance (host swap
        tick, pending recovery thaws, tail allocation), a jitted paged
        decode step over the resident lanes, recovery servicing (page
        rewinds), then one prefill chunk for every admission in flight.
        Returns retired requests."""
        decode_lanes = [i for i, l in enumerate(self.lanes)
                        if l.request is not None and i not in self.prefills]
        finished: List[Request] = []
        if decode_lanes:
            boundary = [i for i in decode_lanes if self.pos[i] % self.page == 0]
            if boundary:
                pool, fstate = self._pull_lanes(boundary)
                keep = {bi: self._keep_gids(i)
                        for bi, i in enumerate(boundary)}
                thaw = tuple(bi for bi, i in enumerate(boundary)
                             if i in self.pending_thaws)
                self.ctl.tick(pool, fstate, step=self.wall_step,
                              lane_ids=tuple(boundary),
                              thaw_lanes=thaw, keep_gids=keep)
                self.pending_thaws -= set(boundary)
                for bi, i in enumerate(boundary):
                    slots = self.ctl.alloc_tail_lane(
                        pool, bi, int(self.pos[i]) // self.page)
                    if slots is None and self.enable_freeze:
                        # recovery may have un-frozen every page the timer
                        # pass would have swapped out; the host is the
                        # bound's enforcer of last resort — stash the
                        # coldest page and retry
                        self.ctl.force_free_slot(pool, fstate, bi, i,
                                                 keep_gids=keep[bi])
                        slots = self.ctl.alloc_tail_lane(
                            pool, bi, int(self.pos[i]) // self.page)
                    if slots is None:
                        raise RuntimeError(
                            f"lane {i}: page pool exhausted"
                            + (" (forced freeze should have kept headroom)"
                               if self.enable_freeze else
                               " — freezing is disabled, so nothing swaps "
                               "out; admission should have rejected this"))
                    self.tail_slot[:, i] = slots
                self._push_lanes(pool, fstate, boundary)
            live = np.zeros(self.n_lanes, bool)
            live[decode_lanes] = True
            self._note_kv_peak(self._scratch_bytes())
            logits, self.state, info = self._step(
                self.params, token=jnp.asarray(self.tok),
                pos=jnp.asarray(self.pos), step=jnp.asarray(self.step),
                tail_slot=jnp.asarray(self.tail_slot), state=self.state,
                live=jnp.asarray(live))
            self.wall_step += 1
            self.key, sub = jax.random.split(self.key)
            keys = ("n_active_slots_lane", "n_frozen_pages_lane", "entropy",
                    "spike", "level", "rr_request", "thaw_request")
            host = jax.device_get(dict(
                {k: info[k] for k in keys if k in info},
                toks=self._sample(logits, sub, *self._lane_params())))
            toks = host["toks"]
            get = host.get
            act, fro = get("n_active_slots_lane"), get("n_frozen_pages_lane")
            entropy, spike, level = get("entropy"), get("spike"), get("level")
            rr, thaw_req = get("rr_request"), get("thaw_request")

            for i in decode_lanes:
                res = self.lanes[i].request.telemetry
                if act is not None:
                    res.active_kv.append(float(act[i]) / self.L_attn)
                    res.frozen_kv.append(
                        float(fro[i]) * self.page / self.L_attn)
                else:
                    res.active_kv.append(float(self.pos[i] + 1))
                    res.frozen_kv.append(0.0)
                res.total_kv.append(int(self.pos[i]) + 1)
                res.offloaded_tokens.append(self._offloaded_tokens_lane(i))
                if entropy is not None:
                    res.entropy.append(float(entropy[i]))
                    if spike is not None and bool(spike[i]):
                        res.recovery_events.append({
                            "step": int(self.step[i]),
                            "level": int(level[i]),
                            "entropy": float(entropy[i]),
                        })

            # ---- recovery servicing: host thaws + page-aware rewinds ----
            if thaw_req is not None:
                for i in decode_lanes:
                    if bool(thaw_req[i]):
                        # serviced by PagedController.thaw_lane at the
                        # lane's next page-boundary tick
                        self.pending_thaws.add(i)
            rewound = set()
            if rr is not None:
                for i in decode_lanes:
                    l = self.lanes[i]
                    if bool(rr[i]) and len(l.history) >= self.fcfg.rewalk_tokens \
                            and l.rewinds < self.max_rewinds \
                            and int(self.step[i]) - l.last_rewind_step \
                                >= self.rewind_cooldown \
                            and self._rewind_lane(i):
                        rewound.add(i)

            for i in decode_lanes:
                if i in rewound:
                    continue
                l = self.lanes[i]
                t = int(toks[i])
                l.history.append((t, int(self.pos[i])))
                l.generated.append(t)
                self.tok[i] = t
                self.pos[i] += 1
                self.step[i] += 1
                if len(l.generated) >= l.request.n_tokens:
                    finished.append(self._retire(i))

        # ---- chunked prefill: one chunk per admission in flight ---- #
        for lane in list(self.prefills):
            self._prefill_tick(lane, busy=bool(decode_lanes))
        return finished

    def _rewind_lane(self, lane: int) -> bool:
        """Rewalk Regeneration on the paged path: rewind ``rewalk_tokens``,
        invalidate the rewound KV slots on device, and make the surviving
        tail page attendable again.  Pages wholly past the rewind point
        unmap (a boundary-landing rewind leaves tail re-allocation to the
        next page-boundary tick) and their stale host copies are dropped —
        the replayed pages must never collide with a stashed copy of the
        rewound generation.  Returns False (rewind skipped, nothing
        mutated) if the tail page cannot be made resident."""
        l = self.lanes[lane]
        nback = self.fcfg.rewalk_tokens
        new_pos = int(self.pos[lane]) - nback
        if new_pos <= 0:
            return False
        gid_t = new_pos // self.page
        window_pages = max(1, -(-self.fcfg.window // self.page))
        keep = tuple(range(max(0, gid_t - window_pages), gid_t + 1))
        if new_pos % self.page:
            # mid-page landing: the tail page must be resident + un-frozen
            # in every layer before decode resumes (it may have been
            # frozen or even stashed if the freeze window is one page)
            pool, fstate = self._pull_lanes([lane])
            ok = self.ctl.ensure_resident(pool, fstate, 0, lane, gid_t,
                                          keep_gids=keep)
            # push back even on failure: a partial layer's thaw/eviction
            # mutated both the pulled copies and the controller's host
            # bookkeeping, and dropping the copies would desynchronize
            # them (duplicate swap-ins / unreachable host pages)
            self._push_lanes(pool, fstate, [lane])
            if not ok:
                return False
            for lyr in range(self.L_attn):
                slot = np.nonzero(pool["page_table"][lyr, 0] == gid_t)[0]
                self.tail_slot[lyr, lane] = int(slot[0])
        self.state = self._rewind(state=self.state, lane=jnp.int32(lane),
                                  new_pos=jnp.int32(new_pos))
        self.ctl.drop_pages_from(lane, -(-new_pos // self.page))
        self._rewind_bookkeeping(lane)
        self.events.append({"event": "rewind", "uid": l.request.uid,
                            "lane": lane, "wall_step": self.wall_step,
                            "new_pos": new_pos})
        return True

    def _retire(self, lane: int) -> Request:
        l = self.lanes[lane]
        req = l.request
        req.result = np.asarray(l.generated[: req.n_tokens], np.int32)
        req.telemetry.tokens = req.result[None, :]
        self.events.append({"event": "finish", "uid": req.uid, "lane": lane,
                            "wall_step": self.wall_step})
        l.request = None
        l.generated = []
        l.history = []
        # unmap the lane's pages on device (attention skips them), drop its
        # host store and any pending thaw so nothing leaks into the lane's
        # next occupant
        self.state = self._reset_lane(state=self.state, lane=jnp.int32(lane))
        self.ctl.drop_lane(lane)
        self.pending_thaws.discard(lane)
        self._set_lane_sampling(lane, SamplingParams.greedy())
        return req

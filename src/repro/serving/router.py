"""Replica router: health-checked failover over N in-process engine
replicas with freeze-native lane migration.

The paper's contract — frozen/stashed KV is *preserved, not evicted* —
gives this engine a capability eviction-based servers don't have: a
suspended lane's ``LaneSnapshot`` (host-side pool slice + host-store
pages + snapshot-stable sampling key) resumes **token-identically on a
different replica** (``export_lane``/``import_lane``).  ``ReplicaRouter``
builds the serving layer that exploits it:

* **SLO-aware placement** — each submitted request is placed on the live
  replica with the lowest score: occupancy (active lanes + queue depth,
  in lane units) + ``admission_pressure`` (stash + exported-snapshot
  bytes over budget) + a deadline-headroom penalty (estimated start
  delay over remaining slack) when the request carries an SLO.

* **Deterministic replica faults** — each replica owns a
  ``FaultInjector`` seeded ``seed + 7919 * rid`` over the shared
  ``ChaosConfig``, consulted once per router tick at the ``replica_*``
  sites: ``replica_crash`` fences the replica permanently,
  ``replica_hang`` skips ``attempts`` consecutive ticks (no progress —
  the heartbeat monitor sees a frozen ``wall_step``), ``replica_slow``
  sleeps before the step.  Same seed + same trace = same kill points;
  chaos runs are replayable.

* **Heartbeat health-checking** — a live replica *with work* whose
  engine ``wall_step`` fails to advance for ``hang_threshold``
  consecutive ticks is declared dead and failed over; idle replicas
  always beat.  Transient hangs (shorter than the threshold) recover
  with no failover.

* **Incremental lane checkpointing** — every ``checkpoint_every`` ticks
  the router mirrors each decoding lane's ``checkpoint_lane`` snapshot
  (non-destructive: the lane keeps running, the controller keeps owning
  its store — ``exported=False`` accounting) into a router-side store.

* **Failover** — on replica death, (1) the engine's retired-but-
  unreported backlog is harvested (those finished — nothing to redo),
  (2) queued work and engine-suspended snapshots re-place on survivors
  via ``Scheduler.adopt`` (snapshots resume token-identically — the
  payload is host numpy, valid on any same-config replica), and (3)
  each in-flight lane resumes from its last router-side checkpoint on
  the best survivor — token-identical from the checkpoint, re-decoding
  the journaled committed tokens on the way — falling back to a fresh
  re-prefill of the original request when no checkpoint exists (e.g.
  death mid-prefill).  Zero requests are lost either way; the
  checkpoint cadence only bounds how much decode work is repeated.

* **Drain / rebalance** — ``drain_replica`` migrates an
  overloaded-but-alive replica's lanes + queue to the others through
  the same suspend/adopt path; ``step`` auto-rebalances one queued item
  per tick toward an idle replica so one replica's backlog cannot
  starve while another sits empty.

Everything here is host-side numpy/bookkeeping — no jax import, no
device syncs beyond what the engines' own step/checkpoint paths do.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import LaneSnapshot, PagedContinuousEngine, Request
from repro.serving.faults import (ChaosConfig, FaultInjector, FaultPlan,
                                  FaultSchedule)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler

# per-replica seed spacing for the shared chaos config (any odd prime
# keeps the per-site crc32 streams disjoint across replicas)
_REPLICA_SEED_STRIDE = 7919


class ReplicaHandle:
    """One in-process replica: its engine, its scheduler, its fault
    injector and its health bookkeeping."""

    def __init__(self, rid: int, engine: PagedContinuousEngine,
                 sched: Scheduler,
                 injector: Optional[FaultInjector] = None):
        self.rid = rid
        self.engine = engine
        self.sched = sched
        self.injector = injector
        self.alive = True
        self.fence_reason: Optional[str] = None
        self.hang_left = 0          # remaining skipped ticks of a hang
        self.no_progress = 0        # consecutive heartbeat misses
        self.last_wall = -1
        self.n_hang_ticks = 0
        self.n_slow_ticks = 0

    @property
    def busy(self) -> bool:
        return bool(self.sched.queue) or self.sched.busy

    def fence(self, reason: str) -> None:
        """Mark dead: the router never steps a fenced replica again."""
        self.alive = False
        self.fence_reason = reason


class ReplicaRouter:
    """Front end spreading requests over N replicas (each its own
    ``Scheduler`` + ``PagedContinuousEngine``) with health-checked
    failover.  All replicas must share one model config/params (a
    snapshot's pool slice only pushes into an identical layout); the
    router gives every scheduler its own clock-shared view by
    constructing them itself.

    ``chaos`` seeds the deterministic replica-level fault injection
    (``replica_*`` sites; engine-level sites stay with each engine's own
    chaos config).  ``kill_at=(rid, tick)`` is the explicit mid-trace
    crash switch benchmarks and ``--kill-replica-at`` use."""

    def __init__(self, engines: List[PagedContinuousEngine],
                 checkpoint_every: int = 8,
                 hang_threshold: int = 3,
                 chaos: Optional[ChaosConfig] = None,
                 kill_at: Optional[Tuple[int, int]] = None,
                 clock=time.monotonic,
                 sched_kw: Optional[Dict[str, Any]] = None):
        assert engines, "router needs at least one replica engine"
        assert checkpoint_every >= 1 and hang_threshold >= 1
        self.clock = clock
        self.checkpoint_every = checkpoint_every
        self.hang_threshold = hang_threshold
        self.replicas: List[ReplicaHandle] = []
        for rid, eng in enumerate(engines):
            injector = None
            if chaos is not None or (kill_at and kill_at[0] == rid):
                base = chaos or ChaosConfig()
                explicit = dict(base.explicit)
                if kill_at and kill_at[0] == rid:
                    explicit[("replica_crash", kill_at[1])] = \
                        FaultPlan(kind="crash")
                injector = FaultInjector(FaultSchedule(
                    seed=base.seed + _REPLICA_SEED_STRIDE * rid,
                    rates=base.rates, attempts=base.attempts,
                    explicit=explicit))
            sched = Scheduler(eng, clock=clock, **(sched_kw or {}))
            self.replicas.append(ReplicaHandle(rid, eng, sched, injector))
        self._uid = 0
        self.requests: Dict[int, Request] = {}
        self.placed: Dict[int, int] = {}       # uid -> rid
        self.done: Dict[int, Request] = {}
        self.metrics: Dict[int, Dict[str, Any]] = {}
        # committed-token journal: the last harvested ``generated`` of
        # each in-flight lane (telemetry + the failover consistency
        # check; under entropy-recovery rewinds the list can shrink —
        # it mirrors the lane, it does not promise monotonicity)
        self.journal: Dict[int, List[int]] = {}
        self.journal_at_fail: Dict[int, List[int]] = {}
        # router-side checkpoint mirror: uid -> (rid, LaneSnapshot)
        self.checkpoints: Dict[int, Tuple[int, LaneSnapshot]] = {}
        self.tick = 0
        self.n_failovers = 0
        self.recovered_with_checkpoint = 0
        self.recovered_reprefill = 0
        self.requeued_items = 0
        self.n_rebalanced = 0
        self.events: List[Dict[str, Any]] = []

    # ---------------- placement ---------------- #
    def _live(self) -> List[ReplicaHandle]:
        return [r for r in self.replicas if r.alive]

    def _start_delay_s(self, r: ReplicaHandle) -> float:
        """Estimated wall delay before a new arrival starts on replica
        ``r``: zero with a free lane, else time for the shortest running
        lane to retire plus the service of everything queued ahead."""
        sched = r.sched
        if r.engine.has_free_lane and not sched.queue:
            return 0.0
        running = [i for i, l in enumerate(r.engine.lanes)
                   if l.request is not None]
        wait = sched._est_free_s(running)
        for entry in sched.queue:
            wait += sched._est_service_s(entry[-1])
        return wait

    def _score(self, r: ReplicaHandle, req: Request,
               deadline_t: Optional[float]) -> Tuple[float, int]:
        """Placement score, lower better: occupancy in lane units +
        admission pressure + deadline-headroom penalty (start delay over
        remaining slack).  The rid tie-break keeps placement
        deterministic."""
        h = r.engine.health()
        occupancy = (h["n_active_lanes"] + len(r.sched.queue)) \
            / max(h["n_lanes"], 1)
        score = occupancy + h["admission_pressure"]
        if deadline_t is not None:
            slack = max(deadline_t - self.clock(), 1e-3)
            score += self._start_delay_s(r) / slack
        return (score, r.rid)

    def _best_replica(self, req: Request,
                      deadline_t: Optional[float] = None,
                      exclude: Tuple[int, ...] = ()) -> ReplicaHandle:
        cands = [r for r in self._live() if r.rid not in exclude]
        if not cands:
            raise RuntimeError("no live replica to place work on")
        return min(cands, key=lambda r: self._score(r, req, deadline_t))

    def submit(self, prompt: np.ndarray, n_tokens: int,
               sampling: SamplingParams = SamplingParams(),
               priority: int = 0,
               deadline_ms: Optional[float] = None,
               slo_tokens_per_s: Optional[float] = None,
               tenant: Optional[str] = None) -> int:
        """Router-global uid; the request lands on the best-scored live
        replica's queue immediately.  ``tenant`` rides the request across
        placements and failovers — pass ONE shared ``TenancyController``
        through ``sched_kw=dict(tenancy=...)`` and quotas/fair shares
        hold router-wide, not per replica."""
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32), n_tokens,
                      sampling, priority=priority, deadline_ms=deadline_ms,
                      slo_tokens_per_s=slo_tokens_per_s, tenant=tenant)
        self.requests[req.uid] = req
        now = self.clock()
        deadlines = []
        if deadline_ms is not None:
            deadlines.append(now + deadline_ms / 1e3)
        if slo_tokens_per_s:
            deadlines.append(now + n_tokens / slo_tokens_per_s)
        deadline_t = min(deadlines) if deadlines else None
        r = self._best_replica(req, deadline_t)
        r.sched.enqueue(req, deadline_t=deadline_t)
        self.placed[req.uid] = r.rid
        return req.uid

    # ---------------- faults + heartbeat ---------------- #
    def _consult_faults(self, r: ReplicaHandle) -> str:
        """One deterministic fault draw per site per tick; returns the
        replica's disposition for this tick: "crash", "skip" (hanging)
        or "step"."""
        inj = r.injector
        if inj is not None:
            plan = inj.next_plan("replica_crash")
            if plan is not None and plan.kind in ("crash", "fail"):
                return "crash"
            plan = inj.next_plan("replica_hang")
            if plan is not None and plan.kind in ("hang", "fail"):
                r.hang_left = max(r.hang_left, plan.attempts)
            plan = inj.next_plan("replica_slow")
            if plan is not None and plan.kind in ("slow", "fail"):
                r.n_slow_ticks += 1
                if plan.delay_s:
                    time.sleep(plan.delay_s)
        if r.hang_left > 0:
            r.hang_left -= 1
            r.n_hang_ticks += 1
            return "skip"
        return "step"

    def _heartbeat(self, r: ReplicaHandle) -> None:
        """Declare a replica dead after ``hang_threshold`` consecutive
        ticks of work-without-progress (frozen ``wall_step``).  The
        check is a host counter compare — no device sync."""
        wall = r.engine.wall_step
        if not r.busy or wall != r.last_wall:
            r.no_progress = 0
        else:
            r.no_progress += 1
        r.last_wall = wall
        if r.no_progress >= self.hang_threshold:
            self._failover(r, "hang")

    # ---------------- journal + checkpoints ---------------- #
    def _harvest(self, r: ReplicaHandle, finished: List[int]) -> None:
        for uid in finished:
            self.done[uid] = r.sched.done[uid]
            self.metrics[uid] = r.sched.metrics[uid]
            self.journal[uid] = list(self.done[uid].result)
            self.checkpoints.pop(uid, None)
        for l in r.engine.lanes:
            if l.request is not None:
                self.journal[l.request.uid] = list(l.generated)

    def _checkpoint_tick(self, r: ReplicaHandle) -> None:
        """Mirror every decoding lane's snapshot into the router store.
        Replacing a prior checkpoint is free — checkpoint snapshots
        never own exported accounting (``exported=False``)."""
        for lane, l in enumerate(r.engine.lanes):
            if l.request is None:
                continue
            snap = r.engine.checkpoint_lane(lane)
            if snap is not None:
                self.checkpoints[snap.req.uid] = (r.rid, snap)

    # ---------------- failover + migration ---------------- #
    def _failover(self, r: ReplicaHandle, reason: str) -> None:
        """Fence a dead replica and re-place every piece of its work on
        survivors: harvested retirements, queued items, engine-suspended
        snapshots, and each in-flight lane from its last checkpoint
        (re-prefill fallback without one)."""
        r.fence(reason)
        self.n_failovers += 1
        self.events.append({"event": "failover", "rid": r.rid,
                            "reason": reason, "tick": self.tick})
        eng, sched = r.engine, r.sched
        # 1) retirements stranded in the engine's backlog already
        #    finished — harvest, don't redo.  (The async ring may also
        #    hold a computed-but-uncommitted step; it is NOT drained —
        #    a dead replica's device state is unreachable by assumption,
        #    so that step re-decodes from the checkpoint like any other
        #    post-checkpoint token.)
        for req in list(eng._retired_backlog):
            self.done[req.uid] = req
            self.metrics[req.uid] = sched.metrics[req.uid]
            self.checkpoints.pop(req.uid, None)
        # 2) queued work + suspended snapshots re-place as-is (host-side
        #    payloads, valid on any same-config replica)
        pending = sched.extract_pending()
        for snap in eng.drain_suspended():
            pending.append((snap, sched.metrics[snap.req.uid]))
        for item, row in pending:
            req = item.req if isinstance(item, LaneSnapshot) else item
            if req.result is not None:
                continue
            tgt = self._best_replica(req, row.get("deadline_t"),
                                     exclude=(r.rid,))
            tgt.sched.adopt(item, row)
            self.placed[req.uid] = tgt.rid
            self.requeued_items += 1
        # 3) in-flight lanes: checkpoint resume, else re-prefill
        inflight: Dict[int, Request] = {}
        for l in eng.lanes:
            if l.request is not None and l.request.result is None:
                inflight[l.request.uid] = l.request
        for pp in getattr(eng, "prefills", {}).values():
            if pp.req.result is None:
                inflight.setdefault(pp.req.uid, pp.req)
        for uid, req in inflight.items():
            row = sched.metrics[uid]
            self.journal_at_fail[uid] = list(self.journal.get(uid, []))
            ck = self.checkpoints.get(uid)
            tgt = self._best_replica(req, row.get("deadline_t"),
                                     exclude=(r.rid,))
            if ck is not None:
                tgt.sched.adopt(ck[1], row)
                self.recovered_with_checkpoint += 1
            else:
                # fresh decode of the same request object: the dead
                # replica is fenced (never stepped), so its stale lane
                # reference cannot race the re-prefill
                tgt.sched.enqueue(req, deadline_t=row.get("deadline_t"))
                self.recovered_reprefill += 1
            self.placed[uid] = tgt.rid
            self.events.append({"event": "recover", "uid": uid,
                                "rid": tgt.rid, "tick": self.tick,
                                "from_checkpoint": ck is not None})

    def drain_replica(self, rid: int) -> int:
        """Migrate an overloaded-but-alive replica's entire load (queue
        + running lanes, via the token-identical suspend path) onto the
        other live replicas; returns items moved.  The replica stays
        live and immediately placeable — this is rebalancing, not
        fencing."""
        r = self.replicas[rid]
        assert r.alive, "drain a dead replica via failover, not drain"
        moved = 0
        for item, row in r.sched.extract_pending():
            req = item.req if isinstance(item, LaneSnapshot) else item
            tgt = self._best_replica(req, row.get("deadline_t"),
                                     exclude=(rid,))
            tgt.sched.adopt(item, row)
            self.placed[req.uid] = tgt.rid
            moved += 1
        for lane, l in enumerate(r.engine.lanes):
            if l.request is None:
                continue
            uid = l.request.uid
            snap = r.engine.suspend_lane(lane)
            if snap is None:
                continue
            row = r.sched.metrics[uid]
            tgt = self._best_replica(snap.req, row.get("deadline_t"),
                                     exclude=(rid,))
            tgt.sched.adopt(snap, row)
            self.placed[uid] = tgt.rid
            moved += 1
        return moved

    def _rebalance(self) -> None:
        """Move one queued item per tick from the deepest queue to a
        live replica with a free lane and nothing queued — bounded-rate,
        so migration can never thrash."""
        live = self._live()
        if len(live) < 2:
            return
        src = max(live, key=lambda r: len(r.sched.queue))
        if len(src.sched.queue) < 2:
            return
        idle = [r for r in live if r is not src and not r.sched.queue
                and r.engine.has_free_lane
                and r.engine.admission_pressure
                < r.engine.ladder_cfg.throttle_admissions]
        if not idle:
            return
        entries = src.sched.extract_pending()
        item, row = entries.pop(0)
        for it, rw in entries:
            src.sched.adopt(it, rw)
        req = item.req if isinstance(item, LaneSnapshot) else item
        tgt = min(idle, key=lambda r: self._score(r, req,
                                                  row.get("deadline_t")))
        tgt.sched.adopt(item, row)
        self.placed[req.uid] = tgt.rid
        self.n_rebalanced += 1

    # ---------------- serving loop ---------------- #
    def step(self) -> List[int]:
        """One router tick: fault draws, one scheduler step per live
        replica with work, journal harvest, heartbeat checks, the
        checkpoint cadence and one bounded rebalance move.  Returns the
        uids that finished this tick."""
        self.tick += 1
        finished: List[int] = []
        for r in self._live():
            disposition = self._consult_faults(r)
            if disposition == "crash":
                self._failover(r, "crash")
                continue
            if disposition == "skip" or not r.busy:
                continue
            done = r.sched.step()
            self._harvest(r, done)
            finished.extend(done)
        for r in self._live():
            self._heartbeat(r)
        if self.tick % self.checkpoint_every == 0:
            for r in self._live():
                self._checkpoint_tick(r)
        self._rebalance()
        return finished

    @property
    def busy(self) -> bool:
        return any(r.busy for r in self._live())

    def pending_uids(self) -> List[int]:
        return [u for u in self.requests if u not in self.done]

    def run(self, max_ticks: int = 200_000) -> None:
        """Serve until every submitted request is done.  ``max_ticks``
        is a safety backstop — hitting it means work was lost, which the
        zero-lost-requests invariant (and the soak tests) treat as a
        failure, not a quiet exit."""
        while self.pending_uids() and self.tick < max_ticks:
            if not self._live():
                raise RuntimeError("all replicas dead; "
                                   f"lost={self.pending_uids()}")
            self.step()

    # ---------------- reporting ---------------- #
    def report(self) -> Dict[str, Any]:
        lost = self.pending_uids()
        return {
            "ticks": self.tick,
            "n_replicas": len(self.replicas),
            "n_live": len(self._live()),
            "submitted": len(self.requests),
            "completed": len(self.done),
            "lost_requests": len(lost),
            "n_failovers": self.n_failovers,
            "recovered_with_checkpoint": self.recovered_with_checkpoint,
            "recovered_reprefill": self.recovered_reprefill,
            "requeued_items": self.requeued_items,
            "n_rebalanced": self.n_rebalanced,
            "replicas": [{
                "rid": r.rid, "alive": r.alive,
                "fence_reason": r.fence_reason,
                "n_hang_ticks": r.n_hang_ticks,
                "n_slow_ticks": r.n_slow_ticks,
                "health": r.engine.health(),
            } for r in self.replicas],
        }

"""Public serving API for the ASR-KF-EGR stack.

Everything a deployment constructs by hand is re-exported here; the
submodules stay importable directly (and the heavy internals — paged
controller, DMA ring, chaos machinery — stay where they are).

    from repro.serving import (ServingConfig, PagedContinuousEngine,
                               Scheduler, TenancyController, TenantConfig,
                               AsyncServingEngine, ServingServer)
"""
from repro.serving.config import ServingConfig
from repro.serving.engine import (ContinuousEngine, Engine, LaneSnapshot,
                                  PagedContinuousEngine, Request,
                                  RequestStatus)
from repro.serving.faults import ChaosConfig
from repro.serving.router import ReplicaRouter
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler, StaticScheduler
from repro.serving.server import (AsyncServingEngine, RequestStream,
                                  ServingServer)
from repro.serving.tenancy import TenancyController, TenantConfig

__all__ = [
    "AsyncServingEngine",
    "ChaosConfig",
    "ContinuousEngine",
    "Engine",
    "LaneSnapshot",
    "PagedContinuousEngine",
    "ReplicaRouter",
    "Request",
    "RequestStatus",
    "RequestStream",
    "SamplingParams",
    "Scheduler",
    "ServingConfig",
    "ServingServer",
    "StaticScheduler",
    "TenancyController",
    "TenantConfig",
]

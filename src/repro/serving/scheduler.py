"""Request scheduler: groups incoming generation requests into fixed-size
padded batches for the Engine (static batching with FIFO admission —
the jitted step has a fixed batch dim, so the scheduler pads partial
batches with dummy lanes and masks their outputs)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import Engine, GenerationResult
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    n_tokens: int
    sampling: SamplingParams = SamplingParams()
    result: Optional[np.ndarray] = None


class Scheduler:
    def __init__(self, engine: Engine, batch_size: int, pad_id: int = 0):
        self.engine = engine
        self.batch_size = batch_size
        self.pad_id = pad_id
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._uid = 0

    def submit(self, prompt: np.ndarray, n_tokens: int,
               sampling: SamplingParams = SamplingParams()) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  n_tokens, sampling))
        return self._uid

    def run_once(self) -> List[int]:
        """Serve one batch from the queue; returns completed uids."""
        if not self.queue:
            return []
        batch = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        n_lanes = self.batch_size
        max_prompt = max(len(r.prompt) for r in batch)
        n_gen = max(r.n_tokens for r in batch)
        toks = np.full((n_lanes, max_prompt), self.pad_id, np.int32)
        for i, r in enumerate(batch):
            toks[i, max_prompt - len(r.prompt):] = r.prompt  # left-pad
        res = self.engine.generate({"tokens": jnp.asarray(toks)}, n_gen,
                                   sampling=batch[0].sampling)
        out = []
        for i, r in enumerate(batch):
            r.result = res.tokens[i, : r.n_tokens]
            self.done[r.uid] = r
            out.append(r.uid)
        return out

    def run(self) -> None:
        while self.queue:
            self.run_once()

"""SLO-aware request scheduling over the serving engines.

``Scheduler`` (PR 5) replaces the thin FIFO admission queue with a
deadline/priority-aware policy built on the freeze machinery's cheapest
primitive: suspending a lane.  Requests carry a strict ``priority`` class
(0 = most important) and optionally a ``deadline_ms`` or an
``slo_tokens_per_s`` decode-rate SLO (converted to a completion
deadline).  The pending queue is a priority heap ordered **strictly
across classes and earliest-deadline-first (EDF) within a class**, with
submission order as the final tie-break — so a trace with no priorities
and no deadlines degrades to exactly the old FIFO behaviour.

**Freeze-native preemption.**  When the best pending request would miss
its deadline waiting for a lane to free naturally, and a strictly
lower-priority request is running, the scheduler preempts.  On the paged
engine it uses install-time preemption (``engine.admit_over``): the
preemptor's chunked prefill runs in scratch while the victim keeps
decoding, and only at install is the victim suspended — its entire
device residency force-stashes to the host store in one batched
transfer, and the continuation is *token-identical* on resume.  The
contiguous engine (and resuming a snapshot, whose pool slice must push
back into a free lane) falls back to immediate ``suspend_lane``;
contiguous resume re-prefills prompt + generated tokens from the
snapshot.  Either way the victim's ``LaneSnapshot`` re-enters the queue
under its own priority/deadline and original submission order, resuming
when capacity returns.  Suspending a lane is nearly free precisely
because the paged engine already treats "this KV lives on the host right
now" as a normal state of the world (ARKV's memory-budget framing;
FreeKV-style retrieval-on-demand makes policy on top of it cheap).

The miss prediction is deliberately simple: an EMA of observed engine
step time, the shortest remaining work across running lanes as the
time-to-free estimate, and chunk-count + decode-length as the service
estimate.  It only gates *when* a preemption fires; correctness never
depends on it.  A second model gates whether preempting is *worth it*:
EMAs of the measured suspend and resume wall cost (``preempt_cost_s``)
veto preemptions whose overhead would eat the whole queue-wait saving.

**Multi-tenancy (PR 10).**  With a ``TenancyController``
(serving/tenancy.py) attached, admission enforces per-tenant quotas
(concurrent-lane caps, token-rate buckets) and weighted fair sharing:
within a priority class the backlogged tenant with the smallest WFQ
virtual time is admitted first, and every committed decode token
advances its tenant's vtime by ``1/weight``.  ``cancel`` / ``pause`` /
``release`` are the server front end's hooks — client disconnects and
per-connection backpressure both route into the freeze-native
suspend/drop machinery rather than growing new engine surface.

Both engines default to the async DMA pipeline (serving/dma.py): a
request may retire one ``step_once`` call after its final token was
computed — the admit-on-free loop is agnostic to that lag, and
``suspend_lane`` flushes the ring first, so preemption decisions act on
committed state.

``StaticScheduler`` keeps the pre-continuous-batching (pre-PR-1)
fixed-batch FIFO behaviour — pad a batch, run everyone for max(n_tokens)
steps, only then admit more — as the comparison baseline for
``benchmarks/continuous_batching.py``.
"""
from __future__ import annotations

import heapq
import math
import time
from typing import Any, Dict, List, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import (ContinuousEngine, Engine, LaneSnapshot,
                                  PagedContinuousEngine, Request,
                                  RequestStatus)
from repro.serving.sampling import SamplingParams
from repro.serving.tenancy import TenancyController

_INF = float("inf")


class Scheduler:
    """Deadline/priority-aware admission (strict classes, EDF within a
    class) with freeze-native lane preemption, over a continuous-batching
    engine (contiguous or paged — both expose the same
    admit/step_once/suspend_lane/resume_lane lane lifecycle).

    ``policy="fifo"`` ignores priorities and deadlines entirely (pure
    submission order, no preemption) — the pre-PR-5 behaviour, kept as
    the benchmark baseline.  ``clock`` is injectable for deterministic
    tests; it must be monotone seconds.

    ``aging_s`` bounds starvation across the strict classes: a queued
    request's *effective* class drops by one (toward 0 = most important)
    for every ``aging_s`` seconds it has waited, so a background request
    under a permanent foreground flood (or a router throttle) is
    eventually admitted instead of starving forever.  Running lanes keep
    their raw class — aging changes who is admitted next, never who is
    preempted."""

    def __init__(self,
                 engine: Union[Engine, ContinuousEngine,
                               PagedContinuousEngine],
                 batch_size: Optional[int] = None, pad_id: int = 0,
                 policy: str = "slo",
                 preemption: bool = True,
                 aging_s: Optional[float] = None,
                 tenancy: Optional[TenancyController] = None,
                 clock=time.monotonic, **kw):
        if isinstance(engine, (ContinuousEngine, PagedContinuousEngine)):
            self.engine = engine
        else:
            self.engine = ContinuousEngine.from_engine(
                engine, n_lanes=batch_size or 1, pad_id=pad_id, **kw)
        assert policy in ("slo", "fifo"), policy
        self.policy = policy
        self.preemption = preemption and policy == "slo"
        self.aging_s = aging_s if policy == "slo" else None
        self.clock = clock
        # heap of (priority, deadline_t, seq, item); item is a Request or
        # a LaneSnapshot (a preempted victim awaiting resume).  Under
        # policy="fifo" the first two components are constants, reducing
        # the order to the seq counter — plain submission order.
        self.queue: List[tuple] = []
        self._seq = 0
        self.done: Dict[int, Request] = {}
        self._uid = 0
        # per-uid SLO bookkeeping (wall times are scheduler-relative)
        self.metrics: Dict[int, Dict[str, Any]] = {}
        self.n_preemptions = 0
        self.n_cancelled = 0
        self._step_s: Optional[float] = None   # EMA of engine step time
        # multi-tenant quotas + weighted fair sharing (serving/tenancy.py);
        # None keeps the single-tenant behaviour bit-for-bit.  A router
        # passes ONE shared controller to every replica via sched_kw.
        self.tenancy = tenancy
        # preemption cost model (the ROADMAP's missing piece): EMAs of the
        # measured wall cost of a suspend and of a resume.  Until BOTH
        # have been observed, preempt_cost_s() reports 0.0 — the first
        # preemption always proceeds and seeds the calibration.
        self._suspend_s: Optional[float] = None
        self._resume_s: Optional[float] = None
        self.n_preempt_skipped_cost = 0

    # ---------------- queue plumbing ---------------- #
    def _deadline_t(self, uid: int) -> Optional[float]:
        return self.metrics[uid]["deadline_t"]

    def _eff_priority(self, req: Request) -> int:
        """The request's class as admission ordering sees it: raw class
        minus one per ``aging_s`` seconds waited (floored at 0)."""
        if self.aging_s is None:
            return req.priority
        waited = self.clock() - self.metrics[req.uid]["arrival_t"]
        return max(0, req.priority - int(waited / self.aging_s))

    def _apply_aging(self) -> None:
        """Re-heap the queue when waiting has promoted any entry's
        effective class — heap keys are computed at push time, so a
        promotion invalidates the stored order.  O(n log n) only on the
        passes where a promotion actually crossed an ``aging_s``
        boundary; a no-op scan otherwise."""
        if self.aging_s is None or not self.queue:
            return
        for key0, _, _, item in self.queue:
            req = item.req if isinstance(item, LaneSnapshot) else item
            if self._eff_priority(req) != key0:
                items = [e[-1] for e in self.queue]
                self.queue = []
                for it in items:
                    self._push(it)
                return

    def _push(self, item: Union[Request, LaneSnapshot]) -> None:
        # the tie-break is the request's ORIGINAL submission seq, not a
        # fresh counter: a preempted victim re-enters the queue ahead of
        # the same-class work submitted after it, so preemption never
        # demotes a request within its class.  (Besides fairness this is
        # what keeps preemption throughput-neutral: victims resume the
        # moment the preemptor retires, instead of their remainders
        # serializing behind the whole class queue at the end of the
        # trace.)  A uid is queued at most once, so seq stays unique.
        req = item.req if isinstance(item, LaneSnapshot) else item
        if self.policy == "fifo":
            key = (0, _INF)
        else:
            dl = self._deadline_t(req.uid)
            key = (self._eff_priority(req), _INF if dl is None else dl)
        heapq.heappush(self.queue,
                       (*key, self.metrics[req.uid]["seq"], item))

    def _peek(self) -> Optional[Union[Request, LaneSnapshot]]:
        return self.queue[0][-1] if self.queue else None

    def _pop(self) -> Union[Request, LaneSnapshot]:
        return heapq.heappop(self.queue)[-1]

    def _pop_admissible(self) -> Optional[Union[Request, LaneSnapshot]]:
        """Pop the next item admission should take.  Without a tenancy
        controller this is the plain heap head.  With one, entries of
        quota-blocked tenants (lane cap reached, token bucket empty) are
        passed over, and WITHIN a priority class the backlogged tenant
        with the smallest WFQ virtual time goes first.  vtime moves with
        every committed token, so the fair-share ordering is computed at
        pop time over a linear scan — the heap keys keep providing the
        class/EDF/seq order for the tenancy-free path and the
        tie-breaks.  Returns None when nothing is quota-admissible."""
        if not self.queue:
            return None
        if self.tenancy is None:
            return heapq.heappop(self.queue)[-1]
        adm: Dict[Optional[str], bool] = {}
        best_i, best_key = None, None
        for i, (p, dl, seq, item) in enumerate(self.queue):
            req = item.req if isinstance(item, LaneSnapshot) else item
            ok = adm.get(req.tenant)
            if ok is None:
                ok = adm[req.tenant] = self.tenancy.may_admit(req.tenant)
            if not ok:
                continue
            key = (p, self.tenancy.vtime(req.tenant), dl, seq)
            if best_key is None or key < best_key:
                best_i, best_key = i, key
        if best_i is None:
            return None
        item = self.queue.pop(best_i)[-1]
        heapq.heapify(self.queue)
        return item

    def submit(self, prompt: np.ndarray, n_tokens: int,
               sampling: SamplingParams = SamplingParams(),
               priority: int = 0,
               deadline_ms: Optional[float] = None,
               slo_tokens_per_s: Optional[float] = None,
               tenant: Optional[str] = None) -> int:
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32), n_tokens,
                      sampling, priority=priority, deadline_ms=deadline_ms,
                      slo_tokens_per_s=slo_tokens_per_s, tenant=tenant)
        now = self.clock()
        deadlines = []
        if deadline_ms is not None:
            deadlines.append(now + deadline_ms / 1e3)
        if slo_tokens_per_s:
            deadlines.append(now + n_tokens / slo_tokens_per_s)
        self._seq += 1
        self.metrics[self._uid] = {
            "arrival_t": now, "priority": priority, "seq": self._seq,
            "deadline_t": min(deadlines) if deadlines else None,
            "finish_t": None, "deadline_hit": None, "preempted": 0,
            "shed": 0, "tenant": tenant,
        }
        if self.tenancy is not None:
            self.tenancy.note_enqueue(tenant)
        self._push(req)
        return self._uid

    # ---------------- router hand-off (serving/router.py) ---------- #
    def enqueue(self, req: Request,
                deadline_t: Optional[float] = None) -> int:
        """Insert a pre-built ``Request`` PRESERVING its uid — the
        router's placement path (router-global uids) and the re-prefill
        failover fallback both re-enqueue the same request object on a
        different replica.  ``deadline_t`` carries an absolute deadline
        already computed against the shared clock (failed-over work keeps
        its original deadline; None recomputes from the request's SLO
        fields as ``submit`` would)."""
        now = self.clock()
        if deadline_t is None:
            deadlines = []
            if req.deadline_ms is not None:
                deadlines.append(now + req.deadline_ms / 1e3)
            if req.slo_tokens_per_s:
                deadlines.append(now + req.n_tokens / req.slo_tokens_per_s)
            deadline_t = min(deadlines) if deadlines else None
        self._uid = max(self._uid, req.uid)   # keep submit() uids unique
        self._seq += 1
        self.metrics[req.uid] = {
            "arrival_t": now, "priority": req.priority, "seq": self._seq,
            "deadline_t": deadline_t,
            "finish_t": None, "deadline_hit": None, "preempted": 0,
            "shed": 0, "tenant": req.tenant,
        }
        if self.tenancy is not None:
            self.tenancy.note_enqueue(req.tenant)
        self._push(req)
        return req.uid

    def adopt(self, item: Union[Request, LaneSnapshot],
              row: Dict[str, Any]) -> None:
        """Requeue work migrated from another replica — a drained /
        failed-over ``LaneSnapshot`` or a still-queued ``Request`` —
        carrying its SLO bookkeeping row.  The row's absolute times are
        valid here because every replica of a router shares one clock;
        only the seq tie-break is re-stamped (per-replica counters
        collide), so adopt in the source's seq order to preserve
        relative arrival."""
        req = item.req if isinstance(item, LaneSnapshot) else item
        self._uid = max(self._uid, req.uid)
        self._seq += 1
        row = dict(row)
        row["seq"] = self._seq
        row.setdefault("tenant", req.tenant)
        self.metrics[req.uid] = row
        if self.tenancy is not None:
            self.tenancy.note_enqueue(req.tenant)
        self._push(item)

    def extract_pending(self) -> List[tuple]:
        """Drain the queue for redistribution (replica drain / death):
        returns ``[(item, metrics_row), ...]`` in queue-seq order and
        forgets the entries locally.  In-flight LANES are not touched —
        the caller suspends or abandons those separately."""
        entries = sorted(self.queue, key=lambda e: e[-2])
        self.queue = []
        out = []
        for e in entries:
            item = e[-1]
            req = item.req if isinstance(item, LaneSnapshot) else item
            out.append((item, self.metrics[req.uid]))
        return out

    # ---------------- server front end (serving/server.py) ---------- #
    def _remove_queued(self, uid: int) \
            -> Optional[Union[Request, LaneSnapshot]]:
        for i, e in enumerate(self.queue):
            item = e[-1]
            req = item.req if isinstance(item, LaneSnapshot) else item
            if req.uid == uid:
                self.queue.pop(i)
                heapq.heapify(self.queue)
                return item
        return None

    def _finish_cancelled(self, req: Request) -> None:
        self.done[req.uid] = req
        m = self.metrics[req.uid]
        m["finish_t"] = self.clock()
        m["deadline_hit"] = None      # cancelled: excluded from SLO stats
        self.n_cancelled += 1
        if self.tenancy is not None:
            n = 0 if req.result is None else int(len(req.result))
            self.tenancy.note_done(req.tenant, req.uid, n, cancelled=True)

    def cancel(self, uid: int) -> bool:
        """Cancel a live request (the server's client-disconnect path).
        A queued entry is removed — a suspended victim's snapshot is
        discarded through the engine, so its exported stash bytes
        release; a running lane goes through the engine's freeze-native
        ``cancel_request`` (suspend + drop).  Either way no scheduler
        entry is stranded: the uid lands in ``done`` with status
        ``CANCELLED`` and its partial tokens as the result.  Returns
        False when the uid already finished — including retiring during
        the cancel's own ring flush, in which case it is too late to
        cancel and the completed result surfaces via ``step`` as
        normal."""
        if uid in self.done or uid not in self.metrics:
            return False
        item = self._remove_queued(uid)
        if item is not None:
            req = item.req if isinstance(item, LaneSnapshot) else item
            if isinstance(item, LaneSnapshot):
                self.engine.discard_snapshot(item)
                req.result = np.asarray(item.generated[: req.n_tokens],
                                        np.int32)
            else:
                req.result = np.zeros(0, np.int32)
            req.status = RequestStatus.CANCELLED
            self._finish_cancelled(req)
            return True
        req = self.engine.cancel_request(uid)
        if req is None:
            return False
        self._finish_cancelled(req)
        return True

    def pause(self, uid: int) -> Optional[Union[Request, LaneSnapshot]]:
        """Freeze-native backpressure (the server's consumer queue is
        full): suspend the uid's lane — or pull its still-queued entry —
        and hand the item to the caller WITHOUT requeueing it, so the
        scheduler cannot resume it until the caller gives it back via
        :meth:`release`.  Returns None when the uid is not pauseable
        right now (already finishing, or mid-install on the paged
        engine)."""
        if uid in self.done or uid not in self.metrics:
            return None
        item = self._remove_queued(uid)
        if item is not None:
            return item
        eng = self.engine
        for i, l in enumerate(eng.lanes):
            if l.request is not None and l.request.uid == uid:
                t0 = self.clock()
                snap = eng.suspend_lane(i)
                self._obs("_suspend_s", self.clock() - t0)
                if snap is None:
                    return None           # retired during the flush
                if self.tenancy is not None:
                    self.tenancy.note_release(snap.req.tenant, uid)
                return snap
        return None

    def release(self, item: Union[Request, LaneSnapshot]) -> None:
        """Requeue a paused item (the consumer drained its queue)."""
        req = item.req if isinstance(item, LaneSnapshot) else item
        if self.tenancy is not None:
            self.tenancy.note_enqueue(req.tenant)
        self._push(item)

    # ---------------- admission + preemption ---------------- #
    def _admit_free(self) -> None:
        """Fill every free lane from the queue in policy order (resuming
        suspended victims through the engine's restore path).  Ladder
        stage 3+ (host-stash pressure at ``throttle_admissions``) holds
        the queue: every admission/resume brings more pages that will
        freeze into the already-over-budget stash, so new work waits
        until the pressure drains.  Queued requests are delayed, never
        altered.  The gate reads ``admission_pressure`` (stash PLUS
        exported snapshot bytes) rather than the raw stash gauge: a shed
        victim's export dips the gauge below the threshold for exactly
        as long as it stays suspended, and resuming it imports every
        byte back — hysteresis that stops the shed rung and this loop
        ping-ponging one lane's pages in and out of the store.  An IDLE
        engine is never throttled — with zero active
        lanes nothing can drain the pressure, so holding the queue would
        starve it forever (and the shed rung never takes the last running
        lane, so admit-then-shed cannot ping-pong a lone request).  The
        gate is re-checked per admission so the idle exemption admits
        exactly one item under pressure, not a full refill."""
        eng = self.engine
        admitted = 0
        while self.queue and eng.has_free_lane:
            if (eng.n_active_lanes + admitted) > 0 and \
                    eng.admission_pressure >= \
                    eng.ladder_cfg.throttle_admissions:
                eng.robust["ladder_throttle"] += 1
                return
            item = self._pop_admissible()
            if item is None:
                return                      # nothing quota-admissible
            req = item.req if isinstance(item, LaneSnapshot) else item
            if isinstance(item, LaneSnapshot):
                t0 = self.clock()
                eng.resume_lane(item)
                self._obs("_resume_s", self.clock() - t0)
            else:
                eng.admit(item)
            if self.tenancy is not None:
                self.tenancy.note_admit(req.tenant, req.uid)
            admitted += 1

    def _est_service_s(self, item: Union[Request, LaneSnapshot]) -> float:
        """Rough wall estimate to serve `item` from (re-)admission: chunked
        prefill steps (paged) or one blocking prefill (contiguous) plus
        one engine step per decode token.  A resumed snapshot on the paged
        engine needs no prefill and only its remaining tokens — its pool
        slice pushes straight back."""
        if self._step_s is None:
            return 0.0
        chunk = getattr(self.engine, "prefill_chunk", None)
        if isinstance(item, LaneSnapshot) and item.started:
            remaining = item.req.n_tokens - len(item.generated)
            pre = 0 if chunk else 1          # contiguous resume re-prefills
            return (pre + max(remaining, 0)) * self._step_s
        req = item.req if isinstance(item, LaneSnapshot) else item
        pre = math.ceil(len(req.prompt) / chunk) if chunk else 1
        return (pre + req.n_tokens) * self._step_s

    def _est_free_s(self, lanes: List[int]) -> float:
        """Estimated wall time until the first of `lanes` frees naturally
        (shortest remaining decode; the async pipeline's host view may lag
        one step — immaterial for an EMA-scaled estimate)."""
        if self._step_s is None or not lanes:
            return 0.0
        rem = min(self.engine.lanes[i].request.n_tokens
                  - len(self.engine.lanes[i].generated) for i in lanes)
        return max(rem, 0) * self._step_s

    def _obs(self, attr: str, dt: float) -> None:
        """Fold one wall-time observation into an EMA attribute (same
        0.7/0.3 blend as the step-time EMA)."""
        cur = getattr(self, attr)
        setattr(self, attr, dt if cur is None else 0.7 * cur + 0.3 * dt)

    def preempt_cost_s(self) -> float:
        """Predicted wall cost of one preemption cycle: suspending the
        victim now plus resuming its snapshot later, from the measured
        EMAs.  0.0 until both legs have been observed — a cost model
        calibrated from nothing would only ever veto, so the scheduler
        preempts freely first and lets the measurements argue back."""
        if self._suspend_s is None or self._resume_s is None:
            return 0.0
        return self._suspend_s + self._resume_s

    def _pick_victim(self, priority: int) -> Optional[int]:
        """The least valuable running lane strictly below `priority`:
        lowest class first, then fewest prior preemptions, then most
        remaining work (it would hold the lane longest), then latest
        deadline.  The prior-preemption key spreads victims across lanes
        — repeatedly preempting the same lane concentrates every inserted
        foreground on one lane's timeline, and the unmatched insertions
        surface later as an unpaired drain tail.  Lanes already being
        preempted into (a pending ``admit_over`` prefill) are not victims
        twice."""
        pending = getattr(self.engine, "prefills", {})
        best, best_rank = None, None
        for i, l in enumerate(self.engine.lanes):
            if l.request is None or l.request.priority <= priority \
                    or i in pending:
                continue
            dl = self._deadline_t(l.request.uid)
            rank = (-l.request.priority,
                    self.metrics[l.request.uid]["preempted"],
                    -(l.request.n_tokens - len(l.generated)),
                    -(dl if dl is not None else _INF))
            if best_rank is None or rank < best_rank:
                best, best_rank = i, rank
        return best

    def _maybe_preempt(self) -> None:
        """Preempt a running lane when the best pending request (a) has a
        deadline it is predicted to miss by waiting, and (b) a strictly
        lower-priority lane is running — at most one preemption per
        scheduling pass (one per engine step is plenty of cadence).
        Victims re-enter the queue as resumable ``LaneSnapshot``s under
        their own priority/deadline."""
        if not self.preemption:
            return
        if self.queue and not self.engine.has_free_lane:
            head = self._peek()
            req = head.req if isinstance(head, LaneSnapshot) else head
            dl = self._deadline_t(req.uid)
            if dl is None:
                return                      # no deadline -> no urgency
            if self.tenancy is not None \
                    and not self.tenancy.may_admit(req.tenant):
                return    # quota-blocked: a freed lane couldn't seat it
            running = [i for i, l in enumerate(self.engine.lanes)
                       if l.request is not None]
            wait = self._est_free_s(running)
            if self.clock() + wait + self._est_service_s(head) <= dl:
                return                      # on track without preempting
            # cost model: preempting buys at most `wait` (the natural
            # time-to-free) for the head, and costs a suspend now plus a
            # resume later.  When the overhead eats the whole gain the
            # preemption is pure churn — skip it and let the lane free
            # naturally.
            cost = self.preempt_cost_s()
            if cost > 0.0 and wait <= cost:
                self.n_preempt_skipped_cost += 1
                return
            victim = self._pick_victim(self._eff_priority(req))
            if victim is None:
                return                      # nothing less important runs
            if not isinstance(head, LaneSnapshot) \
                    and hasattr(self.engine, "admit_over"):
                # install-time preemption (paged engine): the preemptor's
                # prefill runs in scratch while the victim keeps decoding;
                # the victim's snapshot surfaces via drain_suspended()
                # once the prefill installs — preemption costs the victim
                # only the lane-time the preemptor actually decodes
                self._pop()
                self.engine.admit_over(req, victim)
            else:
                # immediate suspension: resuming a snapshot needs the lane
                # free NOW (its pool slice pushes right back), and the
                # contiguous engine has no scratch prefill to overlap
                vic = self.engine.lanes[victim].request
                t0 = self.clock()
                snap = self.engine.suspend_lane(victim)
                self._obs("_suspend_s", self.clock() - t0)
                if snap is not None:
                    self.metrics[vic.uid]["preempted"] += 1
                    self.n_preemptions += 1
                    if self.tenancy is not None:
                        self.tenancy.note_release(vic.tenant, vic.uid)
                    self._push(snap)
                # the freed lane is filled by the _admit_free that follows
            return

    def _maybe_shed(self) -> None:
        """Ladder stage 4 (load shed): suspend the least-valuable running
        lane through the freeze-native snapshot path and requeue it under
        its own priority/seq.  Shedding moves the lane's stash pages out
        of the controller store (``export_lane``), dropping the measured
        pressure immediately; the request resumes **token-identically**
        once the throttle rung clears, marked ``shed-resumed`` at
        retirement.  The last running lane is never shed — some lane must
        keep retiring work or the pressure could never drain."""
        eng = self.engine
        if eng.stash_pressure < eng.ladder_cfg.shed \
                or eng.n_active_lanes <= 1:
            return
        victim = self._pick_victim(-1)      # any running lane qualifies
        if victim is None:
            return
        req = self.engine.lanes[victim].request
        t0 = self.clock()
        snap = self.engine.suspend_lane(victim)
        self._obs("_suspend_s", self.clock() - t0)
        if snap is None:
            return                          # retired during the flush
        req.status = RequestStatus.SHED
        self.metrics[req.uid]["shed"] += 1
        self.engine.robust["ladder_shed"] += 1
        if self.tenancy is not None:
            self.tenancy.note_release(req.tenant, req.uid)
        self._push(snap)

    def _schedule(self) -> None:
        self._apply_aging()
        self._maybe_shed()
        self._maybe_preempt()
        self._admit_free()

    # ---------------- serving loop ---------------- #
    @property
    def busy(self) -> bool:
        """The engine still has work: active lanes, a pending chunked
        prefill (an ``admit_over`` whose victim retired mid-prefill holds
        no request yet, but its admission must still be driven home), or
        retirements parked in the engine's backlog.  The backlog term
        matters at shutdown: a request that retires during the flush
        inside ``suspend_lane`` is re-reported by the next ``step_once``
        — without it the loop could go idle at that exact moment and
        exit with the finished request stranded, never entering
        ``done``."""
        return self.engine.n_active_lanes > 0 \
            or bool(getattr(self.engine, "prefills", None)) \
            or self.engine.n_pending_retired > 0

    def step(self) -> List[int]:
        """One scheduling pass + one engine step; returns completed uids.
        The building block for external drivers with timed arrivals
        (``benchmarks/scheduling.py``)."""
        self._schedule()
        if not self.busy:
            return []
        t0 = self.clock()
        retired = self.engine.step_once()
        dt = self.clock() - t0
        self._step_s = dt if self._step_s is None \
            else 0.7 * self._step_s + 0.3 * dt
        if self.tenancy is not None:
            # charge each tenant the committed tokens its lanes gained
            # this step (delta-based: rewinds shrink `generated` and are
            # simply not refunded)
            for l in self.engine.lanes:
                if l.request is not None:
                    self.tenancy.note_progress(
                        l.request.tenant, l.request.uid, len(l.generated))
        for snap in self.engine.drain_suspended():
            self.metrics[snap.req.uid]["preempted"] += 1
            self.n_preemptions += 1
            if self.tenancy is not None:
                self.tenancy.note_progress(snap.req.tenant, snap.req.uid,
                                           len(snap.generated))
                self.tenancy.note_release(snap.req.tenant, snap.req.uid)
            self._push(snap)
        out = []
        now = self.clock()
        for req in retired:
            self.done[req.uid] = req
            m = self.metrics[req.uid]
            m["finish_t"] = now
            dl = m["deadline_t"]
            m["deadline_hit"] = None if dl is None else bool(now <= dl)
            if self.tenancy is not None:
                self.tenancy.note_done(req.tenant, req.uid,
                                       int(len(req.result)))
            out.append(req.uid)
        return out

    def run_once(self) -> List[int]:
        """Serve until at least one request completes (lanes refill from
        the queue as they free); returns the completed uids."""
        out: List[int] = []
        while not out:
            out = self.step()
            if not out and not self.busy:
                break
        return out

    def run(self) -> None:
        while self.queue or self.busy:
            if not self.run_once():
                break


class StaticScheduler:
    """Original static FIFO batcher (head-of-line blocking by design): pads
    a fixed batch, runs every lane for max(n_tokens) steps, then admits the
    next batch.  Kept as the benchmark baseline.  ``Engine.generate``
    applies ONE ``SamplingParams`` to the whole padded batch, so a batch
    mixing sampling configs is rejected loudly instead of silently decoding
    everyone with ``batch[0]``'s temperature — the limitation that
    motivated per-lane sampling in the continuous engine."""

    def __init__(self, engine: Engine, batch_size: int, pad_id: int = 0):
        self.engine = engine
        self.batch_size = batch_size
        self.pad_id = pad_id
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._uid = 0

    def submit(self, prompt: np.ndarray, n_tokens: int,
               sampling: SamplingParams = SamplingParams()) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  n_tokens, sampling))
        return self._uid

    def run_once(self) -> List[int]:
        """Serve one padded batch from the queue; returns completed uids."""
        if not self.queue:
            return []
        batch = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        mixed = {r.sampling for r in batch}
        if len(mixed) > 1:
            raise ValueError(
                "StaticScheduler pads one jitted batch and Engine.generate "
                f"applies a single SamplingParams to all of it, but this "
                f"batch mixes {len(mixed)} configs: {sorted(map(str, mixed))}"
                ". Submit homogeneous batches or use the continuous "
                "Scheduler (per-lane sampling).")
        n_lanes = self.batch_size
        max_prompt = max(len(r.prompt) for r in batch)
        n_gen = max(r.n_tokens for r in batch)
        toks = np.full((n_lanes, max_prompt), self.pad_id, np.int32)
        for i, r in enumerate(batch):
            toks[i, max_prompt - len(r.prompt):] = r.prompt  # left-pad
        res = self.engine.generate({"tokens": jnp.asarray(toks)}, n_gen,
                                   sampling=batch[0].sampling)
        out = []
        for i, r in enumerate(batch):
            r.result = res.tokens[i, : r.n_tokens]
            self.done[r.uid] = r
            out.append(r.uid)
        return out

    def run(self) -> None:
        while self.queue:
            self.run_once()

"""Request scheduling over the serving engines.

``Scheduler`` is a thin admission queue over ``ContinuousEngine`` or
``PagedContinuousEngine``: it holds pending requests and feeds one into a
lane the moment that lane retires — mid-generation — so short requests
never wait for a long co-batched one (no head-of-line blocking).  All
batching mechanics (per-lane prefill — whole-prompt or chunked — freeze
state reset, entropy-guided recovery servicing, retirement) live in the
engine; the scheduler only sees lanes becoming free.  A recovery rewind
keeps its lane busy longer (the request replays ``rewalk_tokens``), which
to the scheduler is indistinguishable from a longer generation.

Both engines default to the async DMA pipeline (serving/dma.py): a
request may retire one ``step_once`` call after its final token was
computed — the scheduler's admit-on-free loop is agnostic to that lag,
and completions are never lost (``step_once`` reports every retirement
exactly when the host commits it).

``StaticScheduler`` keeps the pre-continuous-batching (pre-PR-1)
fixed-batch FIFO behaviour — pad a batch, run everyone for max(n_tokens)
steps, only then admit more — as the comparison baseline for
``benchmarks/continuous_batching.py``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import (ContinuousEngine, Engine,
                                  PagedContinuousEngine, Request)
from repro.serving.sampling import SamplingParams


class Scheduler:
    """FIFO admission queue over a continuous-batching engine (contiguous
    or paged — both expose the same admit/step_once lane lifecycle)."""

    def __init__(self,
                 engine: Union[Engine, ContinuousEngine,
                               PagedContinuousEngine],
                 batch_size: Optional[int] = None, pad_id: int = 0, **kw):
        if isinstance(engine, (ContinuousEngine, PagedContinuousEngine)):
            self.engine = engine
        else:
            self.engine = ContinuousEngine.from_engine(
                engine, n_lanes=batch_size or 1, pad_id=pad_id, **kw)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._uid = 0

    def submit(self, prompt: np.ndarray, n_tokens: int,
               sampling: SamplingParams = SamplingParams()) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  n_tokens, sampling))
        return self._uid

    def _admit_free(self) -> None:
        while self.queue and self.engine.has_free_lane:
            self.engine.admit(self.queue.pop(0))

    def run_once(self) -> List[int]:
        """Serve until at least one request completes (lanes refill from the
        queue as they free); returns the completed uids."""
        out: List[int] = []
        while not out:
            self._admit_free()
            if not self.engine.n_active_lanes:
                break
            for req in self.engine.step_once():
                self.done[req.uid] = req
                out.append(req.uid)
        return out

    def run(self) -> None:
        while self.queue or self.engine.n_active_lanes:
            if not self.run_once():
                break


class StaticScheduler:
    """Original static FIFO batcher (head-of-line blocking by design): pads
    a fixed batch, runs every lane for max(n_tokens) steps, then admits the
    next batch.  Kept as the benchmark baseline; note it applies one
    request's SamplingParams to the whole batch — the limitation that
    motivated per-lane sampling in the continuous engine."""

    def __init__(self, engine: Engine, batch_size: int, pad_id: int = 0):
        self.engine = engine
        self.batch_size = batch_size
        self.pad_id = pad_id
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._uid = 0

    def submit(self, prompt: np.ndarray, n_tokens: int,
               sampling: SamplingParams = SamplingParams()) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  n_tokens, sampling))
        return self._uid

    def run_once(self) -> List[int]:
        """Serve one padded batch from the queue; returns completed uids."""
        if not self.queue:
            return []
        batch = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        n_lanes = self.batch_size
        max_prompt = max(len(r.prompt) for r in batch)
        n_gen = max(r.n_tokens for r in batch)
        toks = np.full((n_lanes, max_prompt), self.pad_id, np.int32)
        for i, r in enumerate(batch):
            toks[i, max_prompt - len(r.prompt):] = r.prompt  # left-pad
        res = self.engine.generate({"tokens": jnp.asarray(toks)}, n_gen,
                                   sampling=batch[0].sampling)
        out = []
        for i, r in enumerate(batch):
            r.result = res.tokens[i, : r.n_tokens]
            self.done[r.uid] = r
            out.append(r.uid)
        return out

    def run(self) -> None:
        while self.queue:
            self.run_once()

"""Host<->device transfer pipeline for the serving engines.

The serving hot path used to block on a ``jax.device_get`` every decode
step (tokens + telemetry) and on per-lane pool slices at every page
boundary.  This module provides the three primitives that make the step
loop asynchronous with respect to the host:

* ``TransferStats`` — accounting for every host<->device transfer the
  engine issues, split into *blocking* (the host stalled on data that was
  not already in flight) and *async* (issued early, consumed after the
  device had time to produce it).  ``host_blocked_fraction`` — the share
  of engine steps that stalled on at least one blocking transfer — is the
  benchmark's pipeline-health metric: the synchronous path sits at 1.0 by
  construction, the async pipeline only blocks at page-boundary ticks.

* ``FetchRing`` — the double-buffered device->host fetch ring.  At step N
  the engine pushes the step's device arrays (sampled tokens, entropy /
  freeze telemetry, recovery requests) and immediately starts their D2H
  copies (``jax.Array.copy_to_host_async``); the entry is materialized at
  step N+1, by which point the copy has overlapped the host's post-dispatch
  work (prefill chunk prep, event logging, the next tick's maintenance).
  Depth 0 degenerates to the synchronous path — push immediately followed
  by a blocking pop — so both modes share one code path and differ only in
  when the host waits.

* ``HostStaging`` — reused host-side staging buffers for the batched
  boundary-tick swap DMA.  On TPU these would be pinned host allocations
  (the DMA engine requirement for async H2D); here they model the reuse:
  one buffer per transfer role, reallocated only when shapes change, so
  steady-state ticks allocate nothing.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple


def _nbytes(x) -> int:
    try:
        return int(x.nbytes)
    except Exception:                      # scalars / python ints
        return 0


@dataclasses.dataclass
class TransferStats:
    """Counts every host<->device transfer an engine issues.

    *Blocking* transfers stall the host: a direct ``device_get`` /
    ``device_put`` whose data was not already in flight (boundary-tick pool
    pulls, un-prefetched thaw uploads, depth-0 ring pops).  *Async*
    transfers were issued ahead of use (ring fetches, speculative thaw
    staging) — the host may still wait on them at consume time, but the
    wait is overlap-compensated and recorded separately as ``waited_s``.
    """
    blocking_d2h: int = 0
    blocking_h2d: int = 0
    async_d2h: int = 0
    async_h2d: int = 0
    d2h_bytes: int = 0
    h2d_bytes: int = 0
    blocked_s: float = 0.0      # host time inside blocking transfers
    waited_s: float = 0.0       # host time waiting on async-issued data
    steps: int = 0              # engine steps observed (begin/end bracket)
    blocked_steps: int = 0      # steps with >= 1 blocking transfer
    _step_open: bool = dataclasses.field(default=False, repr=False)
    _step_blocked: bool = dataclasses.field(default=False, repr=False)

    # ---- per-step bracketing ---------------------------------------- #
    def begin_step(self) -> None:
        self._step_open = True
        self._step_blocked = False

    def end_step(self) -> None:
        if not self._step_open:
            return
        self.steps += 1
        if self._step_blocked:
            self.blocked_steps += 1
        self._step_open = False

    def cancel_step(self) -> None:
        """Close the bracket without counting it (no jitted step ran —
        e.g. a drain-only or prefill-only engine call)."""
        self._step_open = False

    # ---- transfer notes --------------------------------------------- #
    def note_blocking(self, nbytes: int, d2h: bool, seconds: float = 0.0
                      ) -> None:
        if d2h:
            self.blocking_d2h += 1
            self.d2h_bytes += nbytes
        else:
            self.blocking_h2d += 1
            self.h2d_bytes += nbytes
        self.blocked_s += seconds
        if self._step_open:
            self._step_blocked = True

    def note_async(self, nbytes: int, d2h: bool, seconds: float = 0.0
                   ) -> None:
        if d2h:
            self.async_d2h += 1
            self.d2h_bytes += nbytes
        else:
            self.async_h2d += 1
            self.h2d_bytes += nbytes
        self.waited_s += seconds

    # ---- derived metrics -------------------------------------------- #
    @property
    def host_blocked_fraction(self) -> float:
        """Share of engine steps that stalled on a blocking transfer."""
        return self.blocked_steps / self.steps if self.steps else 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {
            "blocking_d2h": self.blocking_d2h,
            "blocking_h2d": self.blocking_h2d,
            "async_d2h": self.async_d2h,
            "async_h2d": self.async_h2d,
            "d2h_bytes": self.d2h_bytes,
            "h2d_bytes": self.h2d_bytes,
            "blocked_s": round(self.blocked_s, 4),
            "waited_s": round(self.waited_s, 4),
            "steps": self.steps,
            "blocked_steps": self.blocked_steps,
            "host_blocked_fraction": round(self.host_blocked_fraction, 4),
        }


class FetchRing:
    """Double-buffered async device->host fetch ring.

    ``push(meta, arrays)`` starts the D2H copy of every array and enqueues
    the entry; ``pop()`` materializes the oldest entry to numpy.  With
    ``depth >= 1`` the engine consumes entries one step after pushing them
    — the copy overlaps the intervening host work and device compute (and
    the pop is recorded as an *async* transfer).  With ``depth == 0`` the
    engine pops right after pushing (the synchronous baseline: the pop is
    recorded as *blocking*).

    The ring never reorders: entries drain FIFO, so host bookkeeping
    (token commits, rewinds, thaw requests, retirement) is applied in
    exactly the order the synchronous path applies it — which is what
    makes async-vs-sync token parity exact.
    """

    def __init__(self, stats: TransferStats, depth: int = 1,
                 endpoint: Optional[Any] = None):
        assert depth in (0, 1), "the pipeline is single- or double-buffered"
        self.stats = stats
        self.depth = depth
        # optional faults.Endpoint guarding the pop materialization (the
        # "ring" injection point).  must_succeed: a step's tokens/telemetry
        # either reach the host or the engine has nothing to commit.  The
        # engine watches this endpoint's breaker and drops ``depth`` to 0
        # (the synchronous baseline — token-identical by the FIFO-drain
        # design above) while it is tripped.
        self.endpoint = endpoint
        self._entries: List[Tuple[Dict[str, Any], Dict[str, Any]]] = []

    def __len__(self) -> int:
        return len(self._entries)

    def push(self, meta: Dict[str, Any], arrays: Dict[str, Any]) -> None:
        for a in arrays.values():
            start = getattr(a, "copy_to_host_async", None)
            if start is not None:
                start()
        self._entries.append((meta, arrays))

    def pop(self) -> Optional[Tuple[Dict[str, Any], Dict[str, Any]]]:
        """Materialize and return the oldest (meta, host arrays) entry."""
        if not self._entries:
            return None
        import numpy as np
        meta, arrays = self._entries.pop(0)
        t0 = time.perf_counter()

        def _materialize():
            return {k: np.asarray(v) for k, v in arrays.items()}

        if self.endpoint is not None:
            host = self.endpoint.call(_materialize)
        else:
            host = _materialize()
        dt = time.perf_counter() - t0
        nbytes = sum(_nbytes(v) for v in host.values())
        if self.depth == 0:
            self.stats.note_blocking(nbytes, d2h=True, seconds=dt)
        else:
            self.stats.note_async(nbytes, d2h=True, seconds=dt)
        return meta, host

    def drain(self):
        """Pop every pending entry (oldest first)."""
        while self._entries:
            yield self.pop()


class HostStaging:
    """Reused host staging buffers (the pinned-memory stand-in).

    ``buf(name, shape, dtype)`` returns a numpy buffer that persists across
    calls; it is reallocated only when the requested shape/dtype changes,
    so the steady-state boundary tick reuses the same allocation for its
    pull/push staging.  ``put(name, src)`` copies ``src`` into the named
    buffer and returns it.
    """

    def __init__(self):
        self._bufs: Dict[str, Any] = {}

    def buf(self, name: str, shape, dtype):
        import numpy as np
        b = self._bufs.get(name)
        if b is None or b.shape != tuple(shape) or b.dtype != np.dtype(dtype):
            b = np.empty(shape, dtype)
            self._bufs[name] = b
        return b

    def put(self, name: str, src):
        import numpy as np
        b = self.buf(name, src.shape, src.dtype)
        np.copyto(b, src)
        return b

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._bufs.values())

"""Per-tenant quotas and weighted fair sharing for the SLO scheduler.

ARKV's framing (PAPERS.md) is KV management *under a limited memory
budget per workload*; FreeKV's lesson is that the system win comes from
pairing the KV algorithm with the serving layer.  This module is that
pairing's policy half: the scheduler's admission loop consults a
``TenancyController`` so one hog tenant cannot monopolize the lanes (and
with them the freeze/stash machinery's device + host budgets) that every
tenant shares.

Three mechanisms, all host-side bookkeeping (no jax import):

* **Weighted fair sharing** — classic virtual-time WFQ over *committed
  decode tokens*: serving ``n`` tokens of tenant ``t`` advances
  ``vtime[t]`` by ``n / weight[t]``, and admission (within a priority
  class) picks the backlogged tenant with the smallest vtime.  Over any
  saturated window each backlogged tenant's goodput converges to its
  weight share, regardless of how much the others submit.  A tenant
  returning from idle is snapped forward to the smallest active vtime so
  idleness banks no credit (standard WFQ start-time rule).

* **Concurrent-lane caps** — ``max_lanes`` bounds how many engine lanes
  a tenant occupies at once (admissions + snapshot resumes both count;
  suspensions give the lane back).

* **Token-rate caps** — a token bucket per tenant (``tokens_per_s``
  refill up to ``burst_tokens`` deep).  Committed tokens drain the
  bucket; a tenant whose bucket is empty is not admitted until it
  refills.  Running lanes are never throttled mid-request — the bucket
  may overdraw by one request's tail, which the refill then pays off
  (the classic soft-limit trade that avoids mid-stream stalls).

Requests with ``tenant=None`` bypass tenancy entirely (untenanted
traffic keeps the pre-tenancy scheduler behaviour bit-for-bit).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterable, Optional

_INF = float("inf")


@dataclasses.dataclass
class TenantConfig:
    """One tenant's contract.  ``weight`` scales its fair share of lane
    time; ``max_lanes`` caps concurrent lanes (None = engine-wide);
    ``tokens_per_s`` rate-caps committed decode tokens (None = uncapped)
    with a bucket ``burst_tokens`` deep (None = one second of refill)."""
    name: str
    weight: float = 1.0
    max_lanes: Optional[int] = None
    tokens_per_s: Optional[float] = None
    burst_tokens: Optional[float] = None

    def __post_init__(self):
        assert self.weight > 0, "tenant weight must be positive"
        if self.burst_tokens is None and self.tokens_per_s is not None:
            self.burst_tokens = self.tokens_per_s


class _TenantState:
    __slots__ = ("cfg", "vtime", "bucket", "last_refill", "active",
                 "progress", "goodput_tokens", "admitted", "completed",
                 "cancelled", "throttled_lanes", "throttled_rate")

    def __init__(self, cfg: TenantConfig, now: float):
        self.cfg = cfg
        self.vtime = 0.0
        self.bucket = cfg.burst_tokens if cfg.burst_tokens is not None \
            else _INF
        self.last_refill = now
        self.active: set = set()          # uids currently holding a lane
        self.progress: Dict[int, int] = {}  # uid -> tokens already charged
        self.goodput_tokens = 0           # committed tokens, all requests
        self.admitted = 0
        self.completed = 0
        self.cancelled = 0
        self.throttled_lanes = 0          # admission denials by cause
        self.throttled_rate = 0


class TenancyController:
    """Shared tenancy state: one instance per scheduler, or ONE instance
    passed (via ``sched_kw``) to every replica of a ``ReplicaRouter`` so
    caps and fair shares hold across the whole replica set.

    ``default`` (a ``TenantConfig`` template, name ignored) governs
    tenants that were never registered; without it unknown tenants get
    weight-1 uncapped configs — open admission, fairness still applies."""

    def __init__(self, tenants: Iterable[TenantConfig] = (),
                 default: Optional[TenantConfig] = None,
                 clock=time.monotonic):
        self.clock = clock
        self.default = default
        self._t: Dict[str, _TenantState] = {}
        for cfg in tenants:
            self.register(cfg)

    def register(self, cfg: TenantConfig) -> None:
        self._t[cfg.name] = _TenantState(cfg, self.clock())

    def _state(self, tenant: str) -> _TenantState:
        st = self._t.get(tenant)
        if st is None:
            tpl = self.default or TenantConfig(name=tenant)
            cfg = dataclasses.replace(tpl, name=tenant)
            st = _TenantState(cfg, self.clock())
            self._t[tenant] = st
        return st

    def _refill(self, st: _TenantState) -> None:
        now = self.clock()
        dt = now - st.last_refill
        st.last_refill = now
        if st.cfg.tokens_per_s is not None:
            st.bucket = min(st.bucket + dt * st.cfg.tokens_per_s,
                            st.cfg.burst_tokens)

    # ---------------- admission-side interface ---------------- #
    def may_admit(self, tenant: Optional[str]) -> bool:
        """Quota gate for one queued item: lane cap + token bucket.
        Untenanted items always pass."""
        if tenant is None:
            return True
        st = self._state(tenant)
        self._refill(st)
        if st.cfg.max_lanes is not None \
                and len(st.active) >= st.cfg.max_lanes:
            st.throttled_lanes += 1
            return False
        if st.bucket <= 0:
            st.throttled_rate += 1
            return False
        return True

    def vtime(self, tenant: Optional[str]) -> float:
        """WFQ ordering key: untenanted traffic sorts ahead (vtime -inf
        keeps it strictly pre-tenancy: FIFO-within-class, no fairness
        reshuffling of untagged requests)."""
        if tenant is None:
            return -_INF
        return self._state(tenant).vtime

    def note_enqueue(self, tenant: Optional[str]) -> None:
        """A tenant coming back from idle (no active lanes) snaps its
        vtime forward to the busiest tenants' floor — idleness must not
        bank fair-share credit against currently-backlogged tenants."""
        if tenant is None:
            return
        st = self._state(tenant)
        if not st.active:
            floor = [s.vtime for s in self._t.values() if s.active]
            if floor:
                st.vtime = max(st.vtime, min(floor))

    def note_admit(self, tenant: Optional[str], uid: int) -> None:
        if tenant is None:
            return
        st = self._state(tenant)
        if uid not in st.active:
            st.active.add(uid)
            st.admitted += 1
            st.progress.setdefault(uid, 0)

    def note_release(self, tenant: Optional[str], uid: int) -> None:
        """The uid's lane was suspended (preempt/shed/pause) — the lane
        slot frees but the request is still live, so its charged progress
        is kept for the resume."""
        if tenant is None:
            return
        self._state(tenant).active.discard(uid)

    def note_progress(self, tenant: Optional[str], uid: int,
                      tokens_total: int) -> None:
        """Charge the delta between the lane's committed token count and
        what this uid was already charged.  Rewinds shrink the count —
        never refunded (the lane-time was spent; Rewalk regeneration is
        the tenant's cost, matching how goodput counts only kept
        tokens)."""
        if tenant is None:
            return
        st = self._state(tenant)
        delta = tokens_total - st.progress.get(uid, 0)
        if delta <= 0:
            return
        st.progress[uid] = tokens_total
        st.vtime += delta / st.cfg.weight
        st.goodput_tokens += delta
        if st.cfg.tokens_per_s is not None:
            self._refill(st)
            st.bucket -= delta

    def note_done(self, tenant: Optional[str], uid: int,
                  tokens_total: int, cancelled: bool = False) -> None:
        if tenant is None:
            return
        self.note_progress(tenant, uid, tokens_total)
        st = self._state(tenant)
        st.active.discard(uid)
        st.progress.pop(uid, None)
        if cancelled:
            st.cancelled += 1
        else:
            st.completed += 1

    # ---------------- reporting ---------------- #
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        out: Dict[str, Dict[str, Any]] = {}
        for name, st in self._t.items():
            out[name] = {
                "weight": st.cfg.weight,
                "max_lanes": st.cfg.max_lanes,
                "tokens_per_s": st.cfg.tokens_per_s,
                "vtime": st.vtime,
                "bucket": None if st.bucket == _INF else st.bucket,
                "active_lanes": len(st.active),
                "goodput_tokens": st.goodput_tokens,
                "admitted": st.admitted,
                "completed": st.completed,
                "cancelled": st.cancelled,
                "throttled_lanes": st.throttled_lanes,
                "throttled_rate": st.throttled_rate,
            }
        return out

"""Token sampling: temperature / top-k / top-p (paper §4.1: T=0.7,
top-k=40, top-p=0.9; greedy T=0 for the passkey retrieval test)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.7
    top_k: int = 40
    top_p: float = 0.9

    @classmethod
    def greedy(cls) -> "SamplingParams":
        return cls(temperature=0.0, top_k=0, top_p=1.0)


def sample(logits: jnp.ndarray, key: jax.Array,
           params: SamplingParams) -> jnp.ndarray:
    """logits: (B, V) -> token ids (B,) int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    if params.top_k and params.top_k > 0:
        kth = jax.lax.top_k(logits, params.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)

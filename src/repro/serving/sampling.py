"""Token sampling: temperature / top-k / top-p (paper §4.1: T=0.7,
top-k=40, top-p=0.9; greedy T=0 for the passkey retrieval test).

Two entry points: `sample` applies one SamplingParams to the whole batch
(static batching / single request); `sample_batched` takes per-lane
temperature / top-k / top-p vectors so one jitted call serves a continuous
batch of heterogeneous requests."""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.7
    top_k: int = 40
    top_p: float = 0.9

    @classmethod
    def greedy(cls) -> "SamplingParams":
        return cls(temperature=0.0, top_k=0, top_p=1.0)


def lane_base_key(engine_key: jax.Array, admit_index) -> jax.Array:
    """Admission-ordered per-lane sampling base key.

    The j-th *admission* of an engine gets ``fold_in(engine_key, j)``;
    every draw then folds in the lane's own decode clock
    (`sample_batched_perlane`), so a lane's token at logical step k is a
    pure function of (engine seed, admission index, step) — independent of
    which global dispatch carried it, which lane slot it occupies, and how
    many other lanes were admitted in between.

    That purity is what makes the key **snapshot-stable**: a preempted
    lane's base key can be stashed in a ``LaneSnapshot`` and restored on
    resume — possibly into a *different* lane slot — and the continuation
    samples exactly the tokens the uninterrupted run would have (the
    preemption parity guarantee of serving/scheduler.py).  A resumed lane
    must restore its original admission's key, never consume a fresh
    admission index."""
    return jax.random.fold_in(engine_key, admit_index)


def sample(logits: jnp.ndarray, key: jax.Array,
           params: SamplingParams) -> jnp.ndarray:
    """logits: (B, V) -> token ids (B,) int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    if params.top_k and params.top_k > 0:
        kth = jax.lax.top_k(logits, params.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def params_arrays(params: Sequence[SamplingParams]):
    """Pack per-lane SamplingParams into the (temperature, top_k, top_p)
    vectors consumed by `sample_batched`."""
    return (jnp.asarray([p.temperature for p in params], jnp.float32),
            jnp.asarray([p.top_k for p in params], jnp.int32),
            jnp.asarray([p.top_p for p in params], jnp.float32))


def sample_batched_perlane(logits: jnp.ndarray,
                           lane_keys: jnp.ndarray,    # (B, 2) uint32 bases
                           step: jnp.ndarray,         # (B,) i32 lane clocks
                           temperature: jnp.ndarray,
                           top_k: jnp.ndarray,
                           top_p: jnp.ndarray) -> jnp.ndarray:
    """`sample_batched` with order-invariant per-lane randomness: each
    lane's draw uses ``fold_in(lane_key, step)`` of its own base key and
    its own decode clock, so the token a lane samples at logical step k
    does not depend on which global dispatch the step rode in.  This is
    what makes the async DMA pipeline token-identical to the synchronous
    path: the two interleave admissions and steps differently, and a
    single split-per-dispatch key stream would diverge between them."""
    keys = jax.vmap(jax.random.fold_in)(lane_keys, step)
    masked = _mask_logits(logits, temperature, top_k, top_p)
    toks = jax.vmap(lambda k, row: jax.random.categorical(k, row))(
        keys, masked)
    greedy = jnp.argmax(logits.astype(jnp.float32), axis=-1)
    return jnp.where(temperature <= 0.0, greedy, toks).astype(jnp.int32)


def sample_batched(logits: jnp.ndarray, key: jax.Array,
                   temperature: jnp.ndarray,   # (B,) f32; <=0 -> greedy
                   top_k: jnp.ndarray,         # (B,) i32; <=0 -> disabled
                   top_p: jnp.ndarray,         # (B,) f32; >=1 -> disabled
                   ) -> jnp.ndarray:
    """Per-lane sampling: each row of `logits` (B, V) gets its own
    temperature / top-k / top-p.  One fixed-shape jitted computation covers
    every lane mix, so continuous batching never recompiles on admission.

    Row-wise equivalent of `sample`: greedy rows take the argmax; top-k is
    a rank mask (rank < k); top-p keeps everything above the nucleus
    cutoff of the sorted distribution."""
    greedy = jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
    masked = _mask_logits(logits, temperature, top_k, top_p)
    sampled = jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def _mask_logits(logits: jnp.ndarray, temperature: jnp.ndarray,
                 top_k: jnp.ndarray, top_p: jnp.ndarray) -> jnp.ndarray:
    """Shared per-lane masking: temperature scaling, top-k as a rank mask
    (k is traced, so lax.top_k's static k won't do), then the top-p
    nucleus over the top-k-renormalized distribution (matching `sample`,
    which applies top-k before top-p); p>=1 rows keep everything (cutoff
    clamps to the min row value)."""
    B, V = logits.shape
    lf = logits.astype(jnp.float32)
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    ranks = jnp.argsort(jnp.argsort(-scaled, axis=-1), axis=-1)   # 0 = max
    k_eff = jnp.where(top_k > 0, top_k, V)[:, None]
    masked = jnp.where(ranks < k_eff, scaled, -jnp.inf)
    sorted_desc = jnp.sort(masked, axis=-1)[:, ::-1]
    cum = jnp.cumsum(jax.nn.softmax(sorted_desc, axis=-1), axis=-1)
    p_eff = jnp.where(top_p >= 1.0, 2.0, top_p)[:, None]
    cutoff_idx = jnp.minimum(jnp.sum(cum < p_eff, axis=-1, keepdims=True),
                             V - 1)
    cutoff = jnp.take_along_axis(sorted_desc, cutoff_idx, axis=-1)
    return jnp.where(masked >= cutoff, masked, -jnp.inf)

"""Deterministic fault injection + retry/backoff + circuit breaking for
the serving stack's host<->device transfer and host-stash paths.

The paper's contract — frozen/stashed KV is always recoverable — silently
assumes every DMA succeeds and host memory is infinite.  This module is
the harness that lets the repo *test* that contract under failure, and
the retry/breaker machinery that keeps serving alive when it breaks:

* ``FaultSchedule`` — a seed-deterministic plan of *which* operation at
  *which* named injection point fails (and how).  Two sources compose:
  per-site rates hashed from ``(seed, site, op_index)`` (reproducible
  without any global RNG state) and an explicit ``{(site, op): plan}``
  table for tests that need exact placement.  Replaying the same seed
  against the same trace injects the identical fault sequence — chaos
  runs are diffable.

* ``FaultInjector`` — per-site operation counters + injection stats.
  The serving code consults ``next_plan(site)`` once per guarded
  operation; sites are the catalogue in docs/robustness.md:
  ``pull`` / ``push`` (boundary-tick pool DMA), ``ring`` (per-step fetch
  materialization), ``stage`` (speculative-thaw staging upload),
  ``stash`` (host-stash allocation), ``nan`` (poisoned logits).

* ``RetryPolicy`` + ``CircuitBreaker`` + ``Endpoint`` — the production
  side.  Every guarded transfer goes through an ``Endpoint``: transient
  faults are retried with (bounded, deterministic-count) backoff; an
  endpoint whose operations keep failing trips its breaker, and the
  engine degrades that endpoint's *mode* instead of crashing — a tripped
  ``ring`` breaker drops the fetch ring to its depth-0 synchronous
  baseline (token-identical by the async pipeline's design), a tripped
  ``stage`` breaker disables speculative staging so thaws fall back to
  the sync upload path (``n_thaw_upload`` — also token-identical).
  ``must_succeed`` endpoints (``pull``/``push``/``ring``: the data MUST
  move or the engine has no state to continue from) never raise — an
  exhausted retry budget records the failure for the breaker and keeps
  retrying; best-effort endpoints (``stage``) give up and return
  ``Endpoint.FAILED`` so the caller can skip the optimization.

Nothing here imports jax: faults wrap host-side call sites, and the
device-visible effect of an injected failure is always "the bytes did
not move this attempt", never corrupted device state.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

# the injection-point catalogue (docs/robustness.md keeps the prose).
# The replica_* sites are consulted by the router's per-replica step
# driver, not by engine endpoints: ``replica_crash`` permanently fences
# the replica (kind "crash"), ``replica_hang`` makes it skip
# ``attempts`` consecutive steps without progress (kind "hang" — the
# router's heartbeat monitor declares it dead past its threshold),
# ``replica_slow`` sleeps ``delay_s`` before the step (kind "slow")
SITES = ("pull", "push", "ring", "stage", "stash", "nan",
         "replica_crash", "replica_hang", "replica_slow")


class InjectedFault(RuntimeError):
    """A scheduled fault, surfaced past an endpoint's retry budget."""

    def __init__(self, site: str, msg: str):
        super().__init__(f"[{site}] {msg}")
        self.site = site


class StashAllocError(InjectedFault):
    """Host-stash allocation failure (the ``stash`` site)."""


@dataclasses.dataclass
class FaultPlan:
    """What one scheduled fault does to its operation.

    ``kind``: ``fail`` (the attempt raises; retried), ``slow`` (the
    attempt is delayed by ``delay_s``, then succeeds), ``nan``
    (engine-level: poison one lane's logits), ``crash`` / ``hang``
    (replica-level, consumed by the router's step driver — see the
    ``replica_*`` sites).  ``attempts`` is how many
    consecutive attempts of the SAME operation fail before it succeeds —
    ``attempts > RetryPolicy.max_retries`` makes the operation fail
    permanently (breaker food).  ``lane`` targets a specific engine lane
    for ``nan`` plans (first active lane when None)."""
    kind: str = "fail"
    attempts: int = 1
    delay_s: float = 0.0
    lane: Optional[int] = None


class FaultSchedule:
    """Deterministic (site, op_index) -> FaultPlan mapping.

    ``rates``: {site: probability in [0, 1]} — the decision for op ``n``
    at site ``s`` is a pure hash of ``(seed, s, n)`` (crc32), so two runs
    with the same seed inject identically regardless of interleaving.
    ``attempts`` is the per-fault consecutive-failure count for
    rate-scheduled ``fail`` faults.  ``explicit`` entries override the
    rate draw at their exact (site, op_index)."""

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 attempts: int = 1,
                 explicit: Optional[Dict[Tuple[str, int], FaultPlan]] = None):
        self.seed = seed
        self.rates = dict(rates or {})
        self.attempts = attempts
        self.explicit = dict(explicit or {})

    def _draw(self, site: str, op_index: int) -> float:
        h = zlib.crc32(f"{self.seed}:{site}:{op_index}".encode())
        return (h & 0xFFFFFFFF) / 2**32

    def plan(self, site: str, op_index: int) -> Optional[FaultPlan]:
        p = self.explicit.get((site, op_index))
        if p is not None:
            return p
        rate = self.rates.get(site, 0.0)
        if rate and self._draw(site, op_index) < rate:
            # sites without a transfer to fail draw their own kind: nan
            # poisons the step's logits, replica_* act on the whole
            # replica (crash fences it, hang skips `attempts` steps,
            # slow sleeps)
            kind = "fail"
            if site == "nan":
                kind = "nan"
            elif site.startswith("replica_"):
                kind = site.split("_", 1)[1]
            return FaultPlan(kind=kind, attempts=self.attempts)
        return None


class FaultInjector:
    """Per-site op counters + injection stats over one ``FaultSchedule``.

    One injector is shared by every endpoint of an engine, so the op
    indices are a stable per-site clock of the run."""

    def __init__(self, schedule: Optional[FaultSchedule] = None):
        self.schedule = schedule
        self.op_counts: Dict[str, int] = {}
        self.injected: Dict[str, int] = {}

    def next_plan(self, site: str) -> Optional[FaultPlan]:
        n = self.op_counts.get(site, 0)
        self.op_counts[site] = n + 1
        if self.schedule is None:
            return None
        p = self.schedule.plan(site, n)
        if p is not None:
            self.injected[site] = self.injected.get(site, 0) + 1
        return p

    @property
    def n_injected(self) -> int:
        return sum(self.injected.values())


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry with exponential backoff.  ``backoff_s == 0`` (the
    default for benchmarks/tests) keeps the retry loop deterministic-fast;
    production would set a small base (the growth is ``base * 2**k``,
    capped at ``max_backoff_s``)."""
    max_retries: int = 3
    backoff_s: float = 0.0
    max_backoff_s: float = 0.1

    def backoff(self, attempt: int) -> None:
        if self.backoff_s:
            time.sleep(min(self.backoff_s * (2 ** (attempt - 1)),
                           self.max_backoff_s))


@dataclasses.dataclass
class CircuitBreaker:
    """Per-endpoint breaker: ``closed`` -> (``trip_after`` consecutive
    operation failures) -> ``open`` -> (``cooldown_ops`` denied calls)
    -> ``half_open`` (one probe) -> ``closed`` on success / ``open``
    again on failure.  "Operation failure" means the whole retry budget
    was exhausted, not a single retried attempt — transient blips never
    trip it.  Cooldown is measured in *calls*, not wall time, so chaos
    runs replay deterministically."""
    trip_after: int = 3
    cooldown_ops: int = 8
    state: str = "closed"
    n_trips: int = 0
    _consec_failures: int = 0
    _cooldown_left: int = 0

    def allow(self) -> bool:
        """Gate a call: False while open (and burns one cooldown op)."""
        if self.state == "open":
            self._cooldown_left -= 1
            if self._cooldown_left <= 0:
                self.state = "half_open"
                return True
            return False
        return True

    def record(self, ok: bool) -> None:
        if ok:
            self._consec_failures = 0
            if self.state == "half_open":
                self.state = "closed"
            return
        self._consec_failures += 1
        if self.state == "half_open" or \
                self._consec_failures >= self.trip_after:
            self.state = "open"
            self._cooldown_left = self.cooldown_ops
            self.n_trips += 1
            self._consec_failures = 0

    @property
    def tripped(self) -> bool:
        return self.state != "closed"


class Endpoint:
    """One guarded operation class (a named injection point + its retry
    policy + breaker).  ``call(fn, ...)`` consults the injector for this
    operation's fault plan, fails/delays the scheduled attempts, retries
    with backoff, and records the operation's outcome with the breaker.

    ``must_succeed`` endpoints never raise: past the retry budget the
    failure is recorded (``n_exhausted``; the breaker sees it) and the
    loop keeps going until the remaining injected attempts drain and the
    real call runs — modelling "re-issue the DMA until it lands", which
    is the only sound option when the data must move.  Best-effort
    endpoints return ``Endpoint.FAILED`` instead, and the caller skips
    the optimization the transfer was for."""

    FAILED = object()

    def __init__(self, name: str, injector: Optional[FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 must_succeed: bool = True):
        self.name = name
        self.injector = injector
        self.retry = retry or RetryPolicy()
        self.breaker = breaker
        self.must_succeed = must_succeed
        self.n_calls = 0
        self.n_retries = 0
        self.n_slow = 0
        self.n_exhausted = 0     # operations that blew the retry budget

    def allow(self) -> bool:
        """Whether the engine should even attempt this endpoint's mode
        (False while the breaker is open — callers fall back)."""
        return self.breaker.allow() if self.breaker is not None else True

    def call(self, fn: Callable[..., Any], *args, **kw) -> Any:
        self.n_calls += 1
        plan = self.injector.next_plan(self.name) \
            if self.injector is not None else None
        if plan is not None and plan.kind == "slow":
            self.n_slow += 1
            if plan.delay_s:
                time.sleep(plan.delay_s)
            plan = None
        fails = plan.attempts if plan is not None else 0
        attempt = 0
        exhausted = False
        while fails > 0:
            fails -= 1
            attempt += 1
            if attempt > self.retry.max_retries:
                exhausted = True
                self.n_exhausted += 1
                if self.breaker is not None:
                    self.breaker.record(False)
                if not self.must_succeed:
                    return Endpoint.FAILED
                # must-succeed: keep re-issuing (fresh retry budget)
                attempt = 0
                continue
            self.n_retries += 1
            self.retry.backoff(attempt)
        out = fn(*args, **kw)
        # a success after an exhausted budget already fed the breaker its
        # failure; don't also reward it (the op was degraded, not clean)
        if self.breaker is not None and not exhausted:
            self.breaker.record(True)
        return out

    def stats(self) -> Dict[str, int]:
        return {"calls": self.n_calls, "retries": self.n_retries,
                "slow": self.n_slow, "exhausted": self.n_exhausted,
                "breaker_trips":
                    self.breaker.n_trips if self.breaker else 0}


@dataclasses.dataclass
class ChaosConfig:
    """Engine-facing bundle: the fault schedule plus retry/breaker knobs.

    Built by tests / ``benchmarks/chaos.py`` / ``--chaos-seed``; a None
    chaos config costs the hot path one attribute check per guarded op."""
    seed: int = 0
    rates: Dict[str, float] = dataclasses.field(default_factory=dict)
    attempts: int = 1
    explicit: Dict[Tuple[str, int], FaultPlan] = \
        dataclasses.field(default_factory=dict)
    max_retries: int = 3
    backoff_s: float = 0.0
    trip_after: int = 3
    cooldown_ops: int = 8

    def build_injector(self) -> FaultInjector:
        return FaultInjector(FaultSchedule(
            seed=self.seed, rates=self.rates, attempts=self.attempts,
            explicit=self.explicit))

    def build_endpoint(self, name: str, injector: FaultInjector,
                       must_succeed: bool = True) -> Endpoint:
        return Endpoint(
            name, injector,
            retry=RetryPolicy(max_retries=self.max_retries,
                              backoff_s=self.backoff_s),
            breaker=CircuitBreaker(trip_after=self.trip_after,
                                   cooldown_ops=self.cooldown_ops),
            must_succeed=must_succeed)

"""Platform-dispatching jit'd wrappers around the Pallas kernels.

TPU -> compiled pl.pallas_call; CPU/GPU -> the pure-jnp reference path
(identical semantics; the dry-run lowers the reference path).  Tests force
the kernel body on CPU with interpret=True.
"""
from __future__ import annotations

import functools

import jax

from repro.configs.base import FreezeConfig
from repro.core.freeze import FreezeState
from repro.kernels import ref
from repro.kernels.freeze_decode_attn import freeze_decode_attention
from repro.kernels.paged_decode_attn import paged_decode_attention_kernel
from repro.kernels.relevance_freeze import relevance_freeze_update


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("force_kernel",))
def masked_decode_attention(q, k, v, active_mask, force_kernel: bool = False):
    """(out (B,H,hd), relevance (B,S)) — freeze-masked decode attention."""
    if _on_tpu():
        return freeze_decode_attention(q, k, v, active_mask)
    if force_kernel:
        return freeze_decode_attention(q, k, v, active_mask, interpret=True)
    return ref.freeze_decode_attention_ref(q, k, v, active_mask)


@functools.partial(jax.jit, static_argnames=("force_kernel",))
def paged_decode_attention(q, k_pages, v_pages, slot_mask, page_table=None,
                           page_visible=None, page_quant=None, kv_scales=None,
                           force_kernel: bool = False):
    """(out (B,H,hd), page_relevance (B,P)) — the PagedContinuousEngine
    decode hot path.  `page_table` (B,P) lets the kernel skip unmapped
    slots before reading their mask; None derives it from slot_mask.
    `page_visible` (B,P) is the recovery ladder's thaw-aware visibility
    mask (``~frozen``): False pages are skipped like unmapped slots, and a
    just-thawed page re-enters attention + relevance accounting through
    it; None means every mapped page is visible.

    Staging-slot contract (async DMA pipeline): the engine appends
    ``speculative_slots`` extra physical slots per lane and uploads
    likely-thaw pages into them *before* their page-table entries exist —
    the K/V pool may therefore contain live data in slots whose
    `page_table` entry is -1.  Unmapped slots MUST be excluded from the
    softmax and report relevance 0 regardless of their K/V contents or
    stale `slot_mask` bits (tests/test_async_pipeline.py::
    TestStagingSlotVisibility pins this for both the reference and the
    Pallas kernel).

    `page_quant` (B,P) i32 / `kv_scales` (B,P,2,KVH) f32 are the per-page
    quantization slots (core/quant.py): pages whose flag is non-zero hold
    an integer-valued payload in the pool dtype and are dequantized in
    the kernel (K by scales[...,0,:], V by scales[...,1,:]).  None (the
    default) is bit-identical to the unquantized path."""
    if _on_tpu():
        return paged_decode_attention_kernel(q, k_pages, v_pages, slot_mask,
                                             page_table, page_visible,
                                             page_quant, kv_scales)
    if force_kernel:
        return paged_decode_attention_kernel(q, k_pages, v_pages, slot_mask,
                                             page_table, page_visible,
                                             page_quant, kv_scales,
                                             interpret=True)
    return ref.paged_decode_attention_ref(q, k_pages, v_pages, slot_mask,
                                          page_table, page_visible,
                                          page_quant, kv_scales)


def freeze_state_update(state: FreezeState, relevance, pos, step,
                        cfg: FreezeConfig, force_kernel: bool = False):
    """(new FreezeState, active mask) — fused Algorithm 1 pass."""
    if _on_tpu():
        return relevance_freeze_update(state, relevance, pos, step, cfg)
    if force_kernel:
        return relevance_freeze_update(state, relevance, pos, step, cfg,
                                       interpret=True)
    new, info = ref.relevance_freeze_ref(state, relevance, pos, step, cfg)
    return new, info["active"]

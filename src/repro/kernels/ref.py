"""Pure-jnp oracles for every Pallas kernel (the ground truth used by the
shape/dtype sweep tests and by the CPU execution path)."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FreezeConfig
from repro.core.freeze import FreezeState, freeze_update
from repro.core.paging import paged_decode_attention as _paged_ref
from repro.models.layers import decode_attention as _masked_ref


def freeze_decode_attention_ref(q, k, v, active_mask):
    """Oracle for kernels.freeze_decode_attn — (out, relevance (B,S) f32).
    Matches the kernel's convention that masked slots report relevance 0
    only when their whole block is inactive; the reference computes exact
    per-slot |Q.K| means (the kernel sweep compares only active blocks'
    scores — see tests)."""
    out, rel = _masked_ref(q, k, v, active_mask)
    return out, rel.astype(jnp.float32)


def paged_decode_attention_ref(q, k_pages, v_pages, slot_mask):
    """Oracle for kernels.paged_decode_attn — (out, page_relevance)."""
    return _paged_ref(q, k_pages, v_pages, slot_mask)


def relevance_freeze_ref(state: FreezeState, relevance, pos, step,
                         cfg: FreezeConfig):
    """Oracle for kernels.relevance_freeze — vectorized Algorithm 1."""
    return freeze_update(state, relevance, pos, step, cfg)

"""Pure-jnp oracles for every Pallas kernel (the ground truth used by the
shape/dtype sweep tests and by the CPU execution path)."""
from __future__ import annotations


import jax.numpy as jnp

from repro.configs.base import FreezeConfig
from repro.core.freeze import FreezeState, freeze_update
from repro.core.paging import paged_decode_attention as _paged_ref
from repro.models.layers import decode_attention as _masked_ref


def freeze_decode_attention_ref(q, k, v, active_mask):
    """Oracle for kernels.freeze_decode_attn — (out, relevance (B,S) f32).
    Inactive slots report relevance 0 (their KV is frozen or unwritten
    garbage, so their |Q.K| head-mean must never reach the freeze
    schedule) — slot-exact parity with the kernel, including inactive
    slots inside partially-active blocks."""
    out, rel = _masked_ref(q, k, v, active_mask)
    return out, jnp.where(active_mask, rel, 0.0).astype(jnp.float32)


def paged_decode_attention_ref(q, k_pages, v_pages, slot_mask,
                               page_table=None, page_visible=None,
                               page_quant=None, kv_scales=None):
    """Oracle for kernels.paged_decode_attn — (out, page_relevance).
    Unmapped page-table slots (< 0) and invisible pages (page_visible
    False — frozen and not thawed by the recovery ladder) are excluded
    like empty pages.  Exclusion must hold regardless of the slots' K/V
    payload: the async pipeline's staging slots carry speculatively
    uploaded pages while still unmapped (see kernels/ops.py).
    ``page_quant`` / ``kv_scales`` dequantize flagged pages exactly like
    the kernel (see core/quant.py); None is the unquantized path."""
    return _paged_ref(q, k_pages, v_pages, slot_mask, page_table,
                      page_visible, page_quant, kv_scales)


def relevance_freeze_ref(state: FreezeState, relevance, pos, step,
                         cfg: FreezeConfig):
    """Oracle for kernels.relevance_freeze — vectorized Algorithm 1."""
    return freeze_update(state, relevance, pos, step, cfg)

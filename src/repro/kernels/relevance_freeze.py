"""Pallas TPU kernel: fused ASR-KF-EGR state update (Algorithm 1 lines
3–15) — one elementwise VPU pass over the freeze-state arrays.

Used by the non-fused attention path (when relevance comes from a separate
scoring pass): reads (c, d, frozen, frozen_at, relevance) tiles and writes
the updated state in place, including the sublinear schedule
d = floor(sqrt(c)/k), the rolling timer decrement, restoration, and the
history-window counter decay.  pos/step arrive via scalar prefetch (SMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import FreezeConfig
from repro.core.freeze import FreezeState


def _kernel(scalars_ref,                                 # SMEM: [pos, step]
            c_ref, d_ref, fro_ref, fat_ref, rel_ref,     # inputs
            c_o, d_o, fro_o, fat_o, act_o,               # outputs
            *, window: int, tau: float, k_soft: float, history: int,
            block_s: int):
    pos = scalars_ref[0]
    step = scalars_ref[1]
    sblk = pl.program_id(1)
    base = sblk * block_s
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)

    c = c_ref[...]
    d = d_ref[...]
    was_frozen = fro_ref[...] != 0
    fat = fat_ref[...]
    rel = rel_ref[...]

    exists = idx <= pos
    in_window = idx > (pos - window)
    eligible = exists & ~in_window & ~was_frozen
    flagged = eligible & (rel < tau)
    c_new = c + flagged.astype(jnp.int32)
    d_sched = jnp.floor(jnp.sqrt(c_new.astype(jnp.float32)) / k_soft
                        ).astype(jnp.int32)
    just_frozen = flagged & (d_sched > 0)
    frozen_mid = was_frozen | just_frozen
    d_mid = jnp.where(just_frozen, d_sched, d)
    fat_new = jnp.where(just_frozen, step, fat)

    d_dec = jnp.where(was_frozen, d_mid - 1, d_mid)
    restored = was_frozen & (d_dec <= 0)
    frozen_new = frozen_mid & ~restored
    d_new = jnp.where(restored, 0, d_dec)
    decay = (step % history) == (history - 1)
    c_new = jnp.where(decay, jnp.maximum(c_new - 1, 0), c_new)

    c_o[...] = c_new
    d_o[...] = d_new
    fro_o[...] = frozen_new.astype(jnp.int8)
    fat_o[...] = fat_new
    act_o[...] = (exists & ~frozen_new).astype(jnp.int8)


def relevance_freeze_update(
    state: FreezeState,          # arrays (B, S)
    relevance: jnp.ndarray,      # (B, S)
    pos: jnp.ndarray,            # () int32
    step: jnp.ndarray,           # () int32
    cfg: FreezeConfig,
    *,
    block_s: int = 1024,
    interpret: bool = False,
):
    """Returns (new FreezeState, active mask (B,S) bool)."""
    B, S = relevance.shape
    block_s = min(block_s, S)
    assert S % block_s == 0
    grid = (B, S // block_s)
    # index maps receive the scalar-prefetch ref as a trailing argument
    blk = lambda b, s, *_refs: (b, s)
    spec_i32 = pl.BlockSpec((1, block_s), blk)

    kernel = functools.partial(
        _kernel, window=cfg.window, tau=cfg.tau, k_soft=cfg.k_soft,
        history=cfg.history, block_s=block_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[spec_i32] * 5,
        out_specs=[spec_i32] * 5,
    )
    c, d, fro, fat, act = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, S), jnp.int32),
            jax.ShapeDtypeStruct((B, S), jnp.int32),
            jax.ShapeDtypeStruct((B, S), jnp.int8),
            jax.ShapeDtypeStruct((B, S), jnp.int32),
            jax.ShapeDtypeStruct((B, S), jnp.int8),
        ],
        interpret=interpret,
    )(jnp.stack([jnp.asarray(pos, jnp.int32), jnp.asarray(step, jnp.int32)]),
      state.c, state.d, state.frozen.astype(jnp.int8), state.frozen_at,
      relevance.astype(jnp.float32))
    new = FreezeState(c=c, d=d, frozen=fro != 0, frozen_at=fat)
    return new, act != 0

"""Pallas TPU kernel: freeze-masked flash-decode attention with fused
Eq. 2 relevance extraction.

One decode step: q (B, H, hd) attends a contiguous KV cache (B, S, KVH, hd)
under an active mask (B, S) — frozen / unwritten slots excluded.  The kernel
is the TPU-native realization of ASR-KF-EGR's "excluded from active
attention" (paper §3.3 step 2): the grid walks KV blocks; a block with no
active slot skips all its MXU work (`pl.when`), and the |Q.K| head-mean is
emitted per slot as the relevance output — the attention pass *is* the
relevance pass (zero extra HBM traffic vs. the paper's separate scoring).

Block sizes: KV is tiled (block_s, KVH*hd) with block_s a multiple of 128 to
keep the MXU matmul dims hardware-aligned; q (H, hd) stays VMEM-resident
across the whole row of KV blocks.  VMEM footprint per step ~=
block_s*KVH*hd*2*2 (K+V) + H*hd*4*2 (acc) + block_s*4 bytes.

Validated on CPU with interpret=True against repro.kernels.ref (pure jnp);
compiled path is TPU-only (ops.py dispatches).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref,        # inputs
            o_ref, rel_ref,                        # outputs
            m_ref, l_ref, acc_ref,                 # scratch
            *, kv_heads: int, scale: float):
    """Grid: (B, S // block_s)."""
    blk = pl.program_id(1)
    nblk = pl.num_programs(1)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (H, hd)
    mask = mask_ref[0] != 0                        # (block_s,)
    H, hd = q.shape
    G = H // kv_heads

    any_active = jnp.any(mask)

    @pl.when(any_active)
    def _block():
        k = k_ref[0].astype(jnp.float32)           # (block_s, KVH, hd)
        v = v_ref[0].astype(jnp.float32)
        qg = q.reshape(kv_heads, G, hd)
        raw = jnp.einsum("kgh,skh->kgs", qg, k)    # (KVH, G, block_s)
        # fused Eq.2 relevance: mean over all H query heads of |q.k|;
        # inactive slots report 0 even inside an active block (frozen /
        # unwritten KV is garbage — its |Q.K| must not reach the freeze
        # schedule), matching kernels.ref exactly
        tok_rel = jnp.mean(jnp.abs(raw), axis=(0, 1))
        rel_ref[0, :] = jnp.where(mask, tok_rel, 0.0).astype(rel_ref.dtype)
        s = raw * scale
        s = jnp.where(mask[None, None, :], s, NEG_INF)
        m_prev = m_ref[...].reshape(kv_heads, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, :], p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_ref[...].reshape(kv_heads, G) * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("kgs,skh->kgh", p, v)
        acc_prev = acc_ref[...].reshape(kv_heads, G, hd)
        acc_ref[...] = (acc_prev * corr[..., None] + pv).reshape(H, hd)
        m_ref[...] = m_new.reshape(H)
        l_ref[...] = l_new.reshape(H)

    @pl.when(~any_active)
    def _skipped():
        # frozen/empty block: no MXU work; relevance of masked slots is 0
        rel_ref[0, :] = jnp.zeros_like(rel_ref[0, :])

    @pl.when(blk == nblk - 1)
    def _finalize():
        l = l_ref[...]
        o = acc_ref[...] / jnp.maximum(l[:, None], 1e-30)
        o = jnp.where(l[:, None] > 0, o, 0.0)
        o_ref[0] = o.astype(o_ref.dtype)


def freeze_decode_attention(
    q: jnp.ndarray,           # (B, H, hd)
    k: jnp.ndarray,           # (B, S, KVH, hd)
    v: jnp.ndarray,
    active_mask: jnp.ndarray, # (B, S) bool
    *,
    block_s: int = 512,
    interpret: bool = False,
):
    """Returns (out (B, H, hd), relevance (B, S) f32)."""
    B, H, hd = q.shape
    _, S, KVH, _ = k.shape
    assert S % block_s == 0, (S, block_s)
    scale = 1.0 / math.sqrt(hd)
    grid = (B, S // block_s)

    out, rel = pl.pallas_call(
        functools.partial(_kernel, kv_heads=KVH, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, block_s, KVH, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, block_s, KVH, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, block_s), lambda b, s: (b, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, block_s), lambda b, s: (b, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, hd), q.dtype),
            jax.ShapeDtypeStruct((B, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, active_mask.astype(jnp.int8))
    return out, rel

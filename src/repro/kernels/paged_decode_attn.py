"""Pallas TPU kernel: paged flash-decode attention over the bounded active
page pool — the serving hot path of the PagedContinuousEngine.

Grid walks (batch, physical page); each lane's page table AND per-page
visibility mask arrive via scalar prefetch (SMEM), so the kernel knows
*before* touching VMEM whether the (lane, slot) it was scheduled on is
mapped and attendable.  Unmapped slots (page_table < 0), invisible pages
(frozen and not thawed by the recovery ladder — page_visible == 0) and
pages whose slot mask is empty skip their MXU work entirely under
`pl.when` — mirroring `freeze_decode_attn`'s block skip, but page-granular
and per lane.  The page-mean |Q.K| relevance is emitted fused, feeding the
page-granular freeze schedule (core.paging.page_freeze_update); a page the
entropy ladder just thawed re-enters both the softmax and the relevance
accounting through the same mask, so the freeze schedule immediately sees
fresh scores for it.

On real TPU the page pool lives in HBM while the frozen store is in host
memory; the kernel only ever touches the device pool — the bounded-memory
guarantee of DESIGN.md §2.  Validated on CPU with interpret=True against
kernels.ref.paged_decode_attention_ref (tests/test_kernels.py sweep).

The scalar-prefetched page-table skip doubles as the async DMA pipeline's
**staging-slot visibility** guarantee: the serving engine reserves extra
physical slots per lane and speculatively uploads likely-thaw pages into
them while their page-table entries are still -1, so the pool carries
live K/V the sequence must not yet attend.  Because `mapped` is read from
SMEM before any VMEM access, a staged slot costs zero MXU work and zero
relevance until the host remaps it — at which point the same prefetch
path makes it attendable with no kernel change
(tests/test_async_pipeline.py::TestStagingSlotVisibility).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref,                       # SMEM scalar prefetch: (B, P) i32
            vis_ref,                      # SMEM scalar prefetch: (B, P) i32
            qt_ref,                       # SMEM scalar prefetch: (B, P) i32
            q_ref, k_ref, v_ref, sc_ref, mask_ref,
            o_ref, rel_ref,
            m_ref, l_ref, acc_ref,
            *, kv_heads: int, scale: float):
    b = pl.program_id(0)
    blk = pl.program_id(1)
    nblk = pl.num_programs(1)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (H, hd)
    mapped = pt_ref[b, blk] >= 0                   # per-lane page table
    visible = vis_ref[b, blk] != 0                 # thaw-aware page mask
    mask = (mask_ref[0, 0] != 0) & mapped & visible    # (page,)
    H, hd = q.shape
    G = H // kv_heads
    n_act = jnp.sum(mask.astype(jnp.float32))
    live = mapped & visible & (n_act > 0)

    @pl.when(live)
    def _page():
        k = k_ref[0, 0].astype(jnp.float32)        # (page, KVH, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        # in-kernel dequant of quantized (frozen/thawed) pages: the pool
        # holds the integer-valued payload in the pool dtype, the per-page
        # per-kv-head scales ride next to the page table.  Hot pages carry
        # quant flag 0 and multiply by exactly 1.0 — bitwise identity, so
        # kv_quant="none" stays bit-identical to the unquantized kernel.
        quant = qt_ref[b, blk] != 0
        sk = jnp.where(quant, sc_ref[0, 0, 0], 1.0)            # (KVH,)
        sv = jnp.where(quant, sc_ref[0, 0, 1], 1.0)
        k = k * sk[None, :, None]
        v = v * sv[None, :, None]
        qg = q.reshape(kv_heads, G, hd)
        raw = jnp.einsum("kgh,skh->kgs", qg, k)
        tok_rel = jnp.mean(jnp.abs(raw), axis=(0, 1))          # (page,)
        rel_ref[0, 0] = (jnp.sum(tok_rel * mask) / n_act).astype(rel_ref.dtype)
        s = jnp.where(mask[None, None, :], raw * scale, NEG_INF)
        m_prev = m_ref[...].reshape(kv_heads, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, :], p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_ref[...].reshape(kv_heads, G) * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("kgs,skh->kgh", p, v)
        acc_prev = acc_ref[...].reshape(kv_heads, G, hd)
        acc_ref[...] = (acc_prev * corr[..., None] + pv).reshape(H, hd)
        m_ref[...] = m_new.reshape(H)
        l_ref[...] = l_new.reshape(H)

    @pl.when(~live)
    def _skip():
        # unmapped slot, invisible (frozen, un-thawed) page, or empty slot
        # mask: no MXU work, relevance 0
        rel_ref[0, 0] = jnp.zeros((), rel_ref.dtype)

    @pl.when(blk == nblk - 1)
    def _finalize():
        l = l_ref[...]
        o = acc_ref[...] / jnp.maximum(l[:, None], 1e-30)
        o = jnp.where(l[:, None] > 0, o, 0.0)
        o_ref[0] = o.astype(o_ref.dtype)


def paged_decode_attention_kernel(
    q: jnp.ndarray,           # (B, H, hd)
    k_pages: jnp.ndarray,     # (B, P, page, KVH, hd)
    v_pages: jnp.ndarray,
    slot_mask: jnp.ndarray,   # (B, P, page) bool
    page_table: Optional[jnp.ndarray] = None,   # (B, P) i32; < 0 = unmapped
    page_visible: Optional[jnp.ndarray] = None, # (B, P) bool; False = frozen
    page_quant: Optional[jnp.ndarray] = None,   # (B, P) i32; != 0 = quantized
    kv_scales: Optional[jnp.ndarray] = None,    # (B, P, 2, KVH) f32
    *,
    interpret: bool = False,
):
    """Returns (out (B, H, hd), page_relevance (B, P) f32).

    ``page_visible`` is the recovery ladder's thaw-aware mask (``~frozen``
    after in-step un-freezing): False pages skip their MXU work exactly
    like unmapped slots.  None means all mapped pages are visible.

    ``page_quant`` / ``kv_scales`` are the per-page quantization slots
    (core/quant.py): where the flag is non-zero the pool holds an
    integer-valued payload and the kernel multiplies K by
    ``kv_scales[b, p, 0]`` and V by ``kv_scales[b, p, 1]`` (per kv-head)
    after the load.  None (or an all-zero flag array) multiplies by 1.0
    exactly — bit-identical to the unquantized kernel.
    """
    B, H, hd = q.shape
    _, P, page, KVH, _ = k_pages.shape
    scale = 1.0 / math.sqrt(hd)
    grid = (B, P)
    if page_table is None:   # derive: a slot with any valid token is mapped
        page_table = jnp.where(jnp.any(slot_mask, -1), 0, -1).astype(jnp.int32)
    if page_visible is None:
        page_visible = jnp.ones((B, P), jnp.int32)
    if page_quant is None:
        page_quant = jnp.zeros((B, P), jnp.int32)
    if kv_scales is None:
        kv_scales = jnp.ones((B, P, 2, KVH), jnp.float32)

    # index maps receive the scalar-prefetch refs as trailing arguments
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, p, *_: (b, 0, 0)),
            pl.BlockSpec((1, 1, page, KVH, hd), lambda b, p, *_: (b, p, 0, 0, 0)),
            pl.BlockSpec((1, 1, page, KVH, hd), lambda b, p, *_: (b, p, 0, 0, 0)),
            pl.BlockSpec((1, 1, 2, KVH), lambda b, p, *_: (b, p, 0, 0)),
            pl.BlockSpec((1, 1, page), lambda b, p, *_: (b, p, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, hd), lambda b, p, *_: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, p, *_: (b, p)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    out, rel = pl.pallas_call(
        functools.partial(_kernel, kv_heads=KVH, scale=scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, hd), q.dtype),
            jax.ShapeDtypeStruct((B, P), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(page_table, jnp.int32),
      jnp.asarray(page_visible, jnp.int32),
      jnp.asarray(page_quant, jnp.int32),
      q, k_pages, v_pages, jnp.asarray(kv_scales, jnp.float32),
      slot_mask.astype(jnp.int8))
    return out, rel

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) combination
on the production mesh with 512 placeholder host devices, then extract the
roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per combo a JSON record lands in experiments/dryrun/, consumed by
benchmarks/roofline.py and EXPERIMENTS.md §Dry-run/§Roofline.

NOTE the XLA_FLAGS assignment above MUST precede any jax import (jax locks
the device count at first init) — do not move it.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch import mesh as MESH
from repro.launch import specs as SP

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims, in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo: str) -> dict:
    """Sum per-device bytes of collective ops from post-SPMD HLO text.

    Methodology: for each collective we count the RESULT shape bytes (the
    per-device tensor produced); for reduce-scatter we scale by the group
    size to approximate the pre-scatter operand (result is 1/group of the
    input).  '-start' async forms are counted, '-done' skipped (same op).
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue
        shape_txt, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        if op == "reduce-scatter":
            g = _GROUPS_RE.search(line)
            if g:
                b *= int(g.group(2))
            else:
                gb = _GROUPS_BRACE_RE.search(line)
                if gb:
                    b *= len(gb.group(1).split(","))
        out[op] += b
        counts[op] += 1
    out_total = sum(out.values())
    return {"per_op": out, "counts": counts, "total": out_total}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for training;
    2*N*D for a forward-only step (prefill) and 2*N_active per decoded
    token for decode."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
            optimized: bool = False) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = MESH.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(f"{k}={v}" for k, v in mesh.shape.items()),
        "chips": n_chips, "multi_pod": multi_pod, "ok": False,
        "optimized": optimized,
    }
    t0 = time.time()
    try:
        reason = SP.skip_reason(cfg, shape)
        if reason:
            rec["skipped"] = reason
            rec["ok"] = True
            return rec
        bundle = SP.build_step(cfg, shape, mesh, optimized=optimized)
        rec.update(bundle.static)
        with mesh:
            jitted = jax.jit(
                bundle.fn,
                in_shardings=bundle.in_shardings,
                out_shardings=bundle.out_shardings,
                donate_argnums=bundle.donate_argnums,
            )
            lowered = jitted.lower(*bundle.args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["hlo_flops"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)

        # ---- roofline terms (per-chip program vs per-chip peaks) ---- #
        coll = rec["collectives"]["total"]
        rec["roofline"] = {
            "compute_s": rec["hlo_flops"] / MESH.PEAK_FLOPS_BF16,
            "memory_s": rec["hlo_bytes"] / MESH.HBM_BW,
            "collective_s": coll / MESH.ICI_BW,
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["bottleneck"] = dom.replace("_s", "")
        mf = model_flops(cfg, shape)
        rec["model_flops_total"] = mf
        rec["model_flops_per_chip"] = mf / n_chips
        rec["useful_flops_ratio"] = (
            mf / n_chips / rec["hlo_flops"] if rec["hlo_flops"] else 0.0)
        rec["ok"] = True
    except ValueError as e:
        if str(e).startswith("SKIP:"):
            rec["skipped"] = str(e)[5:].strip()
            rec["ok"] = True
        else:
            rec["error"] = traceback.format_exc(limit=25)
    except Exception:
        rec["error"] = traceback.format_exc(limit=25)
    finally:
        rec["total_s"] = round(time.time() - t0, 1)
        out_dir.mkdir(parents=True, exist_ok=True)
        tag = ("mp" if multi_pod else "sp") + ("_opt" if optimized else "")
        (out_dir / f"{arch}__{shape_name}__{tag}.json").write_text(
            json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs() + ["all"], default="all")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"],
                    default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf variants (EXPERIMENTS.md)")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    out_dir = Path(args.out)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = ("mp" if mp else "sp") + ("_opt" if args.optimized else "")
                f = out_dir / f"{arch}__{shape}__{tag}.json"
                if args.skip_existing and f.exists():
                    prev = json.loads(f.read_text())
                    if prev.get("ok"):
                        print(f"[skip] {arch} {shape} {tag}", flush=True)
                        continue
                rec = run_one(arch, shape, mp, out_dir, optimized=args.optimized)
                status = ("SKIPPED " + rec["skipped"]) if "skipped" in rec \
                    else ("OK" if rec["ok"] else "FAIL")
                print(f"[{status:>4}] {arch:24s} {shape:12s} {tag} "
                      f"{rec.get('total_s', 0):7.1f}s", flush=True)
                if not rec["ok"]:
                    n_fail += 1
                    err = rec.get("error", "")
                    print("        " + err.strip().splitlines()[-1][:160],
                          flush=True)
    print(f"done; failures={n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

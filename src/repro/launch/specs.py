"""ShapeDtypeStruct input specs + jit-able step builders for every
(architecture x input-shape) combination — the dry-run's raw material.

Nothing here allocates device memory: states come from jax.eval_shape over
the real init functions, inputs are ShapeDtypeStructs.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as MD
from repro.models import transformer as T
from repro.sharding import rules as RU
from repro.training import optimizer as OPT
from repro.training import train_step as TS

SDS = jax.ShapeDtypeStruct

# device-resident active-pool budget for the bounded long-context mode
LONG_CONTEXT_ACTIVE_TOKENS = 65536


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """DESIGN.md §5 skip policy."""
    if cfg.name.startswith("whisper") and shape.name == "long_500k":
        return ("enc-dec ASR: no 500k-token decode use-case "
                "(DESIGN.md §5 skip note)")
    return None


def batch_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": SDS((b, s), jnp.int32)}
    if cfg.is_encoder_decoder:
        out["frames"] = SDS((b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.multimodal:
        out["patch_embeds"] = SDS((b, cfg.num_patches, T.PATCH_STUB_DIM),
                                  jnp.bfloat16)
    return out


def _sds_tree(f, *args, **kw):
    return jax.eval_shape(f, *args, **kw)


class StepBundle(NamedTuple):
    """Everything needed to lower one (arch x shape) step."""
    fn: Callable                 # jit-able step function
    args: Tuple[Any, ...]        # ShapeDtypeStruct args
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    static: Dict[str, Any]       # metadata for reporting


def _named(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def params_sds(cfg: ModelConfig):
    return _sds_tree(lambda: MD.init_params(jax.random.PRNGKey(0), cfg))


# HBM budget for keeping inference weights fully resident (tensor-parallel
# only, no per-step FSDP all-gather); v5e has 16 GB — leave room for cache.
INFER_RESIDENT_PARAM_BYTES = 10 * 2**30


def param_mode(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> str:
    if shape.kind == "train":
        return "train"   # FSDP over data (+pod): required for optimizer state
    sch = MD.schema(cfg)
    if RU.param_bytes_per_chip(mesh, sch, "infer") <= INFER_RESIDENT_PARAM_BYTES:
        return "infer"
    return "train"       # too big: keep FSDP, pay the per-step all-gather


# paper reports 55-67% compression -> a 50% bounded-active pool for 32k decode
OPT_DECODE32K_ACTIVE_TOKENS = 16384


def apply_optimizations(cfg: ModelConfig, shape: InputShape,
                        mesh: Mesh) -> ModelConfig:
    """§Perf beyond-baseline variants (EXPERIMENTS.md hillclimb log):
    H1 chunked-remat mamba scan (train), H2 decode activation-gather for
    models too big for resident tensor-only weights, H4 bounded-active paged
    pool for decode_32k (the paper's compression applied to resident KV)."""
    import dataclasses
    if shape.kind == "train" and cfg.arch_type == "hybrid":
        cfg = dataclasses.replace(cfg, mamba_scan_chunk=256)
    if shape.kind == "decode" and param_mode(cfg, shape, mesh) == "train":
        cfg = dataclasses.replace(cfg, decode_act_gather=True,
                                  act_model_parts=int(mesh.shape["model"]))
    if shape.kind in ("train", "prefill"):
        # H5: pin activation shardings so SPMD never falls back to
        # "involuntary full rematerialization" (batch replication) inside
        # scanned mamba/attention bodies
        cfg = dataclasses.replace(
            cfg, act_batch_axes=tuple(RU.batch_axes(mesh)),
            act_model_parts=int(mesh.shape["model"]))
    return cfg


def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               optimized: bool = False) -> StepBundle:
    reason = skip_reason(cfg, shape)
    if reason is not None:
        raise ValueError(f"SKIP: {reason}")
    if optimized:
        cfg = apply_optimizations(cfg, shape, mesh)
    schema = MD.schema(cfg)
    mode = param_mode(cfg, shape, mesh)
    p_specs = RU.param_pspecs(mesh, schema, mode)
    p_sh = _named(mesh, p_specs)
    params = params_sds(cfg)
    bdim = RU.batch_dim(mesh, shape.global_batch)
    vdim = RU.model_dim(mesh, cfg.padded_vocab)

    if shape.kind == "train":
        return _build_train(cfg, shape, mesh, params, p_sh, p_specs, bdim, vdim)
    if shape.kind == "prefill":
        return _build_prefill(cfg, shape, mesh, params, p_sh, bdim, vdim)
    pageable = not cfg.is_encoder_decoder and T.attn_layer_count(cfg) > 0
    if shape.name == "long_500k" and pageable:
        return _build_decode_paged(cfg, shape, mesh, params, p_sh, bdim, vdim,
                                   LONG_CONTEXT_ACTIVE_TOKENS)
    if optimized and shape.name == "decode_32k" and pageable:
        # H4: freeze-bounded active pool — resident KV (and its per-step
        # traffic) scales with the paper's reported active fraction
        return _build_decode_paged(cfg, shape, mesh, params, p_sh, bdim, vdim,
                                   OPT_DECODE32K_ACTIVE_TOKENS)
    return _build_decode(cfg, shape, mesh, params, p_sh, bdim, vdim)


def _batch_shardings(cfg, shape, mesh, bdim):
    sh = {"tokens": NamedSharding(mesh, P(bdim, None))}
    if cfg.is_encoder_decoder:
        sh["frames"] = NamedSharding(mesh, P(bdim, None, None))
    if cfg.multimodal:
        sh["patch_embeds"] = NamedSharding(mesh, P(bdim, None, None))
    return sh


def _build_train(cfg, shape, mesh, params, p_sh, p_specs, bdim, vdim):
    batch = batch_inputs(cfg, shape)
    logits_pspec = P(bdim, None, vdim)

    def step(state, batch):
        return TS.train_step(state, batch, cfg, logits_pspec=logits_pspec)

    opt_sds = _sds_tree(lambda: OPT.init(params))
    state = TS.TrainState(params=params, opt=opt_sds)
    opt_sh = OPT.AdamWState(
        step=NamedSharding(mesh, P()),
        m=p_sh, v=p_sh)
    state_sh = TS.TrainState(params=p_sh, opt=opt_sh)
    metrics_sh = None
    return StepBundle(
        fn=step,
        args=(state, batch),
        in_shardings=(state_sh, _batch_shardings(cfg, shape, mesh, bdim)),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
        static={"kind": "train"},
    )


def _build_prefill(cfg, shape, mesh, params, p_sh, bdim, vdim):
    batch = batch_inputs(cfg, shape)
    state = _sds_tree(lambda: MD.init_decode_state(
        cfg, shape.global_batch, shape.seq_len))
    st_specs = RU.decode_state_pspecs(cfg, mesh, state)
    st_sh = _named(mesh, st_specs)

    def step(params, batch, state):
        return MD.prefill(params, cfg, batch, state)

    return StepBundle(
        fn=step,
        args=(params, batch, state),
        in_shardings=(p_sh, _batch_shardings(cfg, shape, mesh, bdim), st_sh),
        out_shardings=(NamedSharding(mesh, P(bdim, vdim)), st_sh),
        donate_argnums=(2,),
        static={"kind": "prefill"},
    )


def _build_decode(cfg, shape, mesh, params, p_sh, bdim, vdim):
    b = shape.global_batch
    state = _sds_tree(lambda: MD.init_decode_state(cfg, b, shape.seq_len))
    st_specs = RU.decode_state_pspecs(cfg, mesh, state)
    st_sh = _named(mesh, st_specs)
    token = SDS((b,), jnp.int32)
    scalar = SDS((), jnp.int32)

    def step(params, token, pos, stp, state):
        return MD.decode_step(params, cfg, token, pos, stp, state)

    rep = NamedSharding(mesh, P())
    return StepBundle(
        fn=step,
        args=(params, token, scalar, scalar, state),
        in_shardings=(p_sh, NamedSharding(mesh, P(bdim)), rep, rep, st_sh),
        out_shardings=(NamedSharding(mesh, P(bdim, vdim)), st_sh, None),
        donate_argnums=(4,),
        static={"kind": "decode"},
    )


def _build_decode_paged(cfg, shape, mesh, params, p_sh, bdim, vdim,
                        active_tokens: int = LONG_CONTEXT_ACTIVE_TOKENS):
    b = shape.global_batch
    pages = active_tokens // cfg.freeze.page_size
    state = _sds_tree(lambda: MD.init_paged_decode_state(cfg, b, pages))
    st_specs = RU.decode_state_pspecs(cfg, mesh, state)
    st_sh = _named(mesh, st_specs)
    token = SDS((b,), jnp.int32)
    scalar = SDS((), jnp.int32)

    def step(params, token, pos, stp, tail, state):
        return MD.decode_step_paged(params, cfg, token, pos, stp, tail, state)

    rep = NamedSharding(mesh, P())
    return StepBundle(
        fn=step,
        args=(params, token, scalar, scalar, scalar, state),
        in_shardings=(p_sh, NamedSharding(mesh, P(bdim)), rep, rep, rep, st_sh),
        out_shardings=(NamedSharding(mesh, P(bdim, vdim)), st_sh, None),
        donate_argnums=(5,),
        static={"kind": "decode_paged",
                "active_pages": pages,
                "active_tokens": active_tokens},
    )

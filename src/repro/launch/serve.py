"""Serving launcher: drive any --arch config through one of the three
serving paths (see docs/serving.md for the architecture):

* default — ``ContinuousEngine``: continuous batching with per-lane
  admission/retirement over a dense (n_lanes, max_seq) KV cache.
* ``--paged`` — ``PagedContinuousEngine``: bounded-HBM decode over a
  per-lane active page pool (``--pages``) with chunked prefill
  (``--prefill-chunk``) and host page swapping; with ``--recovery`` the
  entropy ladder also thaws stashed pages and performs page-granular
  Rewalk rewinds (docs/recovery.md).
* ``--static`` — the pre-continuous-batching fixed-batch FIFO baseline
  (head-of-line blocking: every lane runs for the batch max n_tokens).

Continuous paths serve through the SLO-aware scheduler: ``--priority``
assigns a strict class to the submitted requests, ``--deadline-ms`` /
``--slo-tps`` attach per-request completion deadlines (EDF within a
class), and ``--background N`` floods N low-priority long generations
first so deadlined requests exercise freeze-native lane preemption
(``--no-preempt`` to disable; see docs/serving.md).

CPU/demo scale runs the tiny variant end-to-end; on a TPU slice the same
driver binds the production mesh (launch/mesh.py) and the jitted steps carry
the in/out shardings from launch/specs.py.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --tiny \
        --requests 8 --tokens 128
    PYTHONPATH=src python -m repro.launch.serve --tiny --paged --recovery
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import model as MD
from repro.serving.config import ServingConfig
from repro.serving.engine import (ContinuousEngine, Engine,
                                  PagedContinuousEngine)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler, StaticScheduler


def _serve_http(args, mk_engine) -> None:
    """--http: stand up the multi-tenant SSE streaming front end over one
    continuous engine (see serving/server.py) and serve until killed.

        curl -N localhost:PORT/v1/generate -H 'X-Tenant: gold' \\
             -d '{"prompt": [1, 2, 3], "n_tokens": 32}'
    """
    import asyncio

    from repro.serving.server import AsyncServingEngine, ServingServer
    from repro.serving.tenancy import TenancyController, TenantConfig
    if args.static or args.replicas > 1:
        raise SystemExit("--http serves one continuous engine "
                         "(no --static / --replicas)")
    tenancy = None
    if args.tenants:
        cfgs = []
        for spec in args.tenants.split(","):
            f = spec.split(":")
            cfgs.append(TenantConfig(
                f[0], weight=float(f[1]) if len(f) > 1 else 1.0,
                max_lanes=int(f[2]) if len(f) > 2 else None,
                tokens_per_s=float(f[3]) if len(f) > 3 else None))
        tenancy = TenancyController(cfgs)
    sched = Scheduler(mk_engine(), preemption=args.preempt,
                      tenancy=tenancy)

    async def _run():
        srv = ServingServer(AsyncServingEngine(sched), port=args.http)
        await srv.start()
        print(f"serving on http://{srv.host}:{srv.port}  "
              f"(POST /v1/generate streams SSE; GET /v1/health, "
              f"/v1/stats)", flush=True)
        try:
            await asyncio.Event().wait()
        finally:
            await srv.close()

    try:
        asyncio.run(_run())
    except KeyboardInterrupt:
        pass


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list_archs())
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU scale)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4,
                    help="number of engine lanes")
    ap.add_argument("--tokens", type=int, default=128)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--no-freeze", action="store_true")
    ap.add_argument("--static", action="store_true",
                    help="static FIFO batching baseline instead of "
                         "continuous batching")
    ap.add_argument("--paged", action="store_true",
                    help="bounded-HBM paged engine (chunked prefill, "
                         "O(pages) device KV per lane)")
    ap.add_argument("--pages", type=int, default=8,
                    help="device-resident pages per lane (--paged)")
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--recovery", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="entropy-guided recovery: the escalation ladder "
                         "(SR/WR/FR/RR) un-freezes KV on entropy spikes; "
                         "on --paged this includes host thaws of stashed "
                         "pages and page-granular rewinds "
                         "(--no-recovery = freeze-timer expiry only)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through ReplicaRouter over N in-process "
                         "engine replicas: SLO-aware placement, heartbeat "
                         "health-checking, incremental lane checkpoints "
                         "and zero-loss failover via freeze-native lane "
                         "migration (docs/robustness.md)")
    ap.add_argument("--kill-replica-at", type=int, default=None,
                    metavar="TICK",
                    help="crash replica 0 at this router tick (the "
                         "deterministic replica_crash fault site) to demo "
                         "failover; requires --replicas > 1")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    help="router ticks between incremental lane "
                         "checkpoints (--replicas > 1; smaller = less "
                         "repeated decode after a crash, more checkpoint "
                         "DMA)")
    ap.add_argument("--priority", type=int, default=0,
                    help="strict priority class for the submitted requests "
                         "(0 = most important; higher classes can be "
                         "preempted for lower ones)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request completion deadline (ms after "
                         "submission); deadlines order requests EDF within "
                         "a class and arm preemption")
    ap.add_argument("--slo-tps", type=float, default=None,
                    help="decode-rate SLO (tokens/s) converted to a "
                         "completion deadline per request")
    ap.add_argument("--background", type=int, default=0,
                    help="submit N extra priority-9 long generations first "
                         "(contention for the preemption demo)")
    ap.add_argument("--preempt", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="freeze-native lane preemption: suspend a running "
                         "lower-priority lane (stashing its pages to the "
                         "host store on --paged) when a deadline would "
                         "otherwise be missed (--no-preempt = admission "
                         "reordering only)")
    ap.add_argument("--stash-budget-mb", type=float, default=None,
                    help="host-stash memory budget (MiB); engages the "
                         "graceful-degradation ladder as stash pressure "
                         "rises (deny prefetch -> deepen freeze timers -> "
                         "throttle admissions -> shed lanes; "
                         "docs/robustness.md)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="enable deterministic fault injection on the "
                         "DMA/stash paths with this seed (retries, "
                         "breaker fallbacks and quarantine exercise the "
                         "chaos hardening; docs/robustness.md)")
    ap.add_argument("--chaos-rate", type=float, default=0.05,
                    help="per-site fault rate for --chaos-seed")
    ap.add_argument("--kv-quant", default="none",
                    choices=("none", "int8", "fp8"),
                    help="lossy per-page quantization of frozen/stashed KV "
                         "pages (core/quant.py): on --paged the device "
                         "pool's frozen pages and the host stash store a "
                         "1-byte payload with per-page per-kv-head scales "
                         "(dequantized in-kernel at attention time); on "
                         "the dense path the host stash alone is "
                         "quantized.  'fp8' needs ml_dtypes "
                         "float8_e4m3fn.  'none' is bit-identical to the "
                         "unquantized engine (docs/quantization.md)")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve over HTTP instead of driving a batch "
                         "trace: multi-tenant SSE streaming front end "
                         "(POST /v1/generate, GET /v1/health, /v1/stats; "
                         "PORT 0 = ephemeral; docs/serving.md)")
    ap.add_argument("--tenants", default=None,
                    metavar="NAME:WEIGHT[:LANES[:TPS]],...",
                    help="register tenants for --http, e.g. "
                         "'gold:3,free:1:1:50' — weighted fair sharing "
                         "plus optional concurrent-lane and tokens/s caps")
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--quantile-tau", type=float, default=0.45,
                    help="adaptive-tau quantile (0 = paper fixed tau)")
    ap.add_argument("--async", dest="async_pipeline",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="async DMA pipeline: the per-step token/telemetry "
                         "fetch rides a double-buffered ring (consumed one "
                         "step later), boundary-tick pool swaps batch into "
                         "one transfer pair, and on --paged likely thaws "
                         "are prefetched into device staging slots "
                         "(--no-async = block on every step's fetch — the "
                         "pre-pipeline baseline; identical decisions, and "
                         "bit-identical tokens under a deterministic "
                         "prefill-chunk schedule, see docs/serving.md)")
    args = ap.parse_args()

    cfg = get_config(args.arch + ("-tiny" if args.tiny else ""))
    if args.quantile_tau > 0:
        cfg = dataclasses.replace(cfg, freeze=dataclasses.replace(
            cfg.freeze, tau_mode="quantile", quantile=args.quantile_tau,
            window=16, k_soft=1.0, entropy_abs_threshold=1e9))
    cfg = dataclasses.replace(cfg, freeze=dataclasses.replace(
        cfg.freeze, recovery_enabled=args.recovery))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    mode = "static" if args.static else \
        ("paged-continuous" if args.paged else "continuous")
    print(f"arch={cfg.name} params={n/1e6:.1f}M "
          f"freeze={not args.no_freeze} batching={mode}")

    chaos = None
    if args.chaos_seed is not None:
        from repro.serving.faults import ChaosConfig
        chaos = ChaosConfig(seed=args.chaos_seed,
                            rates={s: args.chaos_rate for s in
                                   ("pull", "push", "ring", "stage")})
    budget = int(args.stash_budget_mb * 2**20) \
        if args.stash_budget_mb is not None else None
    sv = ServingConfig(max_seq=args.max_seq, n_lanes=args.batch,
                       enable_freeze=not args.no_freeze,
                       async_pipeline=args.async_pipeline,
                       prefill_chunk=args.prefill_chunk,
                       max_active_pages=args.pages if args.paged else None,
                       chaos=chaos, stash_budget_bytes=budget,
                       kv_quant=args.kv_quant)

    def mk_engine():
        if args.paged:
            return PagedContinuousEngine(cfg, params, serving=sv)
        return ContinuousEngine(cfg, params, serving=sv)

    if args.http is not None:
        _serve_http(args, mk_engine)
        return

    router = None
    if args.static:
        eng = Engine(cfg, params, max_seq=args.max_seq,
                     enable_freeze=not args.no_freeze)
        sched = StaticScheduler(eng, batch_size=args.batch)
    elif args.replicas > 1:
        from repro.serving.router import ReplicaRouter
        kill = None if args.kill_replica_at is None \
            else (0, args.kill_replica_at)
        router = ReplicaRouter([mk_engine() for _ in range(args.replicas)],
                               checkpoint_every=args.checkpoint_every,
                               kill_at=kill,
                               sched_kw=dict(preemption=args.preempt))
        eng = None
        sched = router   # submit()/run()/done/metrics-compatible front end
    else:
        eng = mk_engine()
        sched = Scheduler(eng, preemption=args.preempt)
    rng = np.random.RandomState(0)
    if not args.static:
        for _ in range(args.background):
            sched.submit(rng.randint(0, cfg.vocab_size, size=32),
                         max(args.tokens * 2, 64), SamplingParams.greedy(),
                         priority=9)
    for _ in range(args.requests):
        sp = SamplingParams(temperature=args.temperature)
        if args.static:
            sched.submit(
                rng.randint(0, cfg.vocab_size, size=rng.randint(16, 64)),
                args.tokens, sp)
        else:
            sched.submit(
                rng.randint(0, cfg.vocab_size, size=rng.randint(16, 64)),
                args.tokens, sp, priority=args.priority,
                deadline_ms=args.deadline_ms,
                slo_tokens_per_s=args.slo_tps)
    t0 = time.time()
    sched.run()
    dt = time.time() - t0
    total = sum(len(r.result) for r in sched.done.values())
    print(f"served {len(sched.done)} requests / {total} tokens in {dt:.1f}s "
          f"({1e3*dt/max(total,1):.1f} ms/token)")
    if router is not None:
        rep = router.report()
        steps = sum(h["health"]["wall_step"] for h in rep["replicas"])
        print(f"router: {rep['n_replicas']} replicas ({rep['n_live']} "
              f"live)  {rep['ticks']} ticks / {steps} engine steps  "
              f"failovers={rep['n_failovers']} "
              f"(ckpt-recovered={rep['recovered_with_checkpoint']} "
              f"reprefill={rep['recovered_reprefill']} "
              f"requeued={rep['requeued_items']})  "
              f"rebalanced={rep['n_rebalanced']}  "
              f"lost={rep['lost_requests']}")
    if not args.static and router is None:
        # first token of each request comes from its prefill, not a decode
        # step, so decode-step utilization excludes it
        decode_tokens = total - len(sched.done)
        util = 100 * decode_tokens / max(eng.wall_step * args.batch, 1)
        print(f"jitted steps: {eng.wall_step}  lane utilization: {util:.0f}%")
        if args.paged:
            print(f"device KV pool: {eng.kv_device_bytes} bytes "
                  f"(peak {eng.peak_kv_bytes} incl. prefill scratch)  "
                  f"page swaps: {eng.ctl.n_swap_out} out / "
                  f"{eng.ctl.n_swap_in} in / {eng.ctl.n_thaw} thawed")
            if eng.ctl.n_thaw:
                print(f"thaw installs: {eng.ctl.n_thaw_remap} remap-only "
                      f"(staged) / {eng.ctl.n_thaw_upload} uploaded")
            if args.kv_quant != "none":
                print(f"kv-quant({args.kv_quant}): "
                      f"{eng.ctl.n_quantized_pages} pages quantized  "
                      f"packed device savings now "
                      f"{eng.ctl.device_savings_bytes} bytes")
        s = eng.stats
        print(f"dma: host-blocked {100 * s.host_blocked_fraction:.0f}% of "
              f"steps ({s.blocked_steps}/{s.steps}; "
              f"{'async' if args.async_pipeline else 'sync'} pipeline)  "
              f"blocking {s.blocking_d2h} D2H / {s.blocking_h2d} H2D  "
              f"async {s.async_d2h} D2H / {s.async_h2d} H2D")
        if chaos is not None or budget is not None:
            rs = eng.robust_snapshot()
            print(f"chaos: injected={rs['injected']} "
                  f"retries={rs['retries']} "
                  f"breaker_trips={rs['breaker_trips']}  "
                  f"ladder: deny={rs['ladder_deny']} "
                  f"deepen={rs['ladder_deepen']} "
                  f"throttle={rs['ladder_throttle']} "
                  f"shed={rs['ladder_shed']}  "
                  f"stash peak {rs['peak_stash_bytes']}B"
                  + (f" / budget {rs['stash_budget_bytes']}B"
                     if budget is not None else ""))
    if not args.static:
        if args.recovery:
            rewinds = sum(r.telemetry.rewinds for r in sched.done.values()
                          if r.telemetry is not None)
            print(f"recovery: {rewinds} rewalk rewinds")
        # per-request terminal status: every request ends completed,
        # shed-resumed (survived a ladder shed) or quarantined
        statuses = {}
        for r in sched.done.values():
            statuses[r.status] = statuses.get(r.status, 0) + 1
        print("terminal: " + "  ".join(
            f"{k}={v}" for k, v in sorted(statuses.items())))
        n_pre = sum(r.sched.n_preemptions for r in router.replicas) \
            if router is not None else sched.n_preemptions
        hits = [m["deadline_hit"] for m in sched.metrics.values()
                if m["deadline_hit"] is not None]
        if hits or n_pre:
            rate = 100 * sum(hits) / len(hits) if hits else 100.0
            print(f"slo: {n_pre} preemptions  "
                  f"deadline hit rate {rate:.0f}% "
                  f"({sum(hits)}/{len(hits)} deadlined requests)")


if __name__ == "__main__":
    main()

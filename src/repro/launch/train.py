"""Training launcher: --arch selects any assigned architecture; on a real
slice this binds the production mesh and the FSDP x tensor shardings from
launch/specs.py; --tiny runs the reduced config end-to-end on CPU.

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --tiny \
        --steps 50 --seq 128 --batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.training import checkpoint as CKPT
from repro.training import data as DATA
from repro.training import train_step as TS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list_archs())
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch + ("-tiny" if args.tiny else ""))
    if cfg.is_encoder_decoder or cfg.multimodal:
        print("note: frontend is stubbed; frames/patches are random inputs")
    state = TS.init_train_state(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M")

    step_fn = jax.jit(lambda s, b: TS.train_step(s, b, cfg, lr=args.lr))
    it = DATA.synthetic_lm(DATA.DataConfig(cfg.vocab_size, args.seq,
                                           args.batch))
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.encoder_frames, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.multimodal:
            from repro.models.transformer import PATCH_STUB_DIM
            batch["patch_embeds"] = jax.random.normal(
                key, (args.batch, cfg.num_patches, PATCH_STUB_DIM),
                jnp.dtype(cfg.dtype))
        state, m = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(m['loss']):.3f} "
                  f"aux {float(m['aux_loss']):.3f} "
                  f"{(time.time()-t0)/(i+1):.2f}s/step", flush=True)
    if args.ckpt:
        CKPT.save(args.ckpt, state.params)
        print("checkpoint:", args.ckpt)


if __name__ == "__main__":
    main()

"""Production mesh factories.

Single pod: 256 TPU v5e chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the pod axis
carries pure data parallelism (gradient all-reduce over DCI) while params
are FSDP-sharded over ('pod','data') and tensor-sharded over 'model'.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
xla_force_host_platform_device_count trick.
"""
from __future__ import annotations

import jax


def abstract_mesh(shape, axes):
    """Version-compatible ``jax.sharding.AbstractMesh`` factory.

    JAX 0.4.35+ takes a tuple of (axis_name, size) pairs; earlier releases
    took ``(shape, axis_names)`` positionally.  Spec-building tests and
    dry-runs construct device-free meshes through this helper so they run
    on either signature."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axes))


def _make_mesh(shape, axes):
    """Version-compatible ``jax.make_mesh``: the helper only landed in
    JAX 0.4.35, and CI's oldest-supported matrix leg (0.4.34, the last
    pre-``AbstractMesh``-signature-change release) predates it."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests/benches (same axis names as single-pod)."""
    return _make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (roofline denominators; EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link

"""Runtime invariant auditor for the paged serving stack — the
consistency sibling of ``trace_guard`` (which audits compile caches, not
data structures).

The paged engine's correctness rests on a handful of cross-structure
invariants that no single module can check alone: the device page table,
the host stash, the freeze metadata and the staging slots all describe
the *same* pages from different sides.  A fault-recovery path that
leaves them disagreeing (a page both resident and timer-tracked, a
staged key whose page vanished, stash-byte accounting that drifts from
the stored arrays) corrupts generation much later than the bug that
caused it.  ``audit_controller`` / ``audit_boundary`` assert the
agreement at the only moment the host holds a coherent view — the page
boundary tick, right after the controller pass — and raise
``InvariantViolation`` naming the first inconsistency.

Cost: pure numpy scans of host metadata (no device sync), linear in
pool slots + stash entries.  The engine runs them only under its
``debug_invariants`` flag (tests, chaos benchmark, property tests);
production ticks skip them entirely.
"""
from __future__ import annotations

from typing import Dict, Iterable

import numpy as np


class InvariantViolation(AssertionError):
    """A pool/stash/lane consistency invariant does not hold."""


def _fail(msg: str) -> None:
    raise InvariantViolation(msg)


def audit_controller(ctl) -> None:
    """Controller-local invariants of a ``PagedController``:

    * stash-byte accounting is exact (incremental gauge == recomputed);
    * every timer-tracked page (``frozen_meta``) has its bytes in the
      store — a timer over missing data would swap garbage in;
    * every staged key refers to a stashed page and a slot the lane
      actually reserved — a stale entry would remap dead bytes;
    * gauges are non-negative.
    """
    recomputed = ctl.host_bytes()
    if ctl.stash_bytes != recomputed:
        _fail(f"stash_bytes gauge {ctl.stash_bytes} != "
              f"recomputed store bytes {recomputed}")
    if ctl.stash_bytes < 0 or ctl.exported_bytes < 0:
        _fail(f"negative byte gauge: stash={ctl.stash_bytes} "
              f"exported={ctl.exported_bytes}")
    for key in ctl.frozen_meta:
        if key not in ctl.store:
            _fail(f"frozen_meta key {key} has no stored bytes")
        if ctl.frozen_meta[key]["d"] <= 0:
            # an expired timer must be consumed by the tick that expired
            # it (or reset to retry); it must never persist across ticks
            _fail(f"frozen_meta key {key} carries non-positive timer "
                  f"{ctl.frozen_meta[key]['d']}")
    for key, slot in ctl.staged_keys.items():
        if key not in ctl.frozen_meta:
            _fail(f"staged key {key} is not a stashed page")
        reserved = ctl.stage_slots.get((key[0], key[1]), [])
        if slot not in reserved:
            _fail(f"staged key {key} sits in slot {slot}, not one of the "
                  f"lane's reserved staging slots {reserved}")


def audit_boundary(ctl, pool: Dict[str, np.ndarray],
                   fstate: Dict[str, np.ndarray],
                   lanes: Iterable[int],
                   lane_ids: Dict[int, int] | None = None) -> None:
    """Pool-vs-stash invariants over the pulled boundary-tick slices.

    ``pool``/``fstate`` are the host copies the engine just ran the
    controller pass on; ``lanes`` are the pool batch indices present,
    ``lane_ids`` maps them to global lane ids (identity when None).

    * slot-map bijectivity: within one (layer, lane) no global page id
      occupies two physical slots;
    * visibility-mask agreement: slot_mask never asserts tokens in an
      unmapped slot, and every frozen flag sits on a mapped slot;
    * residency exclusivity: a page id that is timer-tracked in the
      host stash (``frozen_meta``) is not simultaneously device-mapped
      for the same (layer, lane) — the double-residency would let a
      swap-in overwrite a live slot.
    """
    audit_controller(ctl)
    pt, sm = pool["page_table"], pool["slot_mask"]
    frozen = fstate["frozen"]
    L = pt.shape[0]
    for b in lanes:
        gb = lane_ids[b] if lane_ids is not None else b
        for l in range(L):
            gids = pt[l, b][pt[l, b] >= 0]
            if len(gids) != len(np.unique(gids)):
                _fail(f"layer {l} lane {gb}: page table maps a global id "
                      f"into two slots: {sorted(gids.tolist())}")
            unmapped = pt[l, b] < 0
            if bool(np.any(sm[l, b][unmapped])):
                _fail(f"layer {l} lane {gb}: slot_mask asserts tokens in "
                      f"an unmapped physical slot")
            if bool(np.any(frozen[l, b] & unmapped)):
                _fail(f"layer {l} lane {gb}: frozen flag on an unmapped "
                      f"physical slot")
            resident = set(int(g) for g in gids)
            stashed = {key[2] for key in ctl.frozen_meta
                       if key[0] == l and key[1] == gb}
            both = resident & stashed
            if both:
                _fail(f"layer {l} lane {gb}: pages {sorted(both)} are "
                      f"both device-resident and stash-timer-tracked")

"""trace_guard — assert jit compile caches stay flat over a workload.

A jitted callable's ``_cache_size()`` counts the traces it has compiled;
steady-state serving must not grow it (every retrace stalls a step on
XLA compilation, the exact pathology the ROADMAP's async-latency item
blames).  The guard snapshots every trackable jit before and after a
``with`` block::

    with trace_guard(engine, label="timed region") as tg:
        for _ in range(steps):
            engine.step_once()
    report["n_retraces"] = tg.n_retraces          # 0 when warm

Targets may be jitted callables themselves or objects whose attributes
hold them (the engines: ``self._step``, ``self._chunk``...).  Pass
``max_new_compiles=0`` to raise ``RetraceError`` on any growth instead
of just reporting it — benchmarks report, CI asserts via
``tools/check_bench.py --max-retraces``.

``_cache_size`` is a private jax API (present on the pinned 0.4.x line);
callables without it are skipped and listed in ``report.untracked`` so a
jax upgrade degrades this to a no-op rather than an error.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Iterator, List, Tuple


class RetraceError(RuntimeError):
    """Raised when a guarded region compiled more traces than allowed."""


def _cache_size(fn: Any) -> int:
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


def _discover(targets: Tuple[Any, ...]) -> Tuple[Dict[str, Any], List[str]]:
    """Map label -> jitted callable for every trackable jit reachable from
    ``targets`` (the target itself, or its instance attributes)."""
    tracked: Dict[str, Any] = {}
    untracked: List[str] = []

    def add(label: str, fn: Any) -> None:
        if _cache_size(fn) >= 0:
            base, n = label, 2
            while label in tracked:            # e.g. two engines of a class
                label = f"{base}#{n}"
                n += 1
            tracked[label] = fn
        else:
            untracked.append(label)

    for t in targets:
        if hasattr(t, "_cache_size"):
            add(getattr(t, "__name__", type(t).__name__), t)
            continue
        attrs = vars(t) if hasattr(t, "__dict__") else {}
        found = False
        for name, val in attrs.items():
            if hasattr(val, "_cache_size"):
                add(f"{type(t).__name__}.{name}", val)
                found = True
        if not found:
            untracked.append(type(t).__name__)
    return tracked, untracked


@dataclasses.dataclass
class TraceReport:
    label: str
    before: Dict[str, int]
    after: Dict[str, int] = dataclasses.field(default_factory=dict)
    untracked: List[str] = dataclasses.field(default_factory=list)
    _fns: Dict[str, Any] = dataclasses.field(default_factory=dict, repr=False)

    @property
    def growth(self) -> Dict[str, int]:
        """New compiles per jit over the guarded region (grown only)."""
        return {k: self.after.get(k, v) - v
                for k, v in self.before.items()
                if self.after.get(k, v) != v}

    @property
    def n_retraces(self) -> int:
        return sum(self.growth.values())

    def summary(self) -> Dict[str, Any]:
        return {"label": self.label, "n_retraces": self.n_retraces,
                "growth": self.growth, "n_tracked": len(self.before),
                "untracked": list(self.untracked)}


@contextlib.contextmanager
def trace_guard(*targets: Any, max_new_compiles: int = None,
                label: str = "") -> Iterator[TraceReport]:
    fns, untracked = _discover(targets)
    report = TraceReport(label=label,
                         before={k: _cache_size(f) for k, f in fns.items()},
                         untracked=untracked, _fns=fns)
    try:
        yield report
    finally:
        report.after = {k: _cache_size(f) for k, f in fns.items()}
    if max_new_compiles is not None and report.n_retraces > max_new_compiles:
        raise RetraceError(
            f"jit compile caches grew by {report.n_retraces} trace(s) "
            f"(allowed {max_new_compiles}) in {label or 'guarded region'}: "
            f"{report.growth}")

"""Runtime companions to the static-analysis suite (tools/analysis).

The static ``retrace`` pass is a lexical heuristic; ``trace_guard`` is
its runtime backstop — it watches the actual jit compile caches while a
workload runs and asserts they stop growing once warm.
"""
from .runtime import RetraceError, TraceReport, trace_guard

__all__ = ["RetraceError", "TraceReport", "trace_guard"]

"""Runtime companions to the static-analysis suite (tools/analysis).

The static ``retrace`` pass is a lexical heuristic; ``trace_guard`` is
its runtime backstop — it watches the actual jit compile caches while a
workload runs and asserts they stop growing once warm.  The invariant
auditor (``audit_controller`` / ``audit_boundary``) is the data-structure
counterpart: pool/stash/lane consistency checks the serving engine runs
at boundary ticks under its ``debug_invariants`` flag.
"""
from .invariants import InvariantViolation, audit_boundary, audit_controller
from .runtime import RetraceError, TraceReport, trace_guard

__all__ = ["InvariantViolation", "RetraceError", "TraceReport",
           "audit_boundary", "audit_controller", "trace_guard"]

"""whisper-base [audio] — enc-dec, 6L d_model=512 8H d_ff=2048 vocab=51865;
conv/mel frontend is a STUB (input_specs provides precomputed frame
embeddings, 1500 frames for 30s audio).  [arXiv:2212.04356]

Vocab padded 51865 -> 51968 for 16-way tensor sharding (DESIGN.md §4).
ASR-KF-EGR applies to the decoder self-attention cache only; cross-attention
KV is static (encoder length).  No long_500k shape (DESIGN.md §5 skip note).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,                # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=6,
    encoder_frames=1500,
    rope_theta=10000.0,          # unused (learned positions) but kept uniform
    source="arXiv:2212.04356",
)

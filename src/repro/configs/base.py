"""Config dataclasses for models, freezing (ASR-KF-EGR) and runtime shapes.

Every assigned architecture gets one module in this package defining
``CONFIG = ModelConfig(...)`` with the exact published dimensions (source
cited in the module docstring).  ``tiny()`` derives the reduced variant used
by CPU smoke tests (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class FreezeConfig:
    """Hyperparameters of ASR-KF-EGR (paper §4.1 defaults).

    window:   sliding window K of most-recent tokens never considered for
              freezing (paper: K=32).
    tau:      relevance threshold; tokens with mean |Q.K| below it are
              low-importance candidates (paper: 0.50).
    k_soft:   softness parameter k in d = floor(sqrt(c)/k) (paper: 2.0).
    history:  history window W for the detection counter c (paper §3.4).
              Realized as a periodic decrement: every ``history`` steps each
              counter decays by 1 so stale detections age out.
    page_size:         tokens per KV page for the batched host-offload path.
    max_active_pages:  device-resident page budget per sequence for the
                       bounded-active (long-context) serving mode; 0 = uncapped.
    """

    window: int = 32
    tau: float = 0.50
    k_soft: float = 2.0
    history: int = 256
    # --- beyond-paper: adaptive threshold (DESIGN.md §2) ---
    # "fixed": paper-faithful tau.  "quantile": per-sequence, per-step
    # threshold = the `quantile` quantile of eligible relevance scores, so
    # the flag rate (and hence compression) is scale-invariant — removes
    # the paper's §6 threshold-sensitivity limitation.
    tau_mode: str = "fixed"
    quantile: float = 0.35
    page_size: int = 64
    max_active_pages: int = 0
    # --- entropy-guided recovery (paper §3.6; implemented here) ---
    recovery_enabled: bool = True
    entropy_abs_threshold: float = 4.0     # nats; hard spike level
    entropy_rel_factor: float = 1.75       # spike if H > factor * EMA(H)
    entropy_ema_decay: float = 0.95
    recovery_window: int = 64              # N for Window Reset
    rewalk_tokens: int = 8                 # k for Rewalk Regeneration
    calm_steps_to_deescalate: int = 16


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                  # query heads; 0 for attention-free (ssm)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # ---- MoE ----
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1              # MoE FFN on layers with l % moe_every == moe_offset
    moe_offset: int = 0
    # ---- hybrid (jamba): one attention layer per `attn_every` layers ----
    attn_every: int = 0             # 0 = attention everywhere (or ssm everywhere)
    # ---- mamba ----
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0          # 0 -> ceil(d_model/16)
    # ---- rwkv6 ----
    rwkv_head_dim: int = 64
    # ---- encoder-decoder (whisper) ----
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_frames: int = 1500      # stub conv-frontend output length
    # ---- multimodal stub (early-fusion VLMs) ----
    multimodal: bool = False
    num_patches: int = 256          # stub patch-embedding prefix length
    # ---- misc ----
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # §Perf H2: decode-time activation-gather mode for models whose weights
    # exceed the resident budget under tensor-only sharding.  Activations are
    # replicated over the fsdp axis at the block entry (KBs for a decode
    # step) so the 2-D-sharded weights stay RESIDENT — the per-step FSDP
    # weight all-gather (GBs) disappears.  Set by launch/specs.py.
    decode_act_gather: bool = False
    # §Perf H5: explicit activation sharding constraints (batch axes + model
    # partitions) — defeats SPMD "involuntary full rematerialization" of
    # batch-replicated activations inside scanned mamba/attention bodies.
    # Set by launch/specs.py; empty tuple = no constraints (baseline).
    act_batch_axes: Tuple[str, ...] = ()
    act_model_parts: int = 0
    # §Perf H1: remat chunk for the Mamba selective-scan time dimension
    # during training (0 = plain scan, saves every per-step carry for the
    # backward pass).  Set by launch/specs.py for train bundles.
    mamba_scan_chunk: int = 0
    source: str = ""                # citation
    freeze: FreezeConfig = dataclasses.field(default_factory=FreezeConfig)

    # ------------------------------------------------------------------ #
    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (Megatron-style) so the vocab
        dim shards cleanly over 16-way tensor axes (whisper: 51865->51968)."""
        return -(-self.vocab_size // 128) * 128

    def is_attn_layer(self, layer: int) -> bool:
        if self.arch_type == "ssm":
            return False
        if self.attn_every <= 1:
            return True
        return layer % self.attn_every == 0

    def is_moe_layer(self, layer: int) -> bool:
        if self.num_experts == 0:
            return False
        return layer % self.moe_every == self.moe_offset

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline
        MODEL_FLOPS = 6*N*D."""
        n = self.padded_vocab * self.d_model  # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model  # lm head
        n += self._block_params(active_only=False)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        n = self.padded_vocab * self.d_model
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model
        n += self._block_params(active_only=True)
        return n

    def _block_params(self, active_only: bool) -> int:
        d, f = self.d_model, self.d_ff
        total = 0
        for l in range(self.num_layers):
            if self.is_attn_layer(l):
                total += d * self.num_heads * self.head_dim * 2          # wq, wo
                total += d * self.num_kv_heads * self.head_dim * 2       # wk, wv
            elif self.arch_type in ("hybrid",):                          # mamba layer
                di = self.mamba_expand * d
                total += d * 2 * di + di * self.mamba_d_conv
                total += di * (self.dt_rank + 2 * self.mamba_d_state)
                total += self.dt_rank * di + di * self.mamba_d_state + di
                total += di * d
            if self.arch_type == "ssm":                                  # rwkv6 block
                total += 4 * d * d + d * d                               # r,k,v,g,o
                total += d * f + f * d                                   # channel mix
                continue
            # FFN
            ffn = 3 * d * f                                              # swiglu
            if self.is_moe_layer(l):
                e = self.experts_per_token if active_only else self.num_experts
                total += ffn * e + d * self.num_experts                  # experts + router
            else:
                total += ffn
            total += 2 * d                                               # norms
        return total

    def tiny(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests."""
        d = min(self.d_model, 256)
        heads = 4 if self.num_heads else 0
        kvh = min(self.num_kv_heads, 2) if self.num_kv_heads else 0
        if self.num_kv_heads == 1:
            kvh = 1  # preserve MQA-ness
        return dataclasses.replace(
            self,
            name=self.name + "-tiny",
            num_layers=2,
            attn_every=min(self.attn_every, 2),
            d_model=d,
            num_heads=heads,
            num_kv_heads=kvh,
            head_dim=d // heads if heads else 64,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=min(self.encoder_frames, 64),
            num_patches=min(self.num_patches, 8),
            rwkv_head_dim=min(self.rwkv_head_dim, 64),
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

"""rwkv6-1.6b [ssm] — Finch: 24L d_model=2048 attention-free, d_ff=7168
vocab=65536; data-dependent decay linear attention.  [arXiv:2404.05892]

ASR-KF-EGR is inapplicable (no KV cache; O(1) recurrent WKV state) — the
architecture is built and served without the technique (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    source="arXiv:2404.05892",
)

"""chameleon-34b [vlm] — early-fusion mixed-modal transformer; image content
arrives as VQ tokens / patch embeddings consumed by the decoder backbone.
48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  [arXiv:2405.09818]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    multimodal=True,
    num_patches=256,
    rope_theta=10000.0,
    source="arXiv:2405.09818",
)

"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import INPUT_SHAPES, FreezeConfig, InputShape, ModelConfig

_ARCH_MODULES = {
    "chameleon-34b": "chameleon_34b",
    "mistral-large-123b": "mistral_large_123b",
    "starcoder2-15b": "starcoder2_15b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "granite-20b": "granite_20b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "whisper-base": "whisper_base",
    "llama3-8b": "llama3_8b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    tiny = name.endswith("-tiny")
    base = name[: -len("-tiny")] if tiny else name
    if base not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[base]}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.tiny() if tiny else cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in _ARCH_MODULES}


__all__ = [
    "ModelConfig",
    "FreezeConfig",
    "InputShape",
    "INPUT_SHAPES",
    "get_config",
    "list_archs",
    "all_configs",
]

"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256.  [arXiv:2407.21783]

This is also the paper's own evaluation model (ASR-KF-EGR §4.1: LLaMA-3 8B).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    source="arXiv:2407.21783",
)

"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2; Mamba+attention 1:7 interleave (one
attention layer per 8-layer block), MoE on every second layer.
[arXiv:2403.19887]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,            # 1 attention : 7 mamba
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    rope_theta=10000.0,
    source="arXiv:2403.19887",
)

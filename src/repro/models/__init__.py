from repro.models import model
from repro.models.model import (decode_step, decode_step_paged, init_decode_state,
                                init_paged_decode_state, init_params, prefill,
                                schema, train_logits)

__all__ = ["model", "decode_step", "decode_step_paged", "init_decode_state",
           "init_paged_decode_state", "init_params", "prefill", "schema",
           "train_logits"]

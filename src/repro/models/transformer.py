"""Decoder-only LM assembly covering dense / GQA / MQA / MoE / VLM-backbone
and the Jamba-style hybrid (Mamba+attention interleave, MoE every 2nd layer)
and RWKV-6 families.

Layers are grouped into homogeneous *units* scanned with lax.scan (stacked
params => HLO size is O(one unit) even for 88-layer models).  A unit is one
layer for uniform stacks, or `attn_every` layers for hybrids (jamba: 8 = one
attention + seven mamba), preserving the published interleave exactly.

Decode integrates ASR-KF-EGR per attention layer: the decode-attention
|Q.K| products double as the Eq. 2 relevance scores (zero extra HBM passes),
feeding the freeze state machine; entropy-guided recovery runs on the final
logits over the stacked freeze state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FreezeConfig, ModelConfig
from repro.core.freeze import FreezeState, freeze_update, init_freeze_state
from repro.core.paging import (PageFreezeState, page_freeze_update,
                               write_tail)
from repro.kernels import ops as OPS
from repro.core.recovery import (RecoveryState, page_recovery_update,
                                 recovery_update)
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rwkv6 as R
from repro.models.layers import ParamSpec

PATCH_STUB_DIM = 1024   # stub vision-frontend embedding width (DESIGN.md §3)


# --------------------------------------------------------------------- #
# Unit/role layout
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Role:
    kind: str      # "attn" | "mamba" | "rwkv"
    moe: bool


def unit_roles(cfg: ModelConfig) -> List[Role]:
    """Roles of the layers inside one scanned unit."""
    if cfg.arch_type == "ssm":
        return [Role("rwkv", False)]
    unit = cfg.attn_every if cfg.attn_every > 1 else 1
    roles = []
    for p in range(unit):
        kind = "attn" if cfg.is_attn_layer(p) else "mamba"
        roles.append(Role(kind, cfg.is_moe_layer(p)))
    return roles


def num_units(cfg: ModelConfig) -> int:
    unit = len(unit_roles(cfg))
    assert cfg.num_layers % unit == 0, (cfg.num_layers, unit)
    return cfg.num_layers // unit


def attn_layer_count(cfg: ModelConfig) -> int:
    return sum(1 for l in range(cfg.num_layers) if cfg.is_attn_layer(l))


def mamba_layer_count(cfg: ModelConfig) -> int:
    if cfg.arch_type != "hybrid":
        return 0
    return cfg.num_layers - attn_layer_count(cfg)


# --------------------------------------------------------------------- #
# Schema / init
# --------------------------------------------------------------------- #
def _layer_schema(cfg: ModelConfig, role: Role) -> Dict[str, Any]:
    if role.kind == "rwkv":
        return R.rwkv_schema(cfg)
    s: Dict[str, Any] = {"norm1": ParamSpec((cfg.d_model,), (None,), scale=0.0)}
    if role.kind == "attn":
        s["attn"] = L.attention_schema(cfg)
    else:
        s["mamba"] = M.mamba_schema(cfg)
    s["norm2"] = ParamSpec((cfg.d_model,), (None,), scale=0.0)
    s["ffn"] = MOE.moe_schema(cfg) if role.moe else L.mlp_schema(cfg)
    return s


def schema(cfg: ModelConfig) -> Dict[str, Any]:
    roles = unit_roles(cfg)
    unit = {f"l{i}": _layer_schema(cfg, r) for i, r in enumerate(roles)}
    vp, d = cfg.padded_vocab, cfg.d_model
    s: Dict[str, Any] = {
        "embed": ParamSpec((vp, d), ("vocab", "embed")),
        "unembed": ParamSpec((d, vp), ("embed", "vocab")),
        "final_norm": ParamSpec((d,), (None,), scale=0.0),
        "blocks": L.stack_schema(unit, num_units(cfg)),
    }
    if cfg.multimodal:
        s["patch_proj"] = ParamSpec((PATCH_STUB_DIM, d), (None, "embed"))
    return s


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    return L.init_from_schema(key, schema(cfg), jnp.dtype(cfg.dtype))


# --------------------------------------------------------------------- #
# Embedding / unembedding
# --------------------------------------------------------------------- #
def embed(params, cfg: ModelConfig, tokens: jnp.ndarray,
          patch_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.multimodal and patch_embeds is not None:
        # early fusion stub: precomputed patch embeddings occupy the first
        # num_patches positions (vision frontend is out of scope; DESIGN.md)
        proj = jnp.einsum("bpe,ed->bpd", patch_embeds.astype(x.dtype),
                          params["patch_proj"])
        npatch = proj.shape[1]
        if tokens.shape[1] >= npatch:
            pos = jnp.arange(tokens.shape[1])[None, :, None]
            pad = jnp.pad(proj, ((0, 0), (0, tokens.shape[1] - npatch), (0, 0)))
            x = jnp.where(pos < npatch, pad, x)
    return x


def unembed(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    logits = jnp.einsum("...d,dv->...v", x, params["unembed"])
    vp = cfg.padded_vocab
    if vp != cfg.vocab_size:   # mask padded vocab entries
        bias = jnp.where(jnp.arange(vp) < cfg.vocab_size, 0.0, -1e30)
        logits = logits + bias
    return logits


# --------------------------------------------------------------------- #
# Full-sequence unit forward (training / prefill)
# --------------------------------------------------------------------- #
def _unit_forward(cfg: ModelConfig, roles, up, x, positions,
                  collect_kv: bool):
    """x: (B,S,D). Returns (x, aux, kv list [(k,v)] for attn layers,
    mamba final states list, rwkv final states list)."""
    aux = jnp.zeros((), jnp.float32)
    kvs = []
    for i, role in enumerate(roles):
        lp = up[f"l{i}"]
        if role.kind == "rwkv":
            x = R.rwkv_forward(lp, x, cfg, cfg.norm_eps)
            continue
        xn = L.rms_norm(x, lp["norm1"] + 1.0, cfg.norm_eps)
        if role.kind == "attn":
            q, k, v = L.attention_qkv(lp["attn"], xn, positions, cfg.rope_theta)
            q = L.constrain(q, cfg, "b.m.")
            k = L.constrain(k, cfg, "b.m.")
            v = L.constrain(v, cfg, "b.m.")
            o = L.constrain(L.flash_attention(q, k, v, causal=True),
                            cfg, "b.m.")
            x = x + L.attention_out(lp["attn"], o)
            if collect_kv:
                kvs.append((k, v))
        else:
            x = x + M.mamba_forward(lp["mamba"], xn, cfg)
        xn2 = L.rms_norm(x, lp["norm2"] + 1.0, cfg.norm_eps)
        if role.moe:
            y, a = MOE.moe_forward(lp["ffn"], xn2, cfg)
            aux = aux + a
        else:
            y = L.mlp_forward(lp["ffn"], xn2, cfg)
        x = x + y
    return x, aux, kvs


def lm_forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
               patch_embeds: Optional[jnp.ndarray] = None,
               remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/eval forward over a full sequence -> (logits, aux_loss)."""
    roles = unit_roles(cfg)
    x = embed(params, cfg, tokens, patch_embeds)
    positions = jnp.arange(tokens.shape[1])

    def body(carry, up):
        x, aux = carry
        x, a, _ = _unit_forward(cfg, roles, up, x, positions, collect_kv=False)
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = L.rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    return unembed(params, cfg, x), aux


# --------------------------------------------------------------------- #
# Prefill: forward + KV cache & recurrent-state materialization
# --------------------------------------------------------------------- #
class DecodeState(NamedTuple):
    """Everything the decode step carries between tokens (all stacked)."""
    cache_k: jnp.ndarray      # (L_attn, B, S, KVH, hd)   (zeros if no attn)
    cache_v: jnp.ndarray
    freeze: FreezeState       # arrays (L_attn, B, S)
    mamba: Dict[str, jnp.ndarray]   # conv (L_m,B,k-1,di), ssm (L_m,B,di,n)
    rwkv: Dict[str, jnp.ndarray]    # tm_x/cm_x (L,B,D), wkv (L,B,H,hd,hd)
    recovery: RecoveryState


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=None) -> DecodeState:
    from repro.core.recovery import init_recovery_state
    dt = jnp.dtype(dtype or cfg.dtype)
    la = attn_layer_count(cfg)
    lm = mamba_layer_count(cfg)
    kvh, hd = max(cfg.num_kv_heads, 1), cfg.head_dim
    di = cfg.mamba_expand * cfg.d_model
    cache_shape = (la, batch, max_seq, kvh, hd)
    fz = init_freeze_state(batch, max_seq)
    fz = FreezeState(*(jnp.broadcast_to(a, (max(la, 1),) + a.shape)
                       for a in fz))
    mamba = {
        "conv": jnp.zeros((lm, batch, cfg.mamba_d_conv - 1, di), dt),
        "ssm": jnp.zeros((lm, batch, di, cfg.mamba_d_state), jnp.float32),
    } if lm else {}
    rwkv = {}
    if cfg.arch_type == "ssm":
        hdr = cfg.rwkv_head_dim
        h = cfg.d_model // hdr
        rwkv = {
            "tm_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            "cm_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            "wkv": jnp.zeros((cfg.num_layers, batch, h, hdr, hdr), jnp.float32),
        }
    return DecodeState(
        cache_k=jnp.zeros(cache_shape, dt),
        cache_v=jnp.zeros(cache_shape, dt),
        freeze=fz,
        mamba=mamba,
        rwkv=rwkv,
        recovery=init_recovery_state(batch),
    )


def write_lane_state(state: DecodeState, lane_state: DecodeState,
                     lane: jnp.ndarray) -> DecodeState:
    """Scatter a single-lane (B=1) DecodeState into batch lane `lane` of a
    multi-lane state — the continuous-batching admission path.

    The scatter overwrites the lane's KV cache, freeze masks, recurrent
    states and recovery ladder wholesale, so admitting a freshly-prefilled
    lane state doubles as the lane-granular reset (no stale freeze counters
    or entropy baselines survive from the lane's previous occupant)."""
    lane = jnp.asarray(lane, jnp.int32)
    w1 = lambda big, small: jax.lax.dynamic_update_slice_in_dim(
        big, small.astype(big.dtype), lane, axis=1)
    w0 = lambda big, small: jax.lax.dynamic_update_slice_in_dim(
        big, small.astype(big.dtype), lane, axis=0)
    return DecodeState(
        cache_k=w1(state.cache_k, lane_state.cache_k),
        cache_v=w1(state.cache_v, lane_state.cache_v),
        freeze=FreezeState(*(w1(a, b) for a, b
                             in zip(state.freeze, lane_state.freeze))),
        mamba={k: w1(state.mamba[k], lane_state.mamba[k])
               for k in state.mamba},
        rwkv={k: w1(state.rwkv[k], lane_state.rwkv[k])
              for k in state.rwkv},
        recovery=RecoveryState(*(w0(a, b) for a, b
                                 in zip(state.recovery, lane_state.recovery))),
    )


def _split_xs(state: DecodeState, cfg: ModelConfig):
    """Reshape stacked per-layer state into per-unit xs for lax.scan."""
    roles = unit_roles(cfg)
    n = num_units(cfg)
    ia = sum(1 for r in roles if r.kind == "attn")
    im = sum(1 for r in roles if r.kind == "mamba")
    xs = {}
    if ia:
        xs["cache_k"] = state.cache_k.reshape((n, ia) + state.cache_k.shape[1:])
        xs["cache_v"] = state.cache_v.reshape((n, ia) + state.cache_v.shape[1:])
        xs["freeze"] = FreezeState(*(a.reshape((n, ia) + a.shape[1:])
                                     for a in state.freeze))
    if im:
        xs["mamba"] = {k: v.reshape((n, im) + v.shape[1:])
                       for k, v in state.mamba.items()}
    if cfg.arch_type == "ssm":
        xs["rwkv"] = {k: v.reshape((n, 1) + v.shape[1:])
                      for k, v in state.rwkv.items()}
    return xs


def _merge_ys(state: DecodeState, ys, cfg: ModelConfig) -> DecodeState:
    flat = lambda a: a.reshape((-1,) + a.shape[2:])
    out = state
    if "cache_k" in ys:
        out = out._replace(
            cache_k=flat(ys["cache_k"]), cache_v=flat(ys["cache_v"]),
            freeze=FreezeState(*(flat(a) for a in ys["freeze"])))
    if "mamba" in ys:
        out = out._replace(mamba={k: flat(v) for k, v in ys["mamba"].items()})
    if "rwkv" in ys:
        out = out._replace(rwkv={k: flat(v) for k, v in ys["rwkv"].items()})
    return out


def lm_prefill(params, cfg: ModelConfig, tokens: jnp.ndarray,
               state: DecodeState,
               patch_embeds: Optional[jnp.ndarray] = None,
               remat: bool = True) -> Tuple[jnp.ndarray, DecodeState]:
    """Process the prompt, writing KV caches / recurrent states.
    Returns (last-token logits (B, V), updated DecodeState)."""
    roles = unit_roles(cfg)
    B, S = tokens.shape
    x = embed(params, cfg, tokens, patch_embeds)
    positions = jnp.arange(S)
    xs_state = _split_xs(state, cfg)

    def body(carry, xs):
        x, aux = carry
        up = xs["params"]
        ia = im = 0
        kv_out, m_out, r_out = [], [], []
        for i, role in enumerate(roles):
            lp = up[f"l{i}"]
            if role.kind == "rwkv":
                x, st = R.rwkv_forward_with_state(lp, x, cfg, cfg.norm_eps)
                r_out.append(st)
                continue
            xn = L.rms_norm(x, lp["norm1"] + 1.0, cfg.norm_eps)
            if role.kind == "attn":
                q, k, v = L.attention_qkv(lp["attn"], xn, positions, cfg.rope_theta)
                o = L.flash_attention(q, k, v, causal=True)
                x = x + L.attention_out(lp["attn"], o)
                ck = jax.lax.dynamic_update_slice_in_dim(
                    xs["cache_k"][ia], k.astype(xs["cache_k"].dtype), 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    xs["cache_v"][ia], v.astype(xs["cache_v"].dtype), 0, axis=1)
                kv_out.append((ck, cv))
                ia += 1
            else:
                y, st = M.mamba_forward_with_state(lp["mamba"], xn, cfg)
                x = x + y
                m_out.append(st)
                im += 1
            xn2 = L.rms_norm(x, lp["norm2"] + 1.0, cfg.norm_eps)
            if role.moe:
                y, a = MOE.moe_forward(lp["ffn"], xn2, cfg)
                aux = aux + a
            else:
                y = L.mlp_forward(lp["ffn"], xn2, cfg)
            x = x + y
        ys = {}
        if kv_out:
            ys["cache_k"] = jnp.stack([k for k, _ in kv_out])
            ys["cache_v"] = jnp.stack([v for _, v in kv_out])
            ys["freeze"] = xs["freeze"]   # prefill tokens start unfrozen
        if m_out:
            ys["mamba"] = {k: jnp.stack([s[k] for s in m_out])
                           for k in m_out[0]}
        if r_out:
            ys["rwkv"] = {k: jnp.stack([s[k] for s in r_out])
                          for k in r_out[0]}
        return (x, aux), ys

    if remat:
        body = jax.checkpoint(body)
    xs_all = dict(xs_state, params=params["blocks"])
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs_all)
    new_state = _merge_ys(state, ys, cfg)
    xl = L.rms_norm(x[:, -1], params["final_norm"] + 1.0, cfg.norm_eps)
    return unembed(params, cfg, xl), new_state


def lm_prefill_chunk(params, cfg: ModelConfig, tokens: jnp.ndarray,
                     state: DecodeState, pos0: jnp.ndarray,
                     ) -> Tuple[jnp.ndarray, DecodeState]:
    """Chunked prefill: process `tokens` (B, C) at global positions
    pos0 .. pos0+C-1, attending over the already-written cache prefix plus
    causally within the chunk, and write the chunk's K/V into the
    contiguous cache at pos0.

    Returns (chunk-final logits (B, V), updated DecodeState).  Limited to
    attention-only stacks (mamba/rwkv recurrence would need cross-chunk
    state threading); the PagedContinuousEngine admits long prompts with
    this, one chunk per engine step, interleaved with decode steps of the
    resident lanes — a 4k-token admission no longer head-of-line-blocks
    the batch."""
    roles = unit_roles(cfg)
    assert all(r.kind == "attn" for r in roles), \
        "chunked prefill requires an attention-only stack"
    B, C = tokens.shape
    pos0 = jnp.asarray(pos0, jnp.int32)
    x = embed(params, cfg, tokens, None)
    positions = pos0 + jnp.arange(C)
    xs_state = _split_xs(state, cfg)

    def body(carry, xs):
        x, aux = carry
        up = xs["params"]
        ia = 0
        kv_out = []
        for i, role in enumerate(roles):
            lp = up[f"l{i}"]
            xn = L.rms_norm(x, lp["norm1"] + 1.0, cfg.norm_eps)
            q, k, v = L.attention_qkv(lp["attn"], xn, positions,
                                      cfg.rope_theta)
            ck = jax.lax.dynamic_update_slice_in_dim(
                xs["cache_k"][ia], k.astype(xs["cache_k"].dtype), pos0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                xs["cache_v"][ia], v.astype(xs["cache_v"].dtype), pos0, axis=1)
            # causal + q_offset: the chunk sees the whole written prefix and
            # itself causally; unwritten cache slots are masked by causality
            o = L.flash_attention(q, ck, cv, causal=True, q_offset=pos0)
            x = x + L.attention_out(lp["attn"], o)
            kv_out.append((ck, cv))
            ia += 1
            xn2 = L.rms_norm(x, lp["norm2"] + 1.0, cfg.norm_eps)
            if role.moe:
                y, a = MOE.moe_forward(lp["ffn"], xn2, cfg)
                aux = aux + a
            else:
                y = L.mlp_forward(lp["ffn"], xn2, cfg)
            x = x + y
        ys = {
            "cache_k": jnp.stack([k for k, _ in kv_out]),
            "cache_v": jnp.stack([v for _, v in kv_out]),
            "freeze": xs["freeze"],   # prefill tokens start unfrozen
        }
        return (x, aux), ys

    xs_all = dict(xs_state, params=params["blocks"])
    (x, _), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs_all)
    new_state = _merge_ys(state, ys, cfg)
    xl = L.rms_norm(x[:, -1], params["final_norm"] + 1.0, cfg.norm_eps)
    return unembed(params, cfg, xl), new_state


# --------------------------------------------------------------------- #
# Decode step (contiguous cache + ASR-KF-EGR)
# --------------------------------------------------------------------- #
def lm_decode_step(
    params, cfg: ModelConfig,
    token: jnp.ndarray,            # (B,) int32
    pos: jnp.ndarray,              # () or (B,) int32 — slot for this token
    step: jnp.ndarray,             # () or (B,) int32 — decode step counter
    state: DecodeState,
    freeze_cfg: Optional[FreezeConfig] = None,
    enable_freeze: bool = True,
) -> Tuple[jnp.ndarray, DecodeState, Dict[str, jnp.ndarray]]:
    """One ASR-KF-EGR decode step (Algorithm 1 + recovery).

    `pos`/`step` may be per-lane (B,) vectors — continuous batching runs
    every lane at its own position and decode-step counter; scalar values
    keep the single-request lockstep path (and its slice-write fast path).

    Returns (logits (B, V), new state, info)."""
    fcfg = freeze_cfg or cfg.freeze
    roles = unit_roles(cfg)
    B = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    step = jnp.asarray(step, jnp.int32)
    per_lane = pos.ndim == 1
    Smax = state.cache_k.shape[2] if state.cache_k.size else 0
    x = embed(params, cfg, token[:, None], None)[:, 0]          # (B, D)
    if cfg.decode_act_gather:
        # H2: batch-replicated, feature-sharded (over fsdp axes) decode
        # activations — 2-D-sharded weights contract locally and never move
        x = L.dag(x, cfg, ".f")
    positions = pos[:, None] if per_lane else jnp.full((B, 1), pos)
    pos_col = pos[:, None] if per_lane else pos
    xs_state = _split_xs(state, cfg)

    def body(carry, xs):
        x, act_sum, act_cnt = carry
        up = xs["params"]
        ia = im = 0
        ys: Dict[str, Any] = {}
        kv_k, kv_v, fz_out, m_out, r_out = [], [], [], [], []
        for i, role in enumerate(roles):
            lp = up[f"l{i}"]
            if role.kind == "rwkv":
                st = {k: v[0] for k, v in xs["rwkv"].items()}
                x, st = R.rwkv_decode(lp, x, st, cfg, cfg.norm_eps)
                r_out.append(st)
                continue
            xn = L.rms_norm(x, lp["norm1"] + 1.0, cfg.norm_eps)
            if role.kind == "attn":
                q, k, v = L.attention_qkv(
                    lp["attn"], xn[:, None], positions, cfg.rope_theta)
                q, k, v = q[:, 0], k[:, 0], v[:, 0]             # (B,H/KVH,hd)
                ck, cv = xs["cache_k"][ia], xs["cache_v"][ia]
                if per_lane:
                    lanes = jnp.arange(B)
                    ck = ck.at[lanes, pos].set(k.astype(ck.dtype))
                    cv = cv.at[lanes, pos].set(v.astype(cv.dtype))
                else:
                    ck = jax.lax.dynamic_update_slice_in_dim(
                        ck, k.astype(ck.dtype)[:, None], pos, axis=1)
                    cv = jax.lax.dynamic_update_slice_in_dim(
                        cv, v.astype(cv.dtype)[:, None], pos, axis=1)
                fz = FreezeState(*(a[ia] for a in xs["freeze"]))
                idx = jnp.arange(Smax)[None, :]
                amask = (idx <= pos_col) & ~fz.frozen
                o, rel = L.decode_attention(q, ck, cv, amask)
                if cfg.decode_act_gather:
                    o = L.dag(o, cfg, ".m.")
                x = x + L.dag(L.attention_out(lp["attn"], o), cfg, ".f") \
                    if cfg.decode_act_gather else x + L.attention_out(lp["attn"], o)
                if enable_freeze:
                    fz, finfo = freeze_update(fz, rel, pos, step, fcfg)
                    act_sum = act_sum + jnp.sum(finfo["n_active"])
                    act_cnt = act_cnt + B
                kv_k.append(ck); kv_v.append(cv); fz_out.append(fz)
                ia += 1
            else:
                st = {k: v[im] for k, v in xs["mamba"].items()}
                y, st = M.mamba_decode(lp["mamba"], xn, st, cfg)
                x = x + y
                m_out.append(st)
                im += 1
            xn2 = L.rms_norm(x, lp["norm2"] + 1.0, cfg.norm_eps)
            if role.moe:
                y, _ = MOE.moe_forward(lp["ffn"], xn2[:, None], cfg)
                y = y[:, 0]
            else:
                y = L.mlp_forward(lp["ffn"], xn2, cfg)
            x = x + y
        if kv_k:
            ys["cache_k"] = jnp.stack(kv_k)
            ys["cache_v"] = jnp.stack(kv_v)
            ys["freeze"] = FreezeState(
                *(jnp.stack(parts) for parts in zip(*fz_out)))
        if m_out:
            ys["mamba"] = {k: jnp.stack([s[k] for s in m_out]) for k in m_out[0]}
        if r_out:
            ys["rwkv"] = {k: jnp.stack([s[k] for s in r_out]) for k in r_out[0]}
        return (x, act_sum, act_cnt), ys

    xs_all = dict(xs_state, params=params["blocks"])
    (x, act_sum, act_cnt), ys = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        xs_all)
    new_state = _merge_ys(state, ys, cfg)
    x = L.rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    logits = unembed(params, cfg, x)

    info: Dict[str, jnp.ndarray] = {
        "mean_active": act_sum / jnp.maximum(act_cnt, 1.0),
    }
    # ---- entropy-guided recovery over the stacked freeze state ---- #
    if enable_freeze and attn_layer_count(cfg) and fcfg.recovery_enabled:
        rec, fz, rinfo = recovery_update(
            new_state.recovery, new_state.freeze, logits, step, fcfg)
        new_state = new_state._replace(recovery=rec, freeze=fz)
        info.update(rinfo)
    if attn_layer_count(cfg):
        exists = jnp.arange(Smax)[None, None, :] <= \
            (pos[None, :, None] if per_lane else pos)
        info["n_frozen"] = jnp.sum(new_state.freeze.frozen & exists,
                                   axis=(0, 2))   # (B,) summed over layers
        info["n_active"] = jnp.sum(~new_state.freeze.frozen & exists,
                                   axis=(0, 2))
    return logits, new_state, info


# --------------------------------------------------------------------- #
# Paged decode step (bounded-active pool — long-context mode)
# --------------------------------------------------------------------- #
class PagedDecodeState(NamedTuple):
    k: jnp.ndarray            # (L_attn, B, P, page, KVH, hd)
    v: jnp.ndarray
    page_table: jnp.ndarray   # (L_attn, B, P)
    slot_mask: jnp.ndarray    # (L_attn, B, P, page)
    freeze: PageFreezeState   # arrays (L_attn, B, P)
    mamba: Dict[str, jnp.ndarray]
    rwkv: Dict[str, jnp.ndarray]
    recovery: RecoveryState
    # per-page quantization slots (core/quant.py): flag != 0 means the pool
    # holds an integer-valued 1-byte payload cast into the pool dtype, and
    # attention dequantizes in-kernel by kv_scales (axis -2: 0 = K, 1 = V).
    # Host-mutated only (freeze-time quantize, thaw/rewind dequantize) —
    # the jitted step reads them and never writes them back.
    page_quant: jnp.ndarray   # (L_attn, B, P) i32
    kv_scales: jnp.ndarray    # (L_attn, B, P, 2, KVH) f32


def init_paged_decode_state(cfg: ModelConfig, batch: int,
                            max_active_pages: int,
                            staging_slots: int = 0) -> PagedDecodeState:
    """``staging_slots`` extra physical slots per lane are allocated beyond
    ``max_active_pages`` for the async DMA pipeline's speculative-thaw
    staging: they stay unmapped (page table -1 — attention and the freeze
    schedule skip them) until the host remaps a staged page in place.  The
    jitted step must then be given ``reserved_slots=staging_slots`` so the
    forced-freeze headroom math treats the pool as ``max_active_pages``
    usable slots (see ``core.paging.page_freeze_update``)."""
    from repro.core.paging import init_page_freeze_state
    from repro.core.recovery import init_recovery_state
    dt = jnp.dtype(cfg.dtype)
    la = max(attn_layer_count(cfg), 1)
    lm = mamba_layer_count(cfg)
    P, page = max_active_pages + staging_slots, cfg.freeze.page_size
    kvh, hd = max(cfg.num_kv_heads, 1), cfg.head_dim
    di = cfg.mamba_expand * cfg.d_model
    fz = init_page_freeze_state(batch, P)
    fz = PageFreezeState(*(jnp.broadcast_to(a, (la,) + a.shape) for a in fz))
    mamba = {
        "conv": jnp.zeros((lm, batch, cfg.mamba_d_conv - 1, di), dt),
        "ssm": jnp.zeros((lm, batch, di, cfg.mamba_d_state), jnp.float32),
    } if lm else {}
    rwkv = {}
    if cfg.arch_type == "ssm":
        hdr = cfg.rwkv_head_dim
        h = cfg.d_model // hdr
        rwkv = {
            "tm_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            "cm_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            "wkv": jnp.zeros((cfg.num_layers, batch, h, hdr, hdr), jnp.float32),
        }
    return PagedDecodeState(
        k=jnp.zeros((la, batch, P, page, kvh, hd), dt),
        v=jnp.zeros((la, batch, P, page, kvh, hd), dt),
        page_table=jnp.full((la, batch, P), -1, jnp.int32),
        slot_mask=jnp.zeros((la, batch, P, page), bool),
        freeze=fz,
        mamba=mamba,
        rwkv=rwkv,
        recovery=init_recovery_state(batch),
        page_quant=jnp.zeros((la, batch, P), jnp.int32),
        kv_scales=jnp.ones((la, batch, P, 2, kvh), jnp.float32),
    )


def reset_paged_lane(state: PagedDecodeState, lane) -> PagedDecodeState:
    """Lane-granular paged reset: unmap one lane's pages (page table -> -1,
    slot masks cleared, freeze counters zeroed) so a retired request's pool
    is skipped by attention and never churns the host controller.  K/V
    payloads stay in place — unmapped slots are invisible, and admission
    overwrites them wholesale."""
    from repro.core.recovery import init_recovery_state
    B = state.page_table.shape[1]
    sel = (jnp.arange(B) == jnp.asarray(lane)).reshape(1, -1, 1)
    zero = lambda a: jnp.where(sel, jnp.zeros((), a.dtype), a)
    rec0 = init_recovery_state(B)
    sel_b = jnp.arange(B) == jnp.asarray(lane)
    return state._replace(
        page_table=jnp.where(sel, -1, state.page_table),
        slot_mask=state.slot_mask & ~sel[..., None],
        freeze=PageFreezeState(
            c=zero(state.freeze.c), d=zero(state.freeze.d),
            frozen=state.freeze.frozen & ~sel,
            frozen_at=jnp.where(sel, -1, state.freeze.frozen_at)),
        recovery=RecoveryState(*(jnp.where(sel_b, z.astype(a.dtype), a)
                                 for a, z in zip(state.recovery, rec0))),
        page_quant=jnp.where(sel, 0, state.page_quant),
        kv_scales=jnp.where(sel[..., None, None], 1.0, state.kv_scales),
    )


def rewind_paged_lane(state: PagedDecodeState, lane, new_pos,
                      page: int) -> PagedDecodeState:
    """Page-aware Rewalk Regeneration rewind for ONE lane: tokens at
    positions >= ``new_pos`` are discarded, so their KV slots must become
    invisible and writable again.

    * Slots holding positions >= new_pos have their slot-mask bits cleared
      (regenerated tokens overwrite them in place).
    * Pages that become wholly invalid (``gid * page >= new_pos`` — every
      slot past the rewind point) are unmapped; when the rewind lands
      exactly on a page boundary this includes the new tail page itself,
      and the next step's page-boundary maintenance re-allocates it.
    * The surviving tail page (``gid == new_pos // page`` when the rewind
      lands mid-page) is un-frozen with its timer cleared — regeneration
      must attend and append to it immediately.

    The host side (``PagedController.ensure_resident`` + tail-slot fixup)
    runs in the serving engine; ``page`` is static (``fcfg.page_size``).
    """
    B = state.page_table.shape[1]
    sel = (jnp.arange(B) == jnp.asarray(lane)).reshape(1, -1, 1)   # (1,B,1)
    new_pos = jnp.asarray(new_pos, jnp.int32)
    pt = state.page_table
    mapped = pt >= 0
    # global position of every (page, offset) slot
    gpos = pt[..., None] * page + jnp.arange(page)                 # (L,B,P,pg)
    keep = gpos < new_pos
    slot_mask = jnp.where(sel[..., None] & mapped[..., None],
                          state.slot_mask & keep, state.slot_mask)
    dead = sel & mapped & (pt * page >= new_pos)
    pt_new = jnp.where(dead, -1, pt)
    slot_mask = slot_mask & ~dead[..., None]
    tail_hit = sel & (pt_new == new_pos // page)
    fz = state.freeze
    fz = PageFreezeState(
        c=jnp.where(dead, 0, fz.c),
        d=jnp.where(dead | tail_hit, 0, fz.d),
        frozen=fz.frozen & ~(dead | tail_hit),
        frozen_at=jnp.where(dead | tail_hit, -1, fz.frozen_at),
    )
    # dead pages lose their quant flags/scales with their mapping; the
    # surviving tail page's flag is left alone — the host dequantizes it
    # (``ensure_resident``) and pushes the cleared flag before this jitted
    # rewind runs, and boundary-landing rewinds never touch the tail.
    return state._replace(
        page_table=pt_new, slot_mask=slot_mask, freeze=fz,
        page_quant=jnp.where(dead, 0, state.page_quant),
        kv_scales=jnp.where(dead[..., None, None], 1.0, state.kv_scales))


def lm_decode_step_paged(
    params, cfg: ModelConfig,
    token: jnp.ndarray,           # (B,)
    pos: jnp.ndarray,             # () or (B,) global position of the new token
    step: jnp.ndarray,            # () or (B,) per-lane decode clock
    tail_slot: jnp.ndarray,       # (), (L_attn,) or (L_attn, B) tail slot
    state: PagedDecodeState,
    freeze_cfg: Optional[FreezeConfig] = None,
    live: Optional[jnp.ndarray] = None,   # (B,) bool; False lanes don't write
    enable_freeze: bool = True,
    reserved_slots: int = 0,
) -> Tuple[jnp.ndarray, PagedDecodeState, Dict[str, jnp.ndarray]]:
    """Bounded-active decode: attention sees only the device-resident page
    pool; page-granular freeze feeds the host PagedController.

    `pos` / `step` may be per-lane (B,) vectors and `tail_slot` a per-layer,
    per-lane (L_attn, B) table — continuous batching runs every lane at its
    own position, decode clock and tail page.  `live=False` lanes (idle or
    mid-admission) skip the tail write so their pool never grows garbage.
    `reserved_slots` (static) is the per-lane count of speculative-thaw
    staging slots the host keeps unmapped: attention already skips them
    (page table -1), and the freeze schedule's forced-freeze headroom
    subtracts them so a P + S pool with S reserved is step-for-step
    identical to a plain P pool."""
    fcfg = freeze_cfg or cfg.freeze
    roles = unit_roles(cfg)
    B = token.shape[0]
    page = fcfg.page_size
    pos = jnp.asarray(pos, jnp.int32)
    step = jnp.asarray(step, jnp.int32)
    per_lane = pos.ndim == 1
    x = embed(params, cfg, token[:, None], None)[:, 0]
    if cfg.decode_act_gather:
        # H2: batch-replicated, feature-sharded decode activations
        x = L.dag(x, cfg, ".f")
    positions = pos[:, None] if per_lane else jnp.full((B, 1), pos)
    tail_off = pos % page                 # () or (B,)
    current_page = pos // page

    n = num_units(cfg)
    ia_n = sum(1 for r in roles if r.kind == "attn")
    im_n = sum(1 for r in roles if r.kind == "mamba")
    tail_slot = jnp.asarray(tail_slot, jnp.int32)
    if tail_slot.ndim == 1:               # (L_attn,) shared across lanes
        tail_slot = tail_slot[:, None]
    tail_slot = jnp.broadcast_to(tail_slot, (max(n * ia_n, 1), B))
    xs = {"params": params["blocks"]}
    if ia_n:
        rs = lambda a: a.reshape((n, ia_n) + a.shape[1:])
        xs.update(k=rs(state.k), v=rs(state.v),
                  page_table=rs(state.page_table),
                  slot_mask=rs(state.slot_mask),
                  tail_slot=tail_slot.reshape(n, ia_n, B),
                  freeze=PageFreezeState(*(rs(a) for a in state.freeze)),
                  page_quant=rs(state.page_quant),
                  kv_scales=rs(state.kv_scales))
    if im_n:
        xs["mamba"] = {kk: vv.reshape((n, im_n) + vv.shape[1:])
                       for kk, vv in state.mamba.items()}
    if cfg.arch_type == "ssm":
        xs["rwkv"] = {kk: vv.reshape((n, 1) + vv.shape[1:])
                      for kk, vv in state.rwkv.items()}

    def body(carry, xs_u):
        x, nfro = carry
        up = xs_u["params"]
        ia = im = 0
        ys: Dict[str, Any] = {}
        outs = {kk: [] for kk in ("k", "v", "slot_mask")}
        fz_out, m_out, r_out = [], [], []
        for i, role in enumerate(roles):
            lp = up[f"l{i}"]
            if role.kind == "rwkv":
                st = {kk: vv[0] for kk, vv in xs_u["rwkv"].items()}
                x, st = R.rwkv_decode(lp, x, st, cfg, cfg.norm_eps)
                r_out.append(st)
                continue
            xn = L.rms_norm(x, lp["norm1"] + 1.0, cfg.norm_eps)
            if role.kind == "attn":
                q, k, v = L.attention_qkv(
                    lp["attn"], xn[:, None], positions, cfg.rope_theta)
                q, k, v = q[:, 0], k[:, 0], v[:, 0]
                kp, vp = xs_u["k"][ia], xs_u["v"][ia]
                sm = xs_u["slot_mask"][ia]
                kp, vp, sm = write_tail(kp, vp, sm, k.astype(kp.dtype),
                                        v.astype(vp.dtype),
                                        xs_u["tail_slot"][ia], tail_off,
                                        live=live)
                fz = PageFreezeState(*(a[ia] for a in xs_u["freeze"]))
                # kernels.ops dispatch: Pallas paged kernel on TPU (unmapped
                # slots and invisible pages skipped via the two prefetched
                # per-lane tables), pure-jnp reference elsewhere.  The
                # visibility mask is thaw-aware: a page the recovery ladder
                # un-froze last step re-enters attention AND relevance
                # accounting here.
                o, prel = OPS.paged_decode_attention(
                    q, kp, vp, sm, xs_u["page_table"][ia], ~fz.frozen,
                    xs_u["page_quant"][ia], xs_u["kv_scales"][ia])
                if cfg.decode_act_gather:
                    o = L.dag(o, cfg, ".m.")
                x = x + L.dag(L.attention_out(lp["attn"], o), cfg, ".f") \
                    if cfg.decode_act_gather else x + L.attention_out(lp["attn"], o)
                if enable_freeze:
                    fz, finfo = page_freeze_update(
                        fz, prel, xs_u["page_table"][ia], current_page, step,
                        fcfg, reserved_slots=reserved_slots)
                    nfro = nfro + jnp.sum(finfo["n_frozen"])
                outs["k"].append(kp); outs["v"].append(vp)
                outs["slot_mask"].append(sm); fz_out.append(fz)
                ia += 1
            else:
                st = {kk: vv[im] for kk, vv in xs_u["mamba"].items()}
                y, st = M.mamba_decode(lp["mamba"], xn, st, cfg)
                x = x + y
                m_out.append(st)
                im += 1
            xn2 = L.rms_norm(x, lp["norm2"] + 1.0, cfg.norm_eps)
            if role.moe:
                y, _ = MOE.moe_forward(lp["ffn"], xn2[:, None], cfg)
                y = y[:, 0]
            else:
                y = L.mlp_forward(lp["ffn"], xn2, cfg)
            x = x + y
        if fz_out:
            for kk in ("k", "v", "slot_mask"):
                ys[kk] = jnp.stack(outs[kk])
            ys["page_table"] = xs_u["page_table"]
            ys["freeze"] = PageFreezeState(
                *(jnp.stack(parts) for parts in zip(*fz_out)))
        if m_out:
            ys["mamba"] = {kk: jnp.stack([s[kk] for s in m_out])
                           for kk in m_out[0]}
        if r_out:
            ys["rwkv"] = {kk: jnp.stack([s[kk] for s in r_out])
                          for kk in r_out[0]}
        return (x, nfro), ys

    (x, nfro), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32))
, xs)
    flat = lambda a: a.reshape((-1,) + a.shape[2:])
    new_state = state
    if "k" in ys:
        new_state = new_state._replace(
            k=flat(ys["k"]), v=flat(ys["v"]), slot_mask=flat(ys["slot_mask"]),
            freeze=PageFreezeState(*(flat(a) for a in ys["freeze"])))
    if "mamba" in ys:
        new_state = new_state._replace(
            mamba={kk: flat(vv) for kk, vv in ys["mamba"].items()})
    if "rwkv" in ys:
        new_state = new_state._replace(
            rwkv={kk: flat(vv) for kk, vv in ys["rwkv"].items()})
    x = L.rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    logits = unembed(params, cfg, x)
    info: Dict[str, jnp.ndarray] = {"n_frozen_pages": nfro}
    # ---- entropy-guided recovery over the stacked page-freeze state ---- #
    # (in-step interventions un-freeze resident pages; thaw_request /
    # rr_request ask the host for stashed-page thaws and page-aware
    # rewinds — see core/recovery.py and serving/engine.py)
    if enable_freeze and attn_layer_count(cfg) and fcfg.recovery_enabled:
        rec, pfz, rinfo = page_recovery_update(
            new_state.recovery, new_state.freeze, new_state.page_table,
            logits, step, fcfg)
        new_state = new_state._replace(recovery=rec, freeze=pfz)
        info.update(rinfo)
    if attn_layer_count(cfg):
        exists = new_state.page_table >= 0                 # (L, B, P)
        frozen = new_state.freeze.frozen & exists
        visible = new_state.slot_mask & ~new_state.freeze.frozen[..., None]
        # per-lane counts, summed over layers (host divides by L_attn)
        info["n_frozen_pages_lane"] = jnp.sum(frozen, axis=(0, 2))
        info["n_active_pages_lane"] = jnp.sum(exists & ~frozen, axis=(0, 2))
        info["n_active_slots_lane"] = jnp.sum(visible, axis=(0, 2, 3))
    return logits, new_state, info

"""Whisper-style encoder-decoder (audio).  The mel-spectrogram + conv
feature extractor is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, frames, d_model) straight into the encoder.

ASR-KF-EGR applies to the decoder **self-attention** KV cache; the
cross-attention KV (encoder output projections) is static and never frozen
(DESIGN.md §6).  Norms are RMS for uniformity with the rest of the zoo.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FreezeConfig, ModelConfig
from repro.core.freeze import FreezeState, freeze_update, init_freeze_state
from repro.core.recovery import RecoveryState, init_recovery_state, recovery_update
from repro.models import layers as L
from repro.models.layers import ParamSpec


def _enc_layer_schema(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "norm1": ParamSpec((cfg.d_model,), (None,), scale=0.0),
        "attn": L.attention_schema(cfg),
        "norm2": ParamSpec((cfg.d_model,), (None,), scale=0.0),
        "ffn": L.mlp_schema(cfg, act="gelu"),
    }


def _dec_layer_schema(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "norm1": ParamSpec((cfg.d_model,), (None,), scale=0.0),
        "self_attn": L.attention_schema(cfg),
        "norm_x": ParamSpec((cfg.d_model,), (None,), scale=0.0),
        "cross_attn": L.attention_schema(cfg),
        "norm2": ParamSpec((cfg.d_model,), (None,), scale=0.0),
        "ffn": L.mlp_schema(cfg, act="gelu"),
    }


def schema(cfg: ModelConfig) -> Dict[str, Any]:
    vp, d = cfg.padded_vocab, cfg.d_model
    return {
        "embed": ParamSpec((vp, d), ("vocab", "embed")),
        "unembed": ParamSpec((d, vp), ("embed", "vocab")),
        "enc_pos": ParamSpec((cfg.encoder_frames, d), (None, "embed"), scale=0.02),
        "encoder": L.stack_schema(_enc_layer_schema(cfg), cfg.encoder_layers),
        "enc_norm": ParamSpec((d,), (None,), scale=0.0),
        "decoder": L.stack_schema(_dec_layer_schema(cfg), cfg.num_layers),
        "final_norm": ParamSpec((d,), (None,), scale=0.0),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    return L.init_from_schema(key, schema(cfg), jnp.dtype(cfg.dtype))


# --------------------------------------------------------------------- #
def encode(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, F, D) stub conv-frontend output -> encoder states."""
    x = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][None]

    def body(x, lp):
        xn = L.rms_norm(x, lp["norm1"] + 1.0, cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], xn, None, None)
        o = L.flash_attention(q, k, v, causal=False)
        x = x + L.attention_out(lp["attn"], o)
        xn2 = L.rms_norm(x, lp["norm2"] + 1.0, cfg.norm_eps)
        return x + L.mlp_forward(lp["ffn"], xn2), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rms_norm(x, params["enc_norm"] + 1.0, cfg.norm_eps)


class WhisperState(NamedTuple):
    cache_k: jnp.ndarray     # (L, B, S, KVH, hd) decoder self-attn
    cache_v: jnp.ndarray
    cross_k: jnp.ndarray     # (L, B, F, KVH, hd) static
    cross_v: jnp.ndarray
    freeze: FreezeState      # (L, B, S)
    recovery: RecoveryState


def init_state(cfg: ModelConfig, batch: int, max_seq: int) -> WhisperState:
    dt = jnp.dtype(cfg.dtype)
    Ld = cfg.num_layers
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    fz = init_freeze_state(batch, max_seq)
    fz = FreezeState(*(jnp.broadcast_to(a, (Ld,) + a.shape) for a in fz))
    return WhisperState(
        cache_k=jnp.zeros((Ld, batch, max_seq, kvh, hd), dt),
        cache_v=jnp.zeros((Ld, batch, max_seq, kvh, hd), dt),
        cross_k=jnp.zeros((Ld, batch, cfg.encoder_frames, kvh, hd), dt),
        cross_v=jnp.zeros((Ld, batch, cfg.encoder_frames, kvh, hd), dt),
        freeze=fz,
        recovery=init_recovery_state(batch),
    )


def _dec_positions(tokens_or_pos, d):
    return L.sinusoidal_positions(tokens_or_pos, d)


def decoder_prefill(
    params, cfg: ModelConfig, tokens: jnp.ndarray, enc_out: jnp.ndarray,
    state: WhisperState,
) -> Tuple[jnp.ndarray, WhisperState]:
    """Returns (last-token logits, state with self+cross caches filled)."""
    B, S = tokens.shape
    d = cfg.d_model
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + _dec_positions(jnp.arange(S), d)[None].astype(x.dtype)

    def body(x, xs):
        lp, ck0, cv0 = xs["p"], xs["ck"], xs["cv"]
        xn = L.rms_norm(x, lp["norm1"] + 1.0, cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["self_attn"], xn, None, None)
        o = L.flash_attention(q, k, v, causal=True)
        x = x + L.attention_out(lp["self_attn"], o)
        ck = jax.lax.dynamic_update_slice_in_dim(ck0, k.astype(ck0.dtype), 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv0, v.astype(cv0.dtype), 0, axis=1)
        # cross attention (compute + cache encoder K/V)
        xn = L.rms_norm(x, lp["norm_x"] + 1.0, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", xn, lp["cross_attn"]["wq"])
        kx = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["cross_attn"]["wk"])
        vx = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["cross_attn"]["wv"])
        ox = L.flash_attention(qx, kx, vx, causal=False)
        x = x + L.attention_out(lp["cross_attn"], ox)
        xn2 = L.rms_norm(x, lp["norm2"] + 1.0, cfg.norm_eps)
        x = x + L.mlp_forward(lp["ffn"], xn2)
        return x, {"ck": ck, "cv": cv,
                   "xk": kx.astype(ck0.dtype), "xv": vx.astype(cv0.dtype)}

    xs = {"p": params["decoder"], "ck": state.cache_k, "cv": state.cache_v}
    x, ys = jax.lax.scan(body, x, xs)
    state = state._replace(cache_k=ys["ck"], cache_v=ys["cv"],
                           cross_k=ys["xk"], cross_v=ys["xv"])
    xl = L.rms_norm(x[:, -1], params["final_norm"] + 1.0, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", xl, params["unembed"])
    vp = cfg.padded_vocab
    if vp != cfg.vocab_size:
        logits = logits + jnp.where(jnp.arange(vp) < cfg.vocab_size, 0.0, -1e30)
    return logits, state


def decode_step(
    params, cfg: ModelConfig,
    token: jnp.ndarray, pos: jnp.ndarray, step: jnp.ndarray,
    state: WhisperState,
    freeze_cfg: Optional[FreezeConfig] = None,
    enable_freeze: bool = True,
) -> Tuple[jnp.ndarray, WhisperState, Dict[str, jnp.ndarray]]:
    fcfg = freeze_cfg or cfg.freeze
    B = token.shape[0]
    Smax = state.cache_k.shape[2]
    d = cfg.d_model
    x = jnp.take(params["embed"], token, axis=0)
    x = x + _dec_positions(pos[None], d).astype(x.dtype)

    def body(carry, xs):
        x, act = carry
        lp = xs["p"]
        xn = L.rms_norm(x, lp["norm1"] + 1.0, cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["self_attn"], xn[:, None], None, None)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        ck = jax.lax.dynamic_update_slice_in_dim(
            xs["ck"], k.astype(xs["ck"].dtype)[:, None], pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            xs["cv"], v.astype(xs["cv"].dtype)[:, None], pos, axis=1)
        fz = FreezeState(*xs["freeze"])
        idx = jnp.arange(Smax)[None, :]
        amask = (idx <= pos) & ~fz.frozen
        o, rel = L.decode_attention(q, ck, cv, amask)
        x = x + L.attention_out(lp["self_attn"], o)
        if enable_freeze:
            fz, finfo = freeze_update(fz, rel, pos, step, fcfg)
            act = act + jnp.sum(finfo["n_active"])
        # cross attention over static encoder KV (never frozen)
        xn = L.rms_norm(x, lp["norm_x"] + 1.0, cfg.norm_eps)
        qx = jnp.einsum("bd,dhk->bhk", xn, lp["cross_attn"]["wq"])
        full = jnp.ones(xs["xk"].shape[:2], bool)
        ox, _ = L.decode_attention(qx, xs["xk"], xs["xv"], full)
        x = x + L.attention_out(lp["cross_attn"], ox)
        xn2 = L.rms_norm(x, lp["norm2"] + 1.0, cfg.norm_eps)
        x = x + L.mlp_forward(lp["ffn"], xn2)
        return (x, act), {"ck": ck, "cv": cv, "freeze": tuple(fz)}

    xs = {"p": params["decoder"], "ck": state.cache_k, "cv": state.cache_v,
          "xk": state.cross_k, "xv": state.cross_v,
          "freeze": tuple(state.freeze)}
    (x, act), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    state = state._replace(cache_k=ys["ck"], cache_v=ys["cv"],
                           freeze=FreezeState(*ys["freeze"]))
    x = L.rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["unembed"])
    vp = cfg.padded_vocab
    if vp != cfg.vocab_size:
        logits = logits + jnp.where(jnp.arange(vp) < cfg.vocab_size, 0.0, -1e30)
    info: Dict[str, jnp.ndarray] = {"mean_active": act / (cfg.num_layers * B)}
    if enable_freeze and fcfg.recovery_enabled:
        rec, fz, rinfo = recovery_update(state.recovery, state.freeze,
                                         logits, step, fcfg)
        state = state._replace(recovery=rec, freeze=fz)
        info.update(rinfo)
    exists = jnp.arange(Smax)[None, None, :] <= pos
    info["n_frozen"] = jnp.sum(state.freeze.frozen & exists, axis=(0, 2))
    info["n_active"] = jnp.sum(~state.freeze.frozen & exists, axis=(0, 2))
    return logits, state, info


def train_forward(params, cfg: ModelConfig, frames: jnp.ndarray,
                  tokens: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forced enc-dec forward -> decoder logits (B, S, V)."""
    enc = encode(params, cfg, frames)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + _dec_positions(jnp.arange(S), cfg.d_model)[None].astype(x.dtype)

    def body(x, lp):
        xn = L.rms_norm(x, lp["norm1"] + 1.0, cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["self_attn"], xn, None, None)
        o = L.flash_attention(q, k, v, causal=True)
        x = x + L.attention_out(lp["self_attn"], o)
        xn = L.rms_norm(x, lp["norm_x"] + 1.0, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", xn, lp["cross_attn"]["wq"])
        kx = jnp.einsum("bfd,dhk->bfhk", enc, lp["cross_attn"]["wk"])
        vx = jnp.einsum("bfd,dhk->bfhk", enc, lp["cross_attn"]["wv"])
        ox = L.flash_attention(qx, kx, vx, causal=False)
        x = x + L.attention_out(lp["cross_attn"], ox)
        xn2 = L.rms_norm(x, lp["norm2"] + 1.0, cfg.norm_eps)
        return x + L.mlp_forward(lp["ffn"], xn2), None

    body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = L.rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    vp = cfg.padded_vocab
    if vp != cfg.vocab_size:
        logits = logits + jnp.where(jnp.arange(vp) < cfg.vocab_size, 0.0, -1e30)
    return logits

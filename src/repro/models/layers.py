"""Shared model building blocks.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every module is
described once by a *schema*: ``name -> ParamSpec(shape, logical_axes)``.
Init and PartitionSpec derivation both walk the schema, so sharding can never
drift from parameter structure.  Logical axes ("embed", "heads", "ff",
"vocab", "expert", ...) are mapped to physical mesh axes in
``repro.sharding.rules`` with divisibility fallbacks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, one per dim
    scale: float = 1.0                # stddev multiplier over 1/sqrt(fan_in)
    dtype: Optional[str] = None       # override (e.g. f32 for norms / A_log)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_param_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_from_schema(key: jax.Array, schema: PyTree, dtype: jnp.dtype) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=is_param_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        d = jnp.dtype(spec.dtype) if spec.dtype else dtype
        if len(spec.shape) == 0 or spec.scale == 0.0:
            out.append(jnp.zeros(spec.shape, d))
            continue
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / math.sqrt(max(fan_in, 1))
        out.append((jax.random.normal(k, spec.shape, jnp.float32) * std).astype(d))
    return jax.tree_util.tree_unflatten(treedef, out)


def ones_like_schema_entry(spec: ParamSpec, dtype) -> jnp.ndarray:
    d = jnp.dtype(spec.dtype) if spec.dtype else dtype
    return jnp.ones(spec.shape, d)


def stack_schema(schema: PyTree, n: int) -> PyTree:
    """Add a leading stacked-layer dim (unsharded) to every ParamSpec."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, (None,) + s.axes, s.scale, s.dtype),
        schema,
        is_leaf=is_param_spec,
    )


# ===================================================================== #
# Activation sharding constraints (§Perf H5)
# ===================================================================== #
def constrain(x: jnp.ndarray, cfg, dims: str) -> jnp.ndarray:
    """Pin an activation's sharding: dims is one char per axis —
    'b' batch (over cfg.act_batch_axes), 'm' model (if divisible), '.' none.
    No-op when cfg.act_batch_axes is unset (baseline mode)."""
    if not getattr(cfg, "act_batch_axes", ()):
        return x
    from jax.sharding import PartitionSpec as P
    bax = cfg.act_batch_axes
    b = bax if len(bax) > 1 else bax[0]
    spec = []
    for d, s in zip(dims, x.shape):
        if d == "b":
            spec.append(b)
        elif d == "m" and cfg.act_model_parts and s % cfg.act_model_parts == 0:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def dag(x: jnp.ndarray, cfg, dims: str) -> jnp.ndarray:
    """Decode-act-gather (§Perf H2) constraint: batch replicated, 'm' dims
    sharded over 'model', 'f' (feature/embed) dims sharded over the fsdp
    axes — so 2-D-sharded weights contract against local activation shards
    and never move.  No-op unless cfg.decode_act_gather."""
    if not getattr(cfg, "decode_act_gather", False) \
            or not getattr(cfg, "act_model_parts", 0):
        return x
    from jax.sharding import PartitionSpec as P
    parts = cfg.act_model_parts
    bax = getattr(cfg, "act_batch_axes", ()) or ("data",)
    f_entry = bax if len(bax) > 1 else bax[0]
    f_parts = parts * (2 if len(bax) > 1 else 1)   # pod axis size is 2
    spec = []
    for d, s in zip(dims, x.shape):
        if d == "m" and s % parts == 0:
            spec.append("model")
        elif d == "f" and s % f_parts == 0:
            spec.append(f_entry)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ===================================================================== #
# Norms
# ===================================================================== #
def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layer_norm(x, gamma, beta, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma + beta


def group_norm_heads(x: jnp.ndarray, gamma: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Per-head group norm for RWKV output. x: (..., H, hd)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


# ===================================================================== #
# RoPE
# ===================================================================== #
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    if angles.ndim == 2:                                       # (S, hd/2)
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ===================================================================== #
# Chunked (flash-style) attention — pure JAX, used on CPU & in dry-runs.
# The Pallas kernels in repro.kernels are the TPU-target equivalents.
# ===================================================================== #
def flash_attention(
    q: jnp.ndarray,                 # (B, Sq, H, hd)
    k: jnp.ndarray,                 # (B, Skv, KVH, hd)
    v: jnp.ndarray,                 # (B, Skv, KVH, hd)
    *,
    causal: bool,
    q_offset: int = 0,              # global position of q[0] (for causal masks)
    kv_mask: Optional[jnp.ndarray] = None,   # (B, Skv) bool; False = masked out
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Numerically-stable chunked attention.  Never materializes the full
    (Sq, Skv) score matrix: outer lax.map over q chunks, inner lax.scan over
    kv chunks with running (max, denom, acc)."""
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nkv = -(-Skv // kv_chunk)
    # pad to multiples
    Sq_p, Skv_p = nq * q_chunk, nkv * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    kvm = jnp.ones((B, Skv), dtype=bool) if kv_mask is None else kv_mask
    kvm = jnp.pad(kvm, ((0, 0), (0, Skv_p - Skv)), constant_values=False)

    # (B, nkv, ckv, KVH, hd)
    kb = kp.reshape(B, nkv, kv_chunk, KVH, hd)
    vb = vp.reshape(B, nkv, kv_chunk, KVH, hd)
    mb = kvm.reshape(B, nkv, kv_chunk)

    def one_q_chunk(qi):
        qc = jax.lax.dynamic_slice_in_dim(qp, qi * q_chunk, q_chunk, axis=1)
        qc = qc.reshape(B, q_chunk, KVH, G, hd)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m_run, l_run, acc = carry
            kc, vc, mc, kv_start = inputs
            kv_pos = kv_start + jnp.arange(kv_chunk)
            # scores: (B, q_chunk, KVH, G, ckv)
            s = jnp.einsum("bqkgh,bckh->bqkgc", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            mask = mc[:, None, None, None, :]
            if causal:
                mask = mask & (kv_pos[None, None, None, None, :]
                               <= q_pos[None, :, None, None, None])
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckh->bqkgh", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_chunk, KVH, G), -1e30, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, KVH, G), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, KVH, G, hd), jnp.float32)
        kv_starts = jnp.arange(nkv) * kv_chunk
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), mb.swapaxes(0, 1), kv_starts))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(B, q_chunk, H, hd)

    outs = jax.lax.map(one_q_chunk, jnp.arange(nq))            # (nq, B, qc, H, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq_p, H, hd)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,                # (B, H, hd) — single new token
    k_cache: jnp.ndarray,          # (B, S, KVH, hd)
    v_cache: jnp.ndarray,          # (B, S, KVH, hd)
    active_mask: jnp.ndarray,      # (B, S) bool — True = participates
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-step decode attention over a (possibly frozen-masked) cache.

    Returns (out (B,H,hd), relevance (B,S)) where relevance is the paper's
    Eq. 2 score  s_j = (1/H) sum_h |q_h . k_jh|  — fused with the attention
    score computation (no second pass over K).
    """
    B, H, hd = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    qf = q.reshape(B, KVH, G, hd)
    # accumulate in f32 WITHOUT materializing an f32 copy of the cache
    # (preferred_element_type: bf16 reads, f32 MXU accumulation) — §Perf H3
    raw = jnp.einsum("bkgh,bskh->bkgs", qf, k_cache,
                     preferred_element_type=jnp.float32)       # (B,KVH,G,S)
    relevance = jnp.mean(jnp.abs(raw), axis=(1, 2))            # Eq. 2, mean over H
    s = raw / math.sqrt(hd)
    s = jnp.where(active_mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (no active kv) -> zeros, not NaN
    any_active = jnp.any(active_mask, axis=-1)[:, None, None, None]
    p = jnp.where(any_active, p, 0.0)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype), relevance


# ===================================================================== #
# GQA attention module
# ===================================================================== #
def attention_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, kvh, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, kvh, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed")),
    }


def attention_qkv(p, x, positions, theta):
    """x: (B,S,D) -> q (B,S,H,hd), k,v (B,S,KVH,hd) with RoPE applied
    (theta=None skips RoPE, e.g. whisper's learned/sinusoidal positions)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if theta is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def sinusoidal_positions(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Sinusoidal position embeddings. positions: (...,) -> (..., d)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def attention_out(p, o):
    """o: (B,S,H,hd) or (B,H,hd) -> (..., D)."""
    return jnp.einsum("...hk,hkd->...d", o, p["wo"])


# ===================================================================== #
# SwiGLU MLP
# ===================================================================== #
def mlp_schema(cfg: ModelConfig, act: str = "swiglu") -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    s = {
        "w_up": ParamSpec((d, f), ("embed", "ff")),
        "w_down": ParamSpec((f, d), ("ff", "embed")),
    }
    if act == "swiglu":
        s["w_gate"] = ParamSpec((d, f), ("embed", "ff"))
    return s


def mlp_forward(p, x, cfg=None):
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if cfg is not None:
        up = dag(up, cfg, "." * (up.ndim - 1) + "m")
    if "w_gate" in p:
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
        if cfg is not None:
            gate = dag(gate, cfg, "." * (gate.ndim - 1) + "m")
        up = up * jax.nn.silu(gate)
    else:
        up = jax.nn.gelu(up)
    out = jnp.einsum("...f,fd->...d", up, p["w_down"])
    return dag(out, cfg, "." * (out.ndim - 1) + "f") if cfg is not None else out

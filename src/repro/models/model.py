"""Unified model API over the zoo: build once from a ModelConfig, then call
init / train_logits / prefill / decode_step / decode_step_paged regardless of
family.  ``--arch <id>`` selects the config; this module selects the
implementation (decoder-only transformer stack vs whisper enc-dec).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models import whisper as W


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.is_encoder_decoder


def schema(cfg: ModelConfig):
    return W.schema(cfg) if is_encdec(cfg) else T.schema(cfg)


def init_params(key: jax.Array, cfg: ModelConfig):
    return W.init_params(key, cfg) if is_encdec(cfg) else T.init_params(key, cfg)


def train_logits(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                 remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """batch: tokens (B,S) [+ frames (B,F,D) | patch_embeds (B,P,E)].
    Returns (logits (B,S,V), moe aux loss)."""
    if is_encdec(cfg):
        logits = W.train_forward(params, cfg, batch["frames"], batch["tokens"])
        return logits, jnp.zeros((), jnp.float32)
    return T.lm_forward(params, cfg, batch["tokens"],
                        batch.get("patch_embeds"), remat=remat)


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int):
    if is_encdec(cfg):
        return W.init_state(cfg, batch, max_seq)
    return T.init_decode_state(cfg, batch, max_seq)


def prefill(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], state):
    """Returns (last-token logits, filled state)."""
    if is_encdec(cfg):
        enc = W.encode(params, cfg, batch["frames"])
        return W.decoder_prefill(params, cfg, batch["tokens"], enc, state)
    return T.lm_prefill(params, cfg, batch["tokens"], state,
                        batch.get("patch_embeds"))


def decode_step(params, cfg: ModelConfig, token, pos, step, state,
                freeze_cfg=None, enable_freeze: bool = True):
    if is_encdec(cfg):
        return W.decode_step(params, cfg, token, pos, step, state,
                             freeze_cfg, enable_freeze)
    return T.lm_decode_step(params, cfg, token, pos, step, state,
                            freeze_cfg, enable_freeze)


def write_lane_state(cfg: ModelConfig, state, lane_state, lane):
    """Scatter a single-lane (B=1) decode state into batch lane `lane` —
    continuous-batching admission (decoder-only; enc-dec lanes would also
    need their encoder outputs swapped, which static batching handles)."""
    assert not is_encdec(cfg), "continuous batching is decoder-only"
    return T.write_lane_state(state, lane_state, lane)


def prefill_chunk(params, cfg: ModelConfig, tokens, state, pos0):
    """Chunked prefill (attention-only decoder stacks): process one prompt
    chunk at positions pos0.., writing its K/V into the contiguous cache.
    Returns (chunk-final logits, state)."""
    assert not is_encdec(cfg), "chunked prefill is decoder-only"
    return T.lm_prefill_chunk(params, cfg, tokens, state, pos0)


def init_paged_decode_state(cfg: ModelConfig, batch: int,
                            max_active_pages: int, staging_slots: int = 0):
    """`staging_slots` extra unmapped slots per lane hold speculative-thaw
    prefetches (async DMA pipeline); pass the same count to
    `decode_step_paged(reserved_slots=...)`."""
    assert not is_encdec(cfg), "paged long-context mode is decoder-only"
    return T.init_paged_decode_state(cfg, batch, max_active_pages,
                                     staging_slots)


def decode_step_paged(params, cfg: ModelConfig, token, pos, step, tail_slot,
                      state, freeze_cfg=None, live=None,
                      enable_freeze: bool = True, reserved_slots: int = 0):
    return T.lm_decode_step_paged(params, cfg, token, pos, step, tail_slot,
                                  state, freeze_cfg, live, enable_freeze,
                                  reserved_slots)


def reset_paged_lane(cfg: ModelConfig, state, lane):
    """Unmap one lane of a paged decode state (retirement)."""
    return T.reset_paged_lane(state, lane)


def rewind_paged_lane(cfg: ModelConfig, state, lane, new_pos, page: int):
    """Page-aware Rewalk rewind for one lane: invalidate KV slots at
    positions >= new_pos, unmap wholly-invalid pages, un-freeze the
    surviving tail page (entropy-guided recovery level RR)."""
    return T.rewind_paged_lane(state, lane, new_pos, page)

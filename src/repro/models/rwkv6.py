"""RWKV-6 "Finch" block — attention-free, data-dependent decay linear
attention [arXiv:2404.05892].

State per layer is O(1) in sequence length: two token-shift carries plus the
per-head WKV matrix state S in R^{hd x hd}.  ASR-KF-EGR is inapplicable here
(no KV cache) — see DESIGN.md §6; the arch is served without the technique.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, group_norm_heads

_LORA = 64   # rank of the data-dependent decay LoRA


def rwkv_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "tm": {  # time mixing
            "ln": ParamSpec((d,), (None,), scale=0.0),
            # token-shift interpolation factors (static part of ddlerp)
            "mu_x": ParamSpec((d,), (None,), scale=0.0),
            "mu_w": ParamSpec((d,), (None,), scale=0.0),
            "mu_k": ParamSpec((d,), (None,), scale=0.0),
            "mu_v": ParamSpec((d,), (None,), scale=0.0),
            "mu_r": ParamSpec((d,), (None,), scale=0.0),
            "mu_g": ParamSpec((d,), (None,), scale=0.0),
            # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
            "w0": ParamSpec((d,), (None,), scale=0.0, dtype="float32"),
            "wA": ParamSpec((d, _LORA), ("embed", None)),
            "wB": ParamSpec((_LORA, d), (None, "embed")),
            "Wr": ParamSpec((d, d), ("embed", "heads")),
            "Wk": ParamSpec((d, d), ("embed", "heads")),
            "Wv": ParamSpec((d, d), ("embed", "heads")),
            "Wg": ParamSpec((d, d), ("embed", "heads")),
            "Wo": ParamSpec((d, d), ("heads", "embed")),
            "u": ParamSpec((h, hd), (None, None), scale=0.0, dtype="float32"),
            "gn": ParamSpec((h, hd), (None, None), scale=0.0),
        },
        "cm": {  # channel mixing
            "ln": ParamSpec((d,), (None,), scale=0.0),
            "mu_k": ParamSpec((d,), (None,), scale=0.0),
            "mu_r": ParamSpec((d,), (None,), scale=0.0),
            "Wk": ParamSpec((d, f), ("embed", "ff")),
            "Wv": ParamSpec((f, d), ("ff", "embed")),
            "Wr": ParamSpec((d, d), ("embed", "heads")),
        },
    }


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "tm_x": jnp.zeros((batch, d), dtype),
        "cm_x": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _tm_projections(p, x, x_prev, cfg):
    """Shared between forward/decode. x, x_prev: (..., D)."""
    hd = cfg.rwkv_head_dim
    h = cfg.d_model // hd
    r = jnp.einsum("...d,de->...e", _lerp(x, x_prev, p["mu_r"]), p["Wr"])
    k = jnp.einsum("...d,de->...e", _lerp(x, x_prev, p["mu_k"]), p["Wk"])
    v = jnp.einsum("...d,de->...e", _lerp(x, x_prev, p["mu_v"]), p["Wv"])
    g = jnp.einsum("...d,de->...e", _lerp(x, x_prev, p["mu_g"]), p["Wg"])
    xw = _lerp(x, x_prev, p["mu_w"]).astype(jnp.float32)
    w = p["w0"] + jnp.einsum(
        "...r,rd->...d", jnp.tanh(jnp.einsum("...d,dr->...r", xw, p["wA"].astype(jnp.float32))),
        p["wB"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w))                                    # decay in (0,1)
    split = lambda t: t.reshape(*t.shape[:-1], h, hd)
    return split(r), split(k), split(v), g, split(w)


def _wkv_step(S, r, k, v, w, u):
    """S: (B,H,hd,hd); r,k,v,w: (B,H,hd); u: (H,hd) bonus.
    Returns (S_new, y (B,H,hd))."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    kv = kf[..., :, None] * vf[..., None, :]                    # outer(k, v)
    y = jnp.einsum("bhij,bhi->bhj", S + u[None, :, :, None] * kv, rf)
    S_new = wf[..., :, None] * S + kv
    return S_new, y


def _tm_output(p, y, g, cfg, eps):
    y = group_norm_heads(y, 1.0 + p["gn"], eps).astype(g.dtype)
    y = y.reshape(*g.shape[:-1], cfg.d_model) * jax.nn.silu(g)
    return jnp.einsum("...e,ed->...d", y, p["Wo"])


def _cm(p, x, x_prev):
    k = jnp.einsum("...d,df->...f", _lerp(x, x_prev, p["mu_k"]), p["Wk"])
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("...f,fd->...d", k, p["Wv"])
    r = jnp.einsum("...d,de->...e", _lerp(x, x_prev, p["mu_r"]), p["Wr"])
    return jax.nn.sigmoid(r) * v


def _shift(x):
    """Token shift: x_prev[t] = x[t-1], zeros at t=0. x: (B,S,D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def rwkv_forward_with_state(
    p, x: jnp.ndarray, cfg: ModelConfig, eps: float
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence block forward (time-mix + channel-mix, residuals in).
    Also returns the final recurrent state for decode continuation."""
    from repro.models.layers import rms_norm  # RMS for uniformity
    B, S, D = x.shape
    tm, cm = p["tm"], p["cm"]
    xn = rms_norm(x, 1.0 + tm["ln"], eps)
    r, k, v, g, w = _tm_projections(tm, xn, _shift(xn), cfg)

    def step(S_c, inp):
        r_t, k_t, v_t, w_t = inp
        return _wkv_step(S_c, r_t, k_t, v_t, w_t, tm["u"])

    hd = cfg.rwkv_head_dim
    h = D // hd
    S0 = jnp.zeros((B, h, hd, hd), jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, w))
    S_last, ys = jax.lax.scan(step, S0, xs)                     # (S,B,H,hd)
    y = ys.swapaxes(0, 1).astype(x.dtype)
    x = x + _tm_output(tm, y, g, cfg, eps)
    xn2 = rms_norm(x, 1.0 + cm["ln"], eps)
    x = x + _cm(cm, xn2, _shift(xn2))
    state = {"tm_x": xn[:, -1], "cm_x": xn2[:, -1], "wkv": S_last}
    return x, state


def rwkv_forward(p, x: jnp.ndarray, cfg: ModelConfig, eps: float) -> jnp.ndarray:
    return rwkv_forward_with_state(p, x, cfg, eps)[0]


def rwkv_decode(
    p, x: jnp.ndarray, state: Dict[str, jnp.ndarray], cfg: ModelConfig, eps: float
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode. x: (B, D)."""
    from repro.models.layers import rms_norm
    tm, cm = p["tm"], p["cm"]
    xn = rms_norm(x, 1.0 + tm["ln"], eps)
    r, k, v, g, w = _tm_projections(tm, xn, state["tm_x"], cfg)
    S_new, y = _wkv_step(state["wkv"], r, k, v, w, tm["u"])
    x = x + _tm_output(tm, y.astype(x.dtype), g, cfg, eps)
    xn2 = rms_norm(x, 1.0 + cm["ln"], eps)
    x = x + _cm(cm, xn2, state["cm_x"])
    return x, {"tm_x": xn, "cm_x": xn2, "wkv": S_new}

"""Mamba (selective SSM) block — used by the Jamba hybrid architecture.

Prefill/training runs the selective scan over time with lax.scan (the
TPU-friendly formulation; no materialized (S, d_inner, d_state) tensor).
Decode is a single recurrent update over (conv_state, ssm_state) — O(1)
memory in sequence length, which is why hybrid archs run long_500k natively.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec


def mamba_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    r = cfg.dt_rank
    k = cfg.mamba_d_conv
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ff")),
        "conv_w": ParamSpec((k, di), (None, "ff")),
        "conv_b": ParamSpec((di,), ("ff",), scale=0.0),
        "x_proj": ParamSpec((di, r + 2 * n), ("ff", None)),
        "dt_proj": ParamSpec((r, di), (None, "ff")),
        "dt_bias": ParamSpec((di,), ("ff",), scale=0.0, dtype="float32"),
        "A_log": ParamSpec((di, n), ("ff", None), dtype="float32"),
        "D": ParamSpec((di,), ("ff",), scale=0.0, dtype="float32"),
        "out_proj": ParamSpec((di, d), ("ff", "embed")),
    }


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    di = cfg.mamba_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
    }


def _ssm_params(p, xc, cfg):
    """xc: (..., di) post-conv activations -> (dt, B, C) selective params."""
    n, r = cfg.mamba_d_state, cfg.dt_rank
    dbc = jnp.einsum("...i,ij->...j", xc, p["x_proj"])
    dt, Bm, Cm = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jnp.einsum("...r,ri->...i", dt, p["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def mamba_forward_with_state(
    p, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward. x: (B, S, D) -> ((B, S, D), final state)."""
    from repro.models.layers import constrain
    B, S, D = x.shape
    k = cfg.mamba_d_conv
    xz = constrain(jnp.einsum("bsd,de->bse", x, p["in_proj"]), cfg, "b.m")
    xr, z = jnp.split(xz, 2, axis=-1)                           # (B,S,di)
    # causal depthwise conv as sum of shifts (k is tiny)
    xc = jnp.zeros_like(xr)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(xr, ((0, 0), (shift, 0), (0, 0)))[:, :S]
        xc = xc + xi * p["conv_w"][i]
    xc = constrain(jax.nn.silu(xc + p["conv_b"]), cfg, "b.m")
    dt, Bm, Cm = _ssm_params(p, xc, cfg)                        # (B,S,di),(B,S,n)
    dt = constrain(dt, cfg, "b.m")
    Bm = constrain(Bm, cfg, "b..")
    Cm = constrain(Cm, cfg, "b..")
    A = -jnp.exp(p["A_log"])                                    # (di,n)

    def step(h, inp):
        xc_t, dt_t, B_t, C_t = inp                              # (B,di),(B,di),(B,n)
        dA = jnp.exp(dt_t[..., None] * A)                       # (B,di,n)
        dBx = dt_t[..., None] * B_t[:, None, :] * xc_t.astype(jnp.float32)[..., None]
        h = constrain(dA * h + dBx, cfg, "bm.")
        y = jnp.einsum("bin,bn->bi", h, C_t)
        return h, y

    h0 = jnp.zeros((B, xr.shape[-1], cfg.mamba_d_state), jnp.float32)
    xs = (constrain(xc.swapaxes(0, 1), cfg, ".bm"),
          constrain(dt.swapaxes(0, 1), cfg, ".bm"),
          constrain(Bm.swapaxes(0, 1), cfg, ".b."),
          constrain(Cm.swapaxes(0, 1), cfg, ".b."))
    chunk = cfg.mamba_scan_chunk
    if chunk and S > chunk and S % chunk == 0:
        # §Perf H1: remat the scan in time chunks — the backward pass only
        # keeps carries at chunk boundaries (S/chunk of them) instead of all
        # S per-step (B, di, d_state) carries, trading ~1 extra forward
        # recompute of each chunk for an S/chunk-fold activation-memory cut.
        n_chunks = S // chunk
        xs_c = jax.tree_util.tree_map(
            lambda a: a.reshape((n_chunks, chunk) + a.shape[1:]), xs)

        @jax.checkpoint
        def chunk_body(h, xs_chunk):
            return jax.lax.scan(step, h, xs_chunk)

        h_last, ys = jax.lax.scan(chunk_body, h0, xs_c)
        ys = ys.reshape((S,) + ys.shape[2:])
    else:
        h_last, ys = jax.lax.scan(step, h0, xs)                 # (S,B,di)
    y = constrain(ys.swapaxes(0, 1), cfg, "b.m").astype(x.dtype)
    y = y + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = constrain(jnp.einsum("bsi,id->bsd", y, p["out_proj"]), cfg, "b..")
    kc = cfg.mamba_d_conv
    conv_state = xr[:, -(kc - 1):] if S >= kc - 1 else jnp.pad(
        xr, ((0, 0), (kc - 1 - S, 0), (0, 0)))
    return out, {"conv": conv_state, "ssm": h_last}


def mamba_forward(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return mamba_forward_with_state(p, x, cfg)[0]


def mamba_decode(
    p, x: jnp.ndarray, state: Dict[str, jnp.ndarray], cfg: ModelConfig
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode. x: (B, D) -> (y (B, D), new state)."""
    from repro.models.layers import dag
    xz = dag(jnp.einsum("bd,de->be", x, p["in_proj"]), cfg, ".m")
    xr, z = jnp.split(xz, 2, axis=-1)                           # (B,di)
    window = jnp.concatenate([state["conv"], xr[:, None]], axis=1)  # (B,k,di)
    xc = jnp.einsum("bki,ki->bi", window, p["conv_w"])
    xc = jax.nn.silu(xc + p["conv_b"])
    dt, Bm, Cm = _ssm_params(p, xc, cfg)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)
    dBx = dt[..., None] * Bm[:, None, :] * xc.astype(jnp.float32)[..., None]
    h = dA * state["ssm"] + dBx
    y = jnp.einsum("bin,bn->bi", h, Cm).astype(x.dtype)
    y = y + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = dag(jnp.einsum("bi,id->bd", y, p["out_proj"]), cfg, ".f")
    return out, {"conv": window[:, 1:], "ssm": h}

"""Capacity-based top-k Mixture-of-Experts FFN (GShard/Switch style).

Expert weights carry the logical "expert" axis -> sharded over the tensor
('model') mesh axis; the dispatch/combine einsums between batch-sharded
activations and expert-sharded tensors lower to all-to-all under pjit.

The sequence is processed in groups of ``group_size`` tokens via lax.scan so
the one-hot dispatch tensor (B, g, E, C) of a single group is the peak
routing footprint; per-token routing is identical to ungrouped GShard with
per-group capacity.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec


def moe_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamSpec((d, e), ("embed", None)),
        "w_gate": ParamSpec((e, d, f), ("expert", "embed", None)),
        "w_up": ParamSpec((e, d, f), ("expert", "embed", None)),
        "w_down": ParamSpec((e, f, d), ("expert", None, "embed")),
    }


def capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = tokens_per_group * cfg.experts_per_token * cfg.capacity_factor
    return max(1, int(-(-c // cfg.num_experts)))


def route(probs: jnp.ndarray, cfg: ModelConfig, C: int):
    """Top-k routing with per-expert capacity.

    probs: (B, g, E) router softmax.
    Returns (dispatch (B,g,E,C) float {0,1}, combine (B,g,E,C) float,
             aux load-balance loss scalar).
    """
    B, g, E = probs.shape
    K = cfg.experts_per_token
    combine = jnp.zeros((B, g, E, C), jnp.float32)
    dispatch = jnp.zeros((B, g, E, C), jnp.float32)
    remaining = probs
    prev_count = jnp.zeros((B, 1, E), jnp.float32)
    gates_sum = jnp.zeros((B, g), jnp.float32)
    first_onehot = None
    for _ in range(K):
        idx = jnp.argmax(remaining, axis=-1)                    # (B,g)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)      # (B,g,E)
        if first_onehot is None:
            first_onehot = onehot
        gate = jnp.sum(probs * onehot, axis=-1)                 # (B,g)
        # position of each token within its expert's capacity buffer
        pos_in_expert = (jnp.cumsum(onehot, axis=1) - onehot) + prev_count
        prev_count = prev_count + jnp.sum(onehot, axis=1, keepdims=True)
        pos = jnp.sum(pos_in_expert * onehot, axis=-1)          # (B,g)
        keep = pos < C                                          # capacity drop
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
        full = onehot[..., None] * pos_oh[..., None, :]         # (B,g,E,C)
        full = full * keep[..., None, None]
        dispatch = jnp.maximum(dispatch, full)
        combine = combine + gate[..., None, None] * full
        gates_sum = gates_sum + gate * keep
        remaining = remaining * (1.0 - onehot)
    combine = combine / jnp.maximum(gates_sum[..., None, None], 1e-9)
    # Switch aux loss: E * sum_e mean(probs_e) * mean(top1 == e)
    me = jnp.mean(probs, axis=(0, 1))
    fe = jnp.mean(first_onehot, axis=(0, 1))
    aux = E * jnp.sum(me * fe)
    return dispatch, combine, aux


def _moe_group(p, xg: jnp.ndarray, cfg: ModelConfig, C: int):
    """One token group. xg: (B, g, D) -> (y (B,g,D), aux)."""
    from repro.models.layers import dag
    logits = jnp.einsum("bsd,de->bse", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    dispatch, combine, aux = route(probs, cfg, C)
    dispatch = dispatch.astype(xg.dtype)
    combine = combine.astype(xg.dtype)
    # dispatch -> (E, B, C, D): expert axis model-sharded => all-to-all
    xd = dag(jnp.einsum("bsec,bsd->ebcd", dispatch, xg), cfg, "m...")
    up = dag(jnp.einsum("ebcd,edf->ebcf", xd, p["w_up"]), cfg, "m...")
    gate = dag(jnp.einsum("ebcd,edf->ebcf", xd, p["w_gate"]), cfg, "m...")
    h = up * jax.nn.silu(gate)
    yd = dag(jnp.einsum("ebcf,efd->ebcd", h, p["w_down"]), cfg, "m...")
    y = dag(jnp.einsum("bsec,ebcd->bsd", combine, yd), cfg, "..f")
    return y, aux


def moe_forward(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                     # (B, S, D)
    cfg: ModelConfig,
    group_size: int = 256,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,D), aux_loss scalar)."""
    B, S, D = x.shape
    g = min(group_size, S)
    if S % g:                                # pad sequence to group multiple
        pad = g - S % g
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    else:
        pad, xp = 0, x
    n_groups = xp.shape[1] // g
    C = capacity(g, cfg)
    if n_groups == 1:
        y, aux = _moe_group(p, xp, cfg, C)
        return y[:, :S], aux

    xs = xp.reshape(B, n_groups, g, D).swapaxes(0, 1)           # (N,B,g,D)

    def step(aux_acc, xg):
        y, aux = _moe_group(p, xg, cfg, C)
        return aux_acc + aux, y

    aux_total, ys = jax.lax.scan(step, jnp.zeros((), jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(B, n_groups * g, D)[:, :S]
    return y, aux_total / n_groups

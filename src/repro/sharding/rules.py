"""Logical-axis -> mesh-axis mapping with divisibility fallbacks.

Param schemas label dims with logical names; this module maps them onto the
production mesh:

  embed   -> FSDP axes ('data',) or ('pod','data')   [param storage sharding]
  heads / kv_heads / ff / vocab / expert -> ('model',) [tensor parallelism]

A dim is sharded only when its size divides the mesh-axis product, else it
falls back to replication (DESIGN.md §4: llama4 40 heads, whisper 8 heads,
granite kv=1 all replicate over model=16 while their FFN/vocab still shard).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, is_param_spec

TENSOR_AXES = ("model",)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return fsdp_axes(mesh)


LOGICAL = {
    "embed": "fsdp",
    "heads": "tensor",
    "kv_heads": "tensor",
    "ff": "tensor",
    "vocab": "tensor",
    "expert": "tensor",
}


def _axis_prod(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def dim_spec(mesh: Mesh, size: int, logical: Optional[str]):
    """Resolve one dim: logical name -> mesh axes (or None on indivisible)."""
    if logical is None:
        return None
    kind = LOGICAL[logical]
    axes = fsdp_axes(mesh) if kind == "fsdp" else TENSOR_AXES
    if size % _axis_prod(mesh, axes) != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def spec_of(mesh: Mesh, ps: ParamSpec, mode: str = "train") -> P:
    axes = ps.axes
    if mode == "infer":
        # tensor-parallel only: keep weights resident (no per-step FSDP
        # all-gather); used when params fit HBM without the fsdp axis
        axes = tuple(None if a == "embed" else a for a in axes)
    return P(*(dim_spec(mesh, s, a) for s, a in zip(ps.shape, axes)))


def param_pspecs(mesh: Mesh, schema: Any, mode: str = "train"):
    """Walk a schema pytree -> matching PartitionSpec pytree."""
    return jax.tree_util.tree_map(lambda ps: spec_of(mesh, ps, mode), schema,
                                  is_leaf=is_param_spec)


def param_bytes_per_chip(mesh: Mesh, schema: Any, mode: str) -> int:
    """Storage bytes/chip under the given sharding mode (bf16 assumed for
    un-flagged dtypes)."""
    total = 0
    for ps in jax.tree_util.tree_leaves(schema, is_leaf=is_param_spec):
        n = int(np.prod(ps.shape)) if ps.shape else 1
        itemsize = np.dtype(ps.dtype).itemsize if ps.dtype else 2
        spec = spec_of(mesh, ps, mode)
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            shards *= _axis_prod(mesh, tuple(axes))
        total += n * itemsize // shards
    return total


def shardings(mesh: Mesh, pspecs: Any):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        pspecs, is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------- #
# Activation / state specs
# --------------------------------------------------------------------- #
def batch_dim(mesh: Mesh, b: int):
    axes = batch_axes(mesh)
    if b % _axis_prod(mesh, axes) == 0:
        return axes if len(axes) > 1 else axes[0]
    # long_500k: batch=1 -> replicate
    if "data" in mesh.axis_names and b % mesh.shape["data"] == 0:
        return "data"
    return None


def model_dim(mesh: Mesh, size: int):
    return "model" if size % mesh.shape["model"] == 0 else None


def tokens_spec(mesh: Mesh, b: int) -> P:
    return P(batch_dim(mesh, b), None)


def decode_state_pspecs(cfg: ModelConfig, mesh: Mesh, state) -> Any:
    """PartitionSpecs for DecodeState / WhisperState / PagedDecodeState,
    driven by the concrete array shapes in `state` (works for
    ShapeDtypeStructs too)."""
    def leaf_spec(path, x) -> P:
        names = [getattr(p, "name", getattr(p, "key", None)) for p in path]
        shape = x.shape
        nd = len(shape)
        if nd == 0:
            return P()
        # recovery fields: (B,)
        if nd == 1:
            return P(batch_dim(mesh, shape[0]))
        # freeze / page_table / slot_mask / positions: (L, B, ...)
        field = names[0] if names else ""
        b = shape[1] if nd >= 2 else shape[0]
        if field in ("cache_k", "cache_v", "k", "v"):
            # (L,B,S,KVH,hd) or (L,B,P,page,KVH,hd).  Prefer sharding the
            # sequence/page dim over 'model' (flash-decoding style: softmax
            # over a sharded KV dim lowers to cheap psums) — it always
            # divides, unlike kv_heads (GQA kv<=16, MQA kv=1).
            seq_d = model_dim(mesh, shape[2])
            kvh_d = model_dim(mesh, shape[-2]) if seq_d is None else None
            mid = (None,) * (nd - 5)
            return P(None, batch_dim(mesh, b), seq_d, *mid, kvh_d, None)
        if field in ("cross_k", "cross_v"):
            seq_d = model_dim(mesh, shape[2])
            kvh_d = model_dim(mesh, shape[-2]) if seq_d is None else None
            return P(None, batch_dim(mesh, b), seq_d, kvh_d, None)
        if field == "mamba":
            # conv (L,B,kc,di) / ssm (L,B,di,n)
            if names[-1] == "conv":
                return P(None, batch_dim(mesh, b), None, model_dim(mesh, shape[-1]))
            return P(None, batch_dim(mesh, b), model_dim(mesh, shape[2]), None)
        if field == "rwkv":
            if names[-1] == "wkv":   # (L,B,H,hd,hd)
                return P(None, batch_dim(mesh, b), model_dim(mesh, shape[2]),
                         None, None)
            return P(None, batch_dim(mesh, b), None)
        # freeze state arrays, page tables, masks: (L,B,S,...) — keep the
        # slot dim co-sharded with the KV cache sequence/page dim so the
        # relevance -> freeze-update dataflow never reshards
        if nd >= 3:
            return P(None, batch_dim(mesh, b), model_dim(mesh, shape[2]),
                     *((None,) * (nd - 3)))
        return P(None, batch_dim(mesh, b))

    return jax.tree_util.tree_map_with_path(leaf_spec, state)

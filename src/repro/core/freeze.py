"""ASR-KF-EGR soft-freeze state machine (paper Algorithm 1), fully
vectorized so it runs inside a jitted decode step on TPU.

Per KV slot we track:
  c          low-importance detection counter (Eq. 3 input)
  d          remaining freeze duration (steps)
  frozen     True -> excluded from attention, (K,V) eligible for host offload
  frozen_at  decode step at which the slot was last frozen (Window Reset)

All arrays are (B, S); the transformer stacks them (L, B, S) per layer.

Deviation from the paper's pseudocode (documented in DESIGN.md): Alg. 1
decrements *all* frozen timers in the same step tokens are frozen, which
would immediately restore any token frozen with d=1 (contradicting §3.4's
"c=4 -> d=1" example).  We decrement only slots frozen in *previous* steps,
so d=1 means "frozen for exactly one step" — matching the schedule's intent.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax.numpy as jnp

from repro.configs.base import FreezeConfig


class FreezeState(NamedTuple):
    c: jnp.ndarray          # (B, S) int32
    d: jnp.ndarray          # (B, S) int32
    frozen: jnp.ndarray     # (B, S) bool
    frozen_at: jnp.ndarray  # (B, S) int32


def init_freeze_state(batch: int, seq: int) -> FreezeState:
    return FreezeState(
        c=jnp.zeros((batch, seq), jnp.int32),
        d=jnp.zeros((batch, seq), jnp.int32),
        frozen=jnp.zeros((batch, seq), bool),
        frozen_at=jnp.full((batch, seq), -1, jnp.int32),
    )


def schedule(c: jnp.ndarray, k_soft: float) -> jnp.ndarray:
    """Eq. 3: d = floor(sqrt(c) / k) — sublinear freeze duration."""
    return jnp.floor(jnp.sqrt(c.astype(jnp.float32)) / k_soft).astype(jnp.int32)


def effective_tau(relevance: jnp.ndarray, eligible: jnp.ndarray,
                  cfg: FreezeConfig) -> jnp.ndarray:
    """Paper mode: fixed tau (Eq. 2 threshold).  Beyond-paper "quantile"
    mode: per-sequence threshold at the `cfg.quantile` quantile of currently
    eligible scores — flag rate becomes scale-invariant across models."""
    if cfg.tau_mode == "fixed":
        return jnp.asarray(cfg.tau, relevance.dtype)
    scores = jnp.where(eligible, relevance, jnp.nan)
    tau = jnp.nanquantile(scores.astype(jnp.float32), cfg.quantile,
                          axis=-1, keepdims=True)
    return jnp.where(jnp.isnan(tau), -jnp.inf, tau).astype(relevance.dtype)


def active_mask(state: FreezeState, pos: jnp.ndarray, seq: int) -> jnp.ndarray:
    """(B, S) True for slots that participate in attention: written
    (slot <= pos) and not frozen."""
    idx = jnp.arange(seq)
    exists = idx[None, :] <= pos[:, None] if pos.ndim else idx[None, :] <= pos
    return exists & ~state.frozen


def freeze_update(
    state: FreezeState,
    relevance: jnp.ndarray,      # (B, S) Eq. 2 scores for the current step
    pos: jnp.ndarray,            # () or (B,) index of the newest token
    step: jnp.ndarray,           # () or (B,) decode step (frozen_at / decay)
    cfg: FreezeConfig,
) -> Tuple[FreezeState, Dict[str, jnp.ndarray]]:
    """One rolling ASR-KF-EGR update (Alg. 1 lines 2–15).

    `pos` and `step` may be per-lane (B,) vectors: continuous batching runs
    every lane at its own position / decode-step counter.

    Returns (new_state, info) with info masks for the host-offload
    controller and telemetry:
      just_frozen / restored : (B, S) bool — slots that changed state
      active                  : (B, S) bool — post-update attention mask
      n_active / n_frozen     : (B,) int32
    """
    B, S = relevance.shape
    pos = jnp.asarray(pos)
    pos_b = pos[:, None] if pos.ndim else pos[None, None]
    step = jnp.asarray(step)
    step_b = step[:, None] if step.ndim else step
    idx = jnp.arange(S)[None, :]
    exists = idx <= pos_b
    in_window = idx > (pos_b - cfg.window)          # K most-recent tokens
    was_frozen = state.frozen

    # -- lines 3–9: flag low-importance tokens outside the window --------- #
    eligible = exists & ~in_window & ~was_frozen
    tau = effective_tau(relevance, eligible, cfg)
    flagged = eligible & (relevance < tau)
    c_new = state.c + flagged.astype(jnp.int32)
    d_sched = schedule(c_new, cfg.k_soft)
    just_frozen = flagged & (d_sched > 0)
    frozen_mid = was_frozen | just_frozen
    d_mid = jnp.where(just_frozen, d_sched, state.d)
    frozen_at = jnp.where(just_frozen, step_b, state.frozen_at)

    # -- lines 10–14: rolling decrement + restore (previously-frozen only) #
    d_dec = jnp.where(was_frozen, d_mid - 1, d_mid)
    restored = was_frozen & (d_dec <= 0)
    frozen_new = frozen_mid & ~restored
    d_new = jnp.where(restored, 0, d_dec)

    # -- history window W: age out stale detections (periodic decay) ------ #
    decay = (step_b % cfg.history) == (cfg.history - 1)
    c_new = jnp.where(decay, jnp.maximum(c_new - 1, 0), c_new)

    new_state = FreezeState(c=c_new, d=d_new, frozen=frozen_new, frozen_at=frozen_at)
    active = exists & ~frozen_new
    info = {
        "just_frozen": just_frozen,
        "restored": restored,
        "active": active,
        "n_active": jnp.sum(active, axis=-1).astype(jnp.int32),
        "n_frozen": jnp.sum(frozen_new & exists, axis=-1).astype(jnp.int32),
    }
    return new_state, info


# --------------------------------------------------------------------- #
# Recovery actions (used by repro.core.recovery) — operate on stacked or
# unstacked FreezeState; `sel` is a (B,) bool mask broadcast over slots.
# --------------------------------------------------------------------- #
def _bmask(sel: jnp.ndarray, arr: jnp.ndarray) -> jnp.ndarray:
    """Broadcast (B,) selector over (..., B, S) arrays."""
    shape = [1] * arr.ndim
    shape[-2] = sel.shape[0]
    return sel.reshape(shape)


def soft_reset(state: FreezeState, sel: jnp.ndarray) -> FreezeState:
    """SR: unfreeze tokens with d > 1 (the long-frozen ones)."""
    hit = _bmask(sel, state.d) & (state.d > 1)
    return state._replace(frozen=state.frozen & ~hit,
                          d=jnp.where(hit, 0, state.d))


def window_reset(state: FreezeState, sel: jnp.ndarray, step: jnp.ndarray,
                 window: int) -> FreezeState:
    """WR: unfreeze everything frozen within the last `window` steps.
    `step` may be per-lane (B,), aligned with the batch axis of the state."""
    step = jnp.asarray(step)
    if step.ndim:
        step = _bmask(step, state.frozen_at)
    recent = state.frozen_at > (step - window)
    hit = _bmask(sel, state.d) & recent
    return state._replace(frozen=state.frozen & ~hit,
                          d=jnp.where(hit, 0, state.d))


def full_reset(state: FreezeState, sel: jnp.ndarray) -> FreezeState:
    """FR: clear all freeze state globally (for selected sequences)."""
    hit = _bmask(sel, state.d) & jnp.ones_like(state.frozen)
    return FreezeState(
        c=jnp.where(hit, 0, state.c),
        d=jnp.where(hit, 0, state.d),
        frozen=state.frozen & ~hit,
        frozen_at=jnp.where(hit, -1, state.frozen_at),
    )


def reset_lane(state: FreezeState, lane) -> FreezeState:
    """Lane-granular reset: clear every freeze bookkeeping array for one
    batch lane.  Continuous batching reuses lanes across requests, so the
    retiring request's counters/masks must not leak into its successor."""
    sel = jnp.arange(state.c.shape[-2]) == jnp.asarray(lane)
    return full_reset(state, sel)

"""KV cache structures for ASR-KF-EGR serving.

Two layouts:

* **Contiguous** — (L, B, S_max, KVH, hd) buffers with a freeze mask; the
  faithful in-step representation of the paper (every slot addressable,
  frozen ones excluded from attention).  Offload of frozen *pages* to host
  memory is handled by `HostOffloadController` between steps.

* **Paged / bounded-active** — the TPU-native long-context layout: the device
  holds only `max_active_pages` pages per sequence plus a page table; all
  other pages (frozen or cold) live in the host store.  This is what makes
  `long_500k` decode lower with a bounded device footprint (DESIGN.md §2/§5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import quant


class KVCache(NamedTuple):
    k: jnp.ndarray   # (L, B, S, KVH, hd)
    v: jnp.ndarray   # (L, B, S, KVH, hd)

    @property
    def seq_len(self) -> int:
        return self.k.shape[2]


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> KVCache:
    n_attn = sum(1 for l in range(cfg.num_layers) if cfg.is_attn_layer(l))
    shape = (n_attn, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def reset_lane(cache: KVCache, lane) -> KVCache:
    """Lane-granular reset: zero one batch lane's K/V slots so a retired
    request's cache cannot leak into the lane's next occupant."""
    sel = (jnp.arange(cache.k.shape[1]) == jnp.asarray(lane)
           ).reshape(1, -1, 1, 1, 1)
    return KVCache(k=jnp.where(sel, 0, cache.k),
                   v=jnp.where(sel, 0, cache.v))


def cache_write(k_layer: jnp.ndarray, v_layer: jnp.ndarray,
                new_k: jnp.ndarray, new_v: jnp.ndarray,
                pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write one token (B, KVH, hd) at position `pos` into (B, S, KVH, hd)."""
    k = jax.lax.dynamic_update_slice_in_dim(k_layer, new_k[:, None], pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(v_layer, new_v[:, None], pos, axis=1)
    return k, v


class PagedKVCache(NamedTuple):
    """Bounded-active paged cache (one entry per attention layer).

    k, v:        (L, B, P, page, KVH, hd) — device-resident active pages only
    page_table:  (L, B, P) int32 — global page id held in each physical slot
                 (-1 = empty slot)
    slot_mask:   (L, B, P, page) bool — valid+unfrozen token positions within
                 each physical page (padding/frozen tokens are False)
    positions:   (L, B, P, page) int32 — global token position of each slot
                 (for telemetry; RoPE is applied at write time)
    """
    k: jnp.ndarray
    v: jnp.ndarray
    page_table: jnp.ndarray
    slot_mask: jnp.ndarray
    positions: jnp.ndarray

    @property
    def page_size(self) -> int:
        return self.k.shape[3]


def init_paged_cache(cfg: ModelConfig, batch: int, max_active_pages: int,
                     dtype=jnp.bfloat16) -> PagedKVCache:
    n_attn = sum(1 for l in range(cfg.num_layers) if cfg.is_attn_layer(l))
    P, page = max_active_pages, cfg.freeze.page_size
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    return PagedKVCache(
        k=jnp.zeros((n_attn, batch, P, page, kvh, hd), dtype),
        v=jnp.zeros((n_attn, batch, P, page, kvh, hd), dtype),
        page_table=jnp.full((n_attn, batch, P), -1, jnp.int32),
        slot_mask=jnp.zeros((n_attn, batch, P, page), bool),
        positions=jnp.zeros((n_attn, batch, P, page), jnp.int32),
    )


# ===================================================================== #
# Host offload controller — runs OUTSIDE the jitted step, page-granular.
# ===================================================================== #
@dataclasses.dataclass
class HostOffloadController:
    """Keeps the paper's "frozen storage F" in host RAM.

    After each jitted step the controller reads the freeze masks, finds pages
    whose tokens are *all* frozen, copies them to the host store (numpy) and
    marks them released; when any token of an offloaded page is restored
    (timer expiry / recovery reset) the page is uploaded back before the next
    step.  Transfers are page-batched — the TPU analogue of the paper's
    proposed "batched transfers" fix for their 5x Python overhead (§6).

    On real TPU hardware the store would live in `pinned_host` memory with
    async DMA; on CPU the mechanism (and its bookkeeping) is identical.
    """
    page_size: int
    store: Dict[Tuple[int, int, int], Tuple[np.ndarray, np.ndarray]] = \
        dataclasses.field(default_factory=dict)
    offloaded: set = dataclasses.field(default_factory=set)
    n_offloads: int = 0
    n_restores: int = 0
    # ---- host-stash memory budget (robustness) ------------------------ #
    # Offloading frozen pages is an optimization (it models releasing
    # their device slots), so the graceful degradation under host-memory
    # pressure is simply to stop: with the stash at/over budget, newly
    # fully-frozen pages stay device-resident (the freeze mask already
    # excludes them from attention — token streams are unchanged) and are
    # counted in ``n_denied_offloads``.  Restores are never denied.
    stash_bytes: int = 0
    stash_budget_bytes: "int | None" = None
    n_denied_offloads: int = 0
    # ---- lossy host-stash compression (core/quant.py) ------------------ #
    # "int8"/"fp8" stores each offloaded page as its 1-byte payload with
    # per-page per-kv-head scales (stash_bytes counts the payload, so the
    # budget ladder sees the real cut); restores dequantize HOST-SIDE —
    # the dense cache has no per-page scale slots, unlike the paged pool's
    # in-kernel dequant path.  "none" is byte-identical to the old store.
    kv_quant: str = "none"
    quant_scales: Dict[Tuple[int, int, int],
                       Tuple[np.ndarray, np.ndarray]] = \
        dataclasses.field(default_factory=dict)

    @property
    def stash_pressure(self) -> float:
        """Stash bytes as a fraction of the budget (0.0 when unbounded)."""
        if not self.stash_budget_bytes:
            return 0.0
        return self.stash_bytes / self.stash_budget_bytes

    def _all_frozen(self, frozen: np.ndarray,
                    reduced: bool = False) -> np.ndarray:
        """Page-granular reduction of the (L, B, S) token freeze mask —
        or a passthrough when the caller already reduced it (the async
        pipeline reduces ON DEVICE so only (L, B, n_pages) bools ride the
        per-step fetch, page_size x less D2H than the token mask)."""
        if reduced:
            return frozen                                   # (L, B, n_pages)
        L, B, S = frozen.shape
        pg = self.page_size
        n_pages = S // pg
        fz = frozen[:, :, : n_pages * pg].reshape(L, B, n_pages, pg)
        return fz.all(axis=-1)                              # (L, B, n_pages)

    def needs_sync(self, frozen: np.ndarray, reduced: bool = False) -> bool:
        """True iff a `sync` with this freeze mask would move any page:
        a fully-frozen page not yet offloaded, or an offloaded page that
        thawed — i.e. the fully-frozen set differs from the offloaded
        set.  The async serving pipeline fetches only the (small,
        page-reduced) freeze mask with its per-step telemetry ring and
        calls `sync` — which round-trips the whole K/V cache — only when
        this says a transfer is actually due."""
        all_frozen = self._all_frozen(frozen, reduced)
        want = {(int(l), int(b), int(p))
                for l, b, p in zip(*np.nonzero(all_frozen))}
        return want != self.offloaded

    def sync(self, cache: KVCache, frozen: np.ndarray,
             reduced: bool = False) -> KVCache:
        """frozen: (L, B, S) bool (post-step), or the (L, B, n_pages)
        page-reduction when ``reduced``.  Returns cache with restored
        pages written back.  Offloaded pages are tracked; their device slots
        are considered reclaimable (zeroed to model release)."""
        pg = self.page_size
        all_frozen = self._all_frozen(frozen, reduced)     # (L, B, n_pages)
        # mutable host copies of the full cache: sync round-trips K/V by
        # design, and the serving engines gate it behind needs_sync so it
        # runs only when a page actually moves
        # hotpath: ok(page-batched offload round-trip, gated by needs_sync)
        k_host = np.array(cache.k)
        # hotpath: ok(page-batched offload round-trip, gated by needs_sync)
        v_host = np.array(cache.v)
        dirty = False
        for (l, b, p) in zip(*np.nonzero(all_frozen)):
            key = (int(l), int(b), int(p))
            if key not in self.offloaded:
                sl = slice(p * pg, (p + 1) * pg)
                kk = k_host[l, b, sl].copy()
                vv = v_host[l, b, sl].copy()
                mode = quant.MODES[self.kv_quant]
                qm = None
                if mode:
                    kk, ks = quant.quantize_page(kk, mode)
                    vv, vs = quant.quantize_page(vv, mode)
                    qm = (ks, vs)
                # budget check on what the stash actually holds — the
                # 1-byte payload under an active quant mode
                if self.stash_budget_bytes is not None and \
                        self.stash_bytes + kk.nbytes + vv.nbytes > \
                        self.stash_budget_bytes:
                    self.n_denied_offloads += 1
                    continue       # page stays resident (and frozen)
                if qm is not None:
                    self.quant_scales[key] = qm
                self.store[key] = (kk, vv)
                self.stash_bytes += kk.nbytes + vv.nbytes
                self.offloaded.add(key)
                self.n_offloads += 1
                k_host[l, b, sl] = 0                       # model slot release
                v_host[l, b, sl] = 0
                dirty = True
        # restore pages that are no longer fully frozen
        for key in list(self.offloaded):
            l, b, p = key
            if not all_frozen[l, b, p]:
                kk, vv = self.store.pop(key)
                self.stash_bytes -= kk.nbytes + vv.nbytes
                qm = self.quant_scales.pop(key, None)
                if qm is not None:
                    kk = quant.dequantize_page(kk, qm[0])
                    vv = quant.dequantize_page(vv, qm[1])
                sl = slice(p * pg, (p + 1) * pg)
                k_host[l, b, sl] = kk
                v_host[l, b, sl] = vv
                self.offloaded.discard(key)
                self.n_restores += 1
                dirty = True
        if dirty:
            return KVCache(k=jnp.asarray(k_host), v=jnp.asarray(v_host))
        return cache

    @property
    def offloaded_tokens(self) -> int:
        return len(self.offloaded) * self.page_size

    # ---- per-lane bookkeeping (continuous batching) ------------------- #
    def offloaded_tokens_lane(self, lane: int) -> int:
        """Offloaded token count for one batch lane (store keys are
        (layer, batch, page), so lane membership is exact)."""
        return sum(self.page_size for key in self.offloaded if key[1] == lane)

    def drop_lane(self, lane: int) -> int:
        """Forget every offloaded page belonging to one batch lane.

        Called when the lane is reassigned to a new request: the admission
        prefill overwrites the lane's device slots wholesale, so restoring
        the retired request's pages would corrupt the new occupant's cache.
        Returns the number of pages dropped."""
        stale = [key for key in self.offloaded if key[1] == lane]
        for key in stale:
            kv = self.store.pop(key, None)
            if kv is not None:
                self.stash_bytes -= kv[0].nbytes + kv[1].nbytes
            self.quant_scales.pop(key, None)
            self.offloaded.discard(key)
        return len(stale)

"""ASR-KF-EGR core: the paper's contribution as composable JAX modules.

freeze.py    — Algorithm 1 (soft freeze + sublinear schedule + rolling
               re-evaluation), vectorized for in-jit execution
recovery.py  — Entropy-Guided Recovery ladder (§3.6, implemented)
cache.py     — contiguous KV cache + host offload controller
paging.py    — bounded-active paged cache (TPU-native long-context mode)
"""
from repro.core.freeze import (FreezeState, active_mask, freeze_update,
                               full_reset, init_freeze_state, schedule,
                               soft_reset, window_reset)
from repro.core.recovery import (RecoveryState, init_recovery_state,
                                 recovery_update, token_entropy)
from repro.core.cache import (HostOffloadController, KVCache, PagedKVCache,
                              cache_write, init_kv_cache, init_paged_cache)
from repro.core.paging import (PagedController, PageFreezeState,
                               init_page_freeze_state, page_freeze_update,
                               paged_decode_attention, write_tail)

__all__ = [
    "FreezeState", "active_mask", "freeze_update", "full_reset",
    "init_freeze_state", "schedule", "soft_reset", "window_reset",
    "RecoveryState", "init_recovery_state", "recovery_update", "token_entropy",
    "HostOffloadController", "KVCache", "PagedKVCache", "cache_write",
    "init_kv_cache", "init_paged_cache",
    "PagedController", "PageFreezeState", "init_page_freeze_state",
    "page_freeze_update", "paged_decode_attention", "write_tail",
]

"""Entropy-Guided Recovery (paper §3.6 — proposed there as future work,
implemented here as a first-class feature).

A per-sequence escalation ladder SR -> WR -> FR -> RR is driven by output
entropy: a *spike* (absolute threshold or relative to an EMA baseline)
escalates one level and applies that level's intervention to the freeze
state; sustained calm de-escalates.  RR (Rewalk Regeneration) cannot be done
inside a jitted step — it rewinds generation — so the step only raises
``rr_request`` and the serving engine performs the rewind (engine.py).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FreezeConfig
from repro.core.freeze import FreezeState, full_reset, soft_reset, window_reset

# ladder levels
CALM, SR, WR, FR, RR = 0, 1, 2, 3, 4


class RecoveryState(NamedTuple):
    ema_entropy: jnp.ndarray   # (B,) f32 — EMA baseline of output entropy
    level: jnp.ndarray         # (B,) int32 — current escalation level
    calm_steps: jnp.ndarray    # (B,) int32 — consecutive non-spike steps
    steps_seen: jnp.ndarray    # (B,) int32 — for EMA warmup


def init_recovery_state(batch: int) -> RecoveryState:
    return RecoveryState(
        ema_entropy=jnp.zeros((batch,), jnp.float32),
        level=jnp.zeros((batch,), jnp.int32),
        calm_steps=jnp.zeros((batch,), jnp.int32),
        steps_seen=jnp.zeros((batch,), jnp.int32),
    )


def reset_lane(rec: RecoveryState, lane) -> RecoveryState:
    """Lane-granular reset: a retiring request's entropy baseline and
    escalation level must not carry over to the lane's next occupant."""
    sel = jnp.arange(rec.level.shape[0]) == jnp.asarray(lane)
    return RecoveryState(
        ema_entropy=jnp.where(sel, 0.0, rec.ema_entropy),
        level=jnp.where(sel, 0, rec.level),
        calm_steps=jnp.where(sel, 0, rec.calm_steps),
        steps_seen=jnp.where(sel, 0, rec.steps_seen),
    )


def token_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """Shannon entropy (nats) of the next-token distribution. logits: (B, V)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def recovery_update(
    rec: RecoveryState,
    freeze: FreezeState,            # stacked (L, B, S) or flat (B, S)
    logits: jnp.ndarray,            # (B, V)
    step: jnp.ndarray,
    cfg: FreezeConfig,
) -> Tuple[RecoveryState, FreezeState, dict]:
    ent = token_entropy(logits)                                   # (B,)
    warm = rec.steps_seen >= 8
    spike = warm & (
        (ent > cfg.entropy_abs_threshold)
        | (ent > cfg.entropy_rel_factor * jnp.maximum(rec.ema_entropy, 1e-3))
    )
    if not cfg.recovery_enabled:
        spike = jnp.zeros_like(spike)

    level = jnp.where(spike, jnp.minimum(rec.level + 1, RR), rec.level)
    calm = jnp.where(spike, 0, rec.calm_steps + 1)
    deescalate = calm >= cfg.calm_steps_to_deescalate
    level = jnp.where(deescalate & ~spike, jnp.maximum(level - 1, 0), level)
    calm = jnp.where(deescalate, 0, calm)

    # apply the ladder interventions for sequences spiking at each level
    freeze = soft_reset(freeze, spike & (level == SR))
    freeze = window_reset(freeze, spike & (level == WR), step, cfg.recovery_window)
    freeze = full_reset(freeze, spike & (level >= FR))
    rr_request = spike & (level == RR)
    # RR is terminal for the ladder: after requesting a rewalk the escalation
    # restarts from CALM (prevents a rewind livelock under sustained spikes)
    level = jnp.where(rr_request, CALM, level)

    # EMA update (only post-update so the spike itself doesn't pollute the
    # baseline immediately)
    a = cfg.entropy_ema_decay
    ema = jnp.where(rec.steps_seen == 0, ent, a * rec.ema_entropy + (1 - a) * ent)
    new = RecoveryState(ema_entropy=ema, level=level, calm_steps=calm,
                        steps_seen=rec.steps_seen + 1)
    info = {"entropy": ent, "spike": spike, "level": level, "rr_request": rr_request}
    return new, freeze, info
